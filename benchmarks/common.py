"""Shared benchmark utilities (timing, CSV output, GPResult rows)."""

from __future__ import annotations

import json
import os
import time

import numpy as np

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS_DIR = os.path.join(_REPO_ROOT, "results")

# Machine-readable perf trajectory: every benchmark driver appends rows here
# so future PRs can diff against the committed numbers and catch regressions.
BENCH_PATH = os.path.join(_REPO_ROOT, "BENCH_gp.json")


def bench_record(bench: str, *, scenario: str, V: int, solver: str,
                 seconds: float, iters: int | None = None, **extra) -> dict:
    """Append one perf row to the top-level ``BENCH_gp.json``.

    Rows are keyed by (bench, scenario, V, solver): re-running a driver
    replaces its previous rows instead of growing the file, so the
    committed trajectory stays one row per measurement point.

    ``seconds`` is wall clock for the measured unit; when ``iters`` (total
    committed GP iterations) is given a derived ``s_per_iter`` is stored.
    Extra keyword fields (e.g. ``speedup``, ``n``) are stored verbatim.
    """
    row = {"bench": bench, "scenario": scenario, "V": int(V),
           "solver": solver, "seconds": round(float(seconds), 6)}
    if iters is not None:
        row["iters"] = int(iters)
        row["s_per_iter"] = round(float(seconds) / max(int(iters), 1), 8)
    row.update(extra)
    rows = []
    if os.path.exists(BENCH_PATH):
        try:
            with open(BENCH_PATH) as f:
                rows = json.load(f)["rows"]
        except (json.JSONDecodeError, KeyError):
            rows = []
    key = (row["bench"], row["scenario"], row["V"], row["solver"])
    rows = [r for r in rows
            if (r.get("bench"), r.get("scenario"), r.get("V"),
                r.get("solver")) != key]
    rows.append(row)
    with open(BENCH_PATH, "w") as f:
        json.dump({"rows": rows}, f, indent=1)
    return row


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """The harness contract: ``name,us_per_call,derived`` CSV lines."""
    print(f"{name},{us_per_call:.1f},{derived}")


def save_json(rel_path: str, obj) -> str:
    path = os.path.join(RESULTS_DIR, rel_path)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)
    return path


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.seconds = time.time() - self.t0

    @property
    def us(self) -> float:
        return self.seconds * 1e6


def result_row(res) -> dict:
    """JSON-serializable summary of a ``gp.GPResult``.

    Histories are dense jnp device arrays; trim to the committed prefix and
    convert via numpy so json doesn't choke on them."""
    trimmed = res.trim()
    hist = np.asarray(trimmed.cost_history, dtype=float)
    return {
        "final_cost": trimmed.final_cost,
        "iterations": int(trimmed.iterations),
        "initial_cost": float(hist[0]),
        "cost_history": hist.tolist(),
    }


def speedup_report(serial_s: float, batched_s: float, n: int) -> str:
    return (f"serial:{serial_s:.2f}s|batched:{batched_s:.2f}s|"
            f"speedup:{serial_s / max(batched_s, 1e-9):.2f}x|n:{n}")
