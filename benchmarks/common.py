"""Shared benchmark utilities (timing, CSV output, GPResult rows)."""

from __future__ import annotations

import json
import os
import time

import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                           "results")


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """The harness contract: ``name,us_per_call,derived`` CSV lines."""
    print(f"{name},{us_per_call:.1f},{derived}")


def save_json(rel_path: str, obj) -> str:
    path = os.path.join(RESULTS_DIR, rel_path)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)
    return path


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.seconds = time.time() - self.t0

    @property
    def us(self) -> float:
        return self.seconds * 1e6


def result_row(res) -> dict:
    """JSON-serializable summary of a ``gp.GPResult``.

    Histories are dense jnp device arrays; trim to the committed prefix and
    convert via numpy so json doesn't choke on them."""
    trimmed = res.trim()
    hist = np.asarray(trimmed.cost_history, dtype=float)
    return {
        "final_cost": trimmed.final_cost,
        "iterations": int(trimmed.iterations),
        "initial_cost": float(hist[0]),
        "cost_history": hist.tolist(),
    }


def speedup_report(serial_s: float, batched_s: float, n: int) -> str:
    return (f"serial:{serial_s:.2f}s|batched:{batched_s:.2f}s|"
            f"speedup:{serial_s / max(batched_s, 1e-9):.2f}x|n:{n}")
