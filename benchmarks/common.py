"""Shared benchmark utilities (timing, CSV output, GPResult rows)."""

from __future__ import annotations

import json
import os
import time

import numpy as np

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS_DIR = os.path.join(_REPO_ROOT, "results")

# Machine-readable perf trajectory: every benchmark driver appends rows here
# so future PRs can diff against the committed numbers and catch regressions.
BENCH_PATH = os.path.join(_REPO_ROOT, "BENCH_gp.json")

# bench_record refuses to overwrite committed rows from a loaded box: the
# 1.5x bench_check gate assumes rows were timed near-idle, and one contended
# rewrite poisons the committed trajectory for every later diff.  The guard
# triggers when the 1-min loadavg exceeds this multiple of the core count.
LOADAVG_CONTENTION_RATIO = 1.5


def _box_is_contended() -> float | None:
    """1-min loadavg when the box is too busy to trust timings, else None.

    ``BENCH_FORCE_RECORD=1`` disables the guard (dedicated runners whose
    steady-state load is legitimately high, or deliberate re-baselining).
    Platforms without ``os.getloadavg`` (Windows) never report contention.
    """
    if os.environ.get("BENCH_FORCE_RECORD"):
        return None
    try:
        load1 = os.getloadavg()[0]
    except (AttributeError, OSError):
        return None
    cores = os.cpu_count() or 1
    if load1 > LOADAVG_CONTENTION_RATIO * cores:
        return load1
    return None


def _default_backend() -> str:
    """The JAX backend rows are stamped with (lazy import — keep the module
    importable without initializing a device)."""
    try:
        import jax
        return jax.default_backend()
    except Exception:
        return "cpu"


def bench_record(bench: str, *, scenario: str, V: int, solver: str,
                 seconds: float, iters: int | None = None,
                 backend: str | None = None, **extra) -> dict:
    """Append one perf row to the top-level ``BENCH_gp.json``.

    Rows are keyed by (bench, scenario, V, solver, backend): re-running a
    driver replaces its previous rows instead of growing the file, so the
    committed trajectory stays one row per measurement point.  ``backend``
    defaults to ``jax.default_backend()`` — timings measured on different
    backends are different measurement points (the per-backend AUTO
    dispatch crossover in ``traffic._derive_auto_min_v`` depends on this),
    and rows written before the key existed count as ``"cpu"`` everywhere
    rows are consumed.

    ``seconds`` is wall clock for the measured unit; when ``iters`` (total
    committed GP iterations) is given a derived ``s_per_iter`` is stored.
    Extra keyword fields (e.g. ``speedup``, ``n``) are stored verbatim.

    On a contended box (1-min loadavg > ``LOADAVG_CONTENTION_RATIO`` x
    cores) the row is returned but NOT written — contended timings would
    replace trustworthy committed rows and trip the bench_check gate on the
    next idle run.  Set ``BENCH_FORCE_RECORD=1`` to record anyway.
    """
    row = {"bench": bench, "scenario": scenario, "V": int(V),
           "solver": solver,
           "backend": backend if backend is not None else _default_backend(),
           "seconds": round(float(seconds), 6)}
    if iters is not None:
        row["iters"] = int(iters)
        row["s_per_iter"] = round(float(seconds) / max(int(iters), 1), 8)
    row.update(extra)
    load1 = _box_is_contended()
    if load1 is not None:
        print(f"bench_record: SKIP {bench}/{scenario}/{solver} — box is "
              f"contended (loadavg {load1:.1f} > "
              f"{LOADAVG_CONTENTION_RATIO:.1f}x {os.cpu_count()} cores); "
              f"set BENCH_FORCE_RECORD=1 to record anyway")
        return row
    rows = []
    if os.path.exists(BENCH_PATH):
        try:
            with open(BENCH_PATH) as f:
                rows = json.load(f)["rows"]
        except (json.JSONDecodeError, KeyError):
            rows = []
    key = _row_key(row)
    rows = [r for r in rows if _row_key(r) != key]
    rows.append(row)
    with open(BENCH_PATH, "w") as f:
        json.dump({"rows": rows}, f, indent=1)
    return row


# Regression-gate policy (bench_check): a fresh row fails when its metric
# exceeds MAX_SLOWDOWN x the committed baseline, but only when the pair sits
# above the noise floor — sub-floor timings on small shared CI boxes are
# dominated by dispatch jitter, not by the kernels under test.
MAX_SLOWDOWN = 1.5
NOISE_FLOOR_S = 2e-4

# Iteration-count gate: total committed GP iterations are deterministic
# (no timing noise), so the budget is much tighter than the wall-clock one.
# A pair participates only when BOTH rows carry ``iters`` — rows that
# gained or lost the field between runs are schema drift, not a regression.
MAX_ITERS_REGRESSION = 1.2
_ITERS_NOISE_FLOOR = 8    # don't flag e.g. 5 -> 7 on trivially-small solves


def _row_key(row: dict) -> tuple:
    # rows written before the backend key existed were all CPU measurements
    return (row.get("bench"), row.get("scenario"), row.get("V"),
            row.get("solver"), row.get("backend", "cpu"))


def _pair_metrics(row: dict, ref: dict):
    """The SAME metric field read from both rows of a baseline/fresh pair:
    ``s_per_iter`` when both carry it, else ``seconds`` when both carry
    that — (None, None) when the schemas disagree, so a row that gained or
    lost ``iters`` between runs is skipped rather than compared
    apples-to-oranges."""
    for field in ("s_per_iter", "seconds"):
        if field in row and field in ref:
            return row[field], ref[field]
    return None, None


def load_rows(path: str) -> list[dict]:
    """Rows of a ``BENCH_gp.json``-shaped file ([] if missing/corrupt)."""
    if not os.path.exists(path):
        return []
    try:
        with open(path) as f:
            return json.load(f)["rows"]
    except (json.JSONDecodeError, KeyError, TypeError):
        return []


def bench_check(baseline_rows: list[dict], fresh_rows: list[dict] | None = None,
                *, max_slowdown: float = MAX_SLOWDOWN,
                noise_floor_s: float = NOISE_FLOOR_S,
                max_iters_regression: float = MAX_ITERS_REGRESSION
                ) -> list[str]:
    """Diff freshly generated bench rows against a committed baseline.

    Rows pair up by the ``bench_record`` key (bench, scenario, V, solver);
    fresh rows with no committed counterpart (new measurements) and
    baseline rows not regenerated this run are both ignored.  Two gates run
    per pair:

      * **time** — fails when the fresh metric (``s_per_iter`` when both
        rows carry it, else ``seconds`` — always the same field on both
        sides, see :func:`_pair_metrics`) exceeds ``max_slowdown`` x
        max(baseline metric, noise floor) AND the fresh metric itself sits
        above the noise floor;
      * **iters** — when both rows carry ``iters``, fails when the fresh
        total iteration count exceeds ``max_iters_regression`` x the
        committed one (iteration counts are deterministic, so the budget is
        tight; counts at or below ``_ITERS_NOISE_FLOOR`` are exempt).

    Returns human-readable failure lines (empty = gate passes) — the CI
    ``bench-smoke`` job runs this via ``python -m benchmarks.common
    --check <committed-baseline>`` after ``kernel_bench --smoke``
    regenerates the kernel rows.
    """
    if fresh_rows is None:
        fresh_rows = load_rows(BENCH_PATH)
    base = {_row_key(r): r for r in baseline_rows}
    failures = []
    for row in fresh_rows:
        ref = base.get(_row_key(row))
        if ref is None:
            continue
        key = "/".join(str(k) for k in _row_key(row))
        m_new, m_old = _pair_metrics(row, ref)
        if m_new is not None and m_old is not None and m_new > noise_floor_s:
            limit = max_slowdown * max(float(m_old), noise_floor_s)
            if float(m_new) > limit:
                failures.append(
                    f"{key}: {float(m_new):.6f}s vs committed "
                    f"{float(m_old):.6f}s (> {max_slowdown:.2f}x)")
        if "iters" in row and "iters" in ref:
            it_new, it_old = int(row["iters"]), int(ref["iters"])
            if (it_new > _ITERS_NOISE_FLOOR
                    and it_new > max_iters_regression * max(it_old, 1)):
                failures.append(
                    f"{key}: {it_new} iters vs committed {it_old} "
                    f"(> {max_iters_regression:.2f}x)")
    return failures


def delta_table(baseline_rows: list[dict], fresh_rows: list[dict]
                ) -> list[str]:
    """One line per compared pair showing BOTH the time and iters deltas.

    Columns: row key, s_per_iter (or seconds) fresh/committed with the
    ratio, and — when both rows carry ``iters`` — the iteration counts with
    their ratio.  Purely informational (the pass/fail decision is
    :func:`bench_check`'s); ``--check`` prints it so a CI log shows where
    the time went even when the gate is green.
    """
    base = {_row_key(r): r for r in baseline_rows}
    lines = []
    for row in fresh_rows:
        ref = base.get(_row_key(row))
        if ref is None:
            continue
        key = "/".join(str(k) for k in _row_key(row))
        m_new, m_old = _pair_metrics(row, ref)
        if m_new is not None and m_old is not None:
            ratio = float(m_new) / max(float(m_old), 1e-12)
            time_col = f"{float(m_new):.6f}s/{float(m_old):.6f}s ({ratio:.2f}x)"
        else:
            time_col = "-"
        if "iters" in row and "iters" in ref:
            it_new, it_old = int(row["iters"]), int(ref["iters"])
            iters_col = f"{it_new}/{it_old} ({it_new / max(it_old, 1):.2f}x)"
        else:
            iters_col = "-"
        lines.append(f"{key}: time {time_col} | iters {iters_col}")
    return lines


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """The harness contract: ``name,us_per_call,derived`` CSV lines."""
    print(f"{name},{us_per_call:.1f},{derived}")


def save_json(rel_path: str, obj) -> str:
    path = os.path.join(RESULTS_DIR, rel_path)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)
    return path


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.seconds = time.time() - self.t0

    @property
    def us(self) -> float:
        return self.seconds * 1e6


def result_row(res) -> dict:
    """JSON-serializable summary of a ``gp.GPResult``.

    Histories are dense jnp device arrays; trim to the committed prefix and
    convert via numpy so json doesn't choke on them."""
    trimmed = res.trim()
    hist = np.asarray(trimmed.cost_history, dtype=float)
    return {
        "final_cost": trimmed.final_cost,
        "iterations": int(trimmed.iterations),
        "initial_cost": float(hist[0]),
        "cost_history": hist.tolist(),
    }


def speedup_report(serial_s: float, batched_s: float, n: int) -> str:
    return (f"serial:{serial_s:.2f}s|batched:{batched_s:.2f}s|"
            f"speedup:{serial_s / max(batched_s, 1e-9):.2f}x|n:{n}")


def _check_main(argv: list[str]) -> int:
    """``python -m benchmarks.common --check <baseline.json>`` — the CI gate."""
    import argparse

    ap = argparse.ArgumentParser(prog="benchmarks.common")
    ap.add_argument("--check", required=True,
                    help="committed BENCH_gp.json snapshot to diff against")
    ap.add_argument("--fresh", default=BENCH_PATH,
                    help="freshly generated rows (default: BENCH_gp.json)")
    ap.add_argument("--max-slowdown", type=float, default=MAX_SLOWDOWN)
    ap.add_argument("--max-iters-regression", type=float,
                    default=MAX_ITERS_REGRESSION)
    args = ap.parse_args(argv)
    baseline = load_rows(args.check)
    fresh = load_rows(args.fresh)
    if not baseline or not fresh:
        print(f"bench_check: nothing to compare "
              f"({len(baseline)} baseline rows, {len(fresh)} fresh rows)")
        return 0
    failures = bench_check(baseline, fresh, max_slowdown=args.max_slowdown,
                           max_iters_regression=args.max_iters_regression)
    table = delta_table(baseline, fresh)
    compared = len({_row_key(r) for r in fresh}
                   & {_row_key(r) for r in baseline})
    print(f"bench_check: {compared} compared row(s) "
          f"(fresh/committed, ratio):")
    for line in table:
        print(f"  {line}")
    if failures:
        print(f"bench_check: {len(failures)} regression(s):")
        for line in failures:
            print(f"  REGRESSION {line}")
        return 1
    print(f"bench_check: OK (time within {args.max_slowdown:.2f}x, iters "
          f"within {args.max_iters_regression:.2f}x of committed)")
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(_check_main(sys.argv[1:]))
