"""Shared benchmark utilities (timing, CSV output)."""

from __future__ import annotations

import json
import os
import time

RESULTS_DIR = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                           "results")


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """The harness contract: ``name,us_per_call,derived`` CSV lines."""
    print(f"{name},{us_per_call:.1f},{derived}")


def save_json(rel_path: str, obj) -> str:
    path = os.path.join(RESULTS_DIR, rel_path)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)
    return path


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.seconds = time.time() - self.t0

    @property
    def us(self) -> float:
        return self.seconds * 1e6
