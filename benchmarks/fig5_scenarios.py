"""Fig. 5: normalized total cost across the Table II network scenarios,
GP vs SPOC / LCOF / LPR-SC — GP *and* the iterative baselines run as
batched scenario families.

Paper claims to validate:
  * GP achieves the lowest cost in every scenario,
  * up to ~50% improvement over LPR-SC (the joint-optimization baseline),
  * the advantage is larger with queueing (congestion-aware) costs
    (SW-queue vs SW-linear).

Engine claims to validate (this repo's batched scenario engine):
  * batched family solves reproduce per-scenario serial costs — for GP and
    for the mask-restricted SPOC/LCOF baselines (``baselines.spoc_masks`` /
    ``lcof_masks`` threaded through ``scenarios.run_sweep``),
  * per-solver batched-vs-serial wall clock is measured honestly (both
    paths fully warmed) and recorded to BENCH_gp.json.  Note the two
    regimes: *homogeneous* families (seed ensembles, fig6/fig7 sweeps —
    identical member shapes) win 3-5x batched, while the *heterogeneous*
    Table II six pays envelope padding (V and A inflate to each group's
    max) and only LCOF's cheap restricted solves still come out ahead —
    exactly the padding trade-off DESIGN.md §9 / run_sweep's size-class
    grouping predicts.
"""

from __future__ import annotations

from benchmarks.common import (
    Timer, bench_record, emit, save_json, speedup_report,
)
from repro.core import baselines, network, scenarios

GP_ITERS = 250
ENSEMBLE_SEEDS = 32

# (solver label, masks_fn) for the three iterative solvers; masks_fn=None
# is unrestricted GP (baselines are direction-mask restrictions, §11).
SOLVERS = (("GP", None), *baselines.BASELINE_MASKS.items())


def run_fig5(iters: int = GP_ITERS) -> dict:
    """All Table II scenarios: GP, SPOC and LCOF each as one batched
    scenario family (per cost-kind/size-class group); LPR-SC stays serial
    (a single closed-form shortest-path evaluation per scenario)."""
    family = scenarios.expand("fig5")
    sweeps = {}
    seconds = {}
    for solver, masks_fn in SOLVERS:
        with Timer() as t:
            sweeps[solver] = scenarios.run_sweep(
                family, masks_fn=masks_fn, alpha=0.1, max_iters=iters)
        seconds[solver] = t.seconds
    table = {}
    for i, sc in enumerate(family):
        out = {solver: sweeps[solver].results[i].final_cost
               for solver, _ in SOLVERS}
        out["gp_iters"] = int(sweeps["GP"].results[i].iterations)
        out["LPR-SC"] = baselines.lpr_sc(sc.instance).final_cost
        worst = max(out[k] for k in ("GP", "SPOC", "LCOF", "LPR-SC"))
        out["normalized"] = {k: out[k] / worst for k in ("GP", "SPOC", "LCOF", "LPR-SC")}
        table[sc.label] = out
        emit(f"fig5_{sc.label}_GP", seconds["GP"] * 1e6 / len(family),
             "norm=" + "|".join(f"{k}:{v:.3f}" for k, v in out["normalized"].items()))
    return {"table": table, "batched_seconds": seconds,
            "gp_batches": sweeps["GP"].n_batches}


def run_baseline_speedup(iters: int = GP_ITERS) -> dict:
    """Batched-vs-serial wall clock for GP, SPOC and LCOF on the small
    Table II six (one padded batch per solver; the V=100 small-world pair
    is excluded so the serial reference stays minutes, not hours).

    Both paths solve exactly the same restricted problems — serial goes
    through ``run_sweep_serial(masks_fn=...)`` (apples-to-apples).  Rows
    land in BENCH_gp.json (bench="fig5", scenario="small6").  Speedups
    here can be < 1 for GP/SPOC: the six topologies pad to per-group
    (V, A) envelopes, so this row pairs with the homogeneous-ensemble row
    (speedup ~4x) as the two ends of the batching trade-off.
    """
    small = [sc for sc in scenarios.expand("fig5")
             if sc.label in scenarios.SMALL_TABLE_II]
    vmax = max(sc.instance.V for sc in small)
    kw = dict(alpha=0.1, max_iters=iters)
    out = {}
    for solver, masks_fn in SOLVERS:
        # warm both paths: steady-state solving, not XLA compilation.  The
        # serial warm-up must cover the FULL family — gp.solve jit-caches
        # per instance shape and the six topologies all differ, so warming
        # one member would leave five compiles inside the timed window.
        scenarios.run_sweep(small, masks_fn=masks_fn, **kw)
        scenarios.run_sweep_serial(small, masks_fn=masks_fn, **kw)
        batched = scenarios.run_sweep(small, masks_fn=masks_fn, **kw)
        serial = scenarios.run_sweep_serial(small, masks_fn=masks_fn, **kw)
        rel_errs = [
            abs(b.final_cost - s.final_cost) / max(abs(s.final_cost), 1e-9)
            for b, s in zip(batched.results, serial.results)
        ]
        speedup = serial.seconds / max(batched.seconds, 1e-9)
        out[solver] = {
            "batched_seconds": batched.seconds,
            "serial_seconds": serial.seconds,
            "speedup": speedup,
            "max_rel_cost_err": max(rel_errs),
        }
        bench_record("fig5", scenario="small6", V=vmax,
                     solver=f"{solver}-batched", seconds=batched.seconds,
                     iters=sum(int(r.iterations) for r in batched.results),
                     n=len(small), speedup=round(speedup, 3))
        bench_record("fig5", scenario="small6", V=vmax,
                     solver=f"{solver}-serial", seconds=serial.seconds,
                     iters=sum(int(r.iterations) for r in serial.results),
                     n=len(small))
        emit(f"fig5_{solver.lower()}_speedup", batched.seconds * 1e6,
             speedup_report(serial.seconds, batched.seconds, len(small)))
    return out


def run_sw_warmstart(iters: int = GP_ITERS) -> dict:
    """Incremental-rate warm start on the congested V=100 small-world pair.

    The fig5 table solves sw-linear / sw-queue cold at their target rate for
    ``iters`` iterations each — the dominant wall-clock of the whole driver.
    Here each member instead climbs a two-rung rate ladder (half rate, then
    target) with ``scenarios.run_sweep_chained`` threading phi between
    rungs, and we report the target-rate iteration/wall-clock cut vs the
    cold solve (both warmed; rows land in BENCH_gp.json).
    """
    out = {}
    kw = dict(alpha=0.1, max_iters=iters)
    for name in ("sw-linear", "sw-queue"):
        rate = scenarios.FIG5_RATE[name]
        rungs = [
            scenarios.Scenario(
                label=f"{name}@x{s:g}",
                instance=network.table_ii_instance(
                    name, seed=0, rate_scale=s * rate),
                meta={"table_ii": name, "rate_scale": s * rate})
            for s in (0.5, 1.0)
        ]
        # warm the (single) program shape, then time cold vs chained
        scenarios.run_sweep_serial(rungs[-1:], **kw)
        with Timer() as t:
            cold = scenarios.run_sweep_serial(rungs[-1:], **kw)
        t_cold = t.seconds
        with Timer() as t:
            warm = scenarios.run_sweep_chained(rungs, **kw)
        t_warm = t.seconds
        it_cold = int(cold.results[0].iterations)
        it_target = int(warm.results[-1].iterations)
        it_total = sum(int(r.iterations) for r in warm.results)
        rel = ((warm.results[-1].final_cost - cold.results[0].final_cost)
               / max(abs(cold.results[0].final_cost), 1e-9))
        out[name] = {
            "cold_seconds": t_cold, "chained_seconds": t_warm,
            "cold_iters": it_cold, "target_iters": it_target,
            "chained_iters_total": it_total,
            "rel_cost_delta": rel,       # negative: warm landed lower
        }
        bench_record("fig5", scenario=f"{name}-warmstart", V=100,
                     solver="GP-chained", seconds=t_warm, iters=it_total,
                     target_iters=it_target, cold_iters=it_cold)
        bench_record("fig5", scenario=f"{name}-warmstart", V=100,
                     solver="GP-cold", seconds=t_cold, iters=it_cold)
        emit(f"fig5_{name}_warmstart", t_warm * 1e6,
             f"target_iters:{it_target}|cold_iters:{it_cold}|"
             f"cold_s:{t_cold:.1f}|rel_cost_delta:{rel:+.2e}")
    return out


def run_ensemble_speedup(n_seeds: int = ENSEMBLE_SEEDS, iters: int = GP_ITERS) -> dict:
    """Batched-vs-serial wall clock on the seed-ensemble sweep (warm)."""
    kw = dict(alpha=0.1, max_iters=iters)
    skw = {"n_seeds": n_seeds}
    # warm both paths so the comparison measures steady-state solving, not
    # XLA compilation (the batched path compiles one program per compaction
    # bucket size, the serial path one chunk program)
    scenarios.run_sweep("seed-ensemble", sweep_kwargs=skw, **kw)
    scenarios.run_sweep_serial("seed-ensemble", sweep_kwargs={"n_seeds": 2}, **kw)

    batched = scenarios.run_sweep("seed-ensemble", sweep_kwargs=skw, **kw)
    serial = scenarios.run_sweep_serial("seed-ensemble", sweep_kwargs=skw, **kw)
    rel_errs = [
        abs(b.final_cost - s.final_cost) / max(s.final_cost, 1e-9)
        for b, s in zip(batched.results, serial.results)
    ]
    ens = {
        "n_seeds": n_seeds,
        "batched_seconds": batched.seconds,
        "serial_seconds": serial.seconds,
        "speedup": serial.seconds / max(batched.seconds, 1e-9),
        "max_rel_cost_err": max(rel_errs),
        "costs": [r.final_cost for r in batched.results],
    }
    bench_record("fig5", scenario=f"abilene-ensemble{n_seeds}", V=11,
                 solver="GP-batched", seconds=batched.seconds,
                 iters=sum(int(r.iterations) for r in batched.results),
                 n=n_seeds, speedup=round(ens["speedup"], 3))

    # the same family under the §15 acceleration layer (Anderson mixing +
    # adaptive stepsize + residual stopping): same final costs, fewer
    # committed iterations — the iters gate holds this row to the claim
    scenarios.run_sweep("seed-ensemble", sweep_kwargs=skw, accel=True, **kw)
    accel = scenarios.run_sweep("seed-ensemble", sweep_kwargs=skw,
                                accel=True, **kw)
    it_plain = sum(int(r.iterations) for r in batched.results)
    it_accel = sum(int(r.iterations) for r in accel.results)
    ens["accel"] = {
        "seconds": accel.seconds,
        "iters": it_accel, "plain_iters": it_plain,
        "iter_cut": 1 - it_accel / max(it_plain, 1),
        "max_rel_cost_delta": max(
            (a.final_cost - b.final_cost) / max(abs(b.final_cost), 1e-9)
            for a, b in zip(accel.results, batched.results)),
    }
    bench_record("fig5", scenario=f"abilene-ensemble{n_seeds}", V=11,
                 solver="GP-accel-batched", seconds=accel.seconds,
                 iters=it_accel, n=n_seeds, plain_iters=it_plain)
    emit("fig5_ensemble_accel", accel.seconds * 1e6,
         f"iters:{it_accel}|plain:{it_plain}|"
         f"iter_cut:{ens['accel']['iter_cut']:.0%}")
    return ens


def main() -> dict:
    fig5 = run_fig5()
    table = fig5["table"]
    # paper-claim checks (0.5% tolerance: linear-cost scenarios tie exactly
    # at the shortest-path optimum, which IS the global optimum there)
    ok_best = all(
        t["normalized"]["GP"] <= 1.005 * min(
            t["normalized"][k] for k in ("SPOC", "LCOF", "LPR-SC"))
        for t in table.values())
    gain_lpr = max(1 - t["normalized"]["GP"] / max(t["normalized"]["LPR-SC"], 1e-9)
                   for t in table.values())
    sw_gap_queue = 1 - table["sw-queue"]["normalized"]["GP"]
    sw_gap_linear = 1 - table["sw-linear"]["normalized"]["GP"]

    baseline_speedups = run_baseline_speedup()
    ensemble = run_ensemble_speedup()
    warmstart = run_sw_warmstart()
    summary = {
        "sw_warmstart": warmstart,
        "gp_best_everywhere": ok_best,
        "max_gain_vs_lpr_sc": gain_lpr,
        "sw_queue_gain": sw_gap_queue,
        "sw_linear_gain": sw_gap_linear,
        "queue_gain_exceeds_linear": sw_gap_queue >= sw_gap_linear,
        "baseline_speedups": baseline_speedups,
        "ensemble": ensemble,
    }
    save_json("fig5.json", {"table": table, "summary": summary})
    emit("fig5_summary", 0.0,
         f"gp_best={ok_best} max_gain_vs_LPR={gain_lpr:.2f} "
         f"queue>{sw_gap_linear:.2f}linear={summary['queue_gain_exceeds_linear']}")
    emit("fig5_ensemble_speedup", ensemble["batched_seconds"] * 1e6,
         speedup_report(ensemble["serial_seconds"], ensemble["batched_seconds"],
                        ensemble["n_seeds"]))
    return {"table": table, "summary": summary}


if __name__ == "__main__":
    main()
