"""Fig. 5: normalized total cost across the Table II network scenarios,
GP vs SPOC / LCOF / LPR-SC — GP runs as a batched scenario family.

Paper claims to validate:
  * GP achieves the lowest cost in every scenario,
  * up to ~50% improvement over LPR-SC (the joint-optimization baseline),
  * the advantage is larger with queueing (congestion-aware) costs
    (SW-queue vs SW-linear).

Engine claims to validate (this repo's batched scenario engine):
  * the batched family solve reproduces per-scenario serial costs,
  * on the ``seed-ensemble`` sweep, the batched engine beats solving the
    seeds one at a time (wall clock, warm).
"""

from __future__ import annotations

from benchmarks.common import Timer, emit, save_json, speedup_report
from repro.core import baselines, scenarios

GP_ITERS = 250
ENSEMBLE_SEEDS = 32


def run_fig5(iters: int = GP_ITERS) -> dict:
    """All Table II scenarios: GP batched via the scenario layer, baselines
    serial (they are restrictions with per-scenario direction masks)."""
    family = scenarios.expand("fig5")
    with Timer() as t:
        sweep = scenarios.run_sweep(family, alpha=0.1, max_iters=iters)
    table = {}
    for sc, res in zip(sweep.scenarios, sweep.results):
        out = {
            "GP": res.final_cost,
            "gp_iters": int(res.iterations),
            "SPOC": baselines.spoc(sc.instance, alpha=0.1, max_iters=iters).final_cost,
            "LCOF": baselines.lcof(sc.instance, alpha=0.1, max_iters=iters).final_cost,
            "LPR-SC": baselines.lpr_sc(sc.instance).final_cost,
        }
        worst = max(out[k] for k in ("GP", "SPOC", "LCOF", "LPR-SC"))
        out["normalized"] = {k: out[k] / worst for k in ("GP", "SPOC", "LCOF", "LPR-SC")}
        table[sc.label] = out
        emit(f"fig5_{sc.label}_GP", t.us / len(family),
             "norm=" + "|".join(f"{k}:{v:.3f}" for k, v in out["normalized"].items()))
    return {"table": table, "gp_batched_seconds": sweep.seconds,
            "gp_batches": sweep.n_batches}


def run_ensemble_speedup(n_seeds: int = ENSEMBLE_SEEDS, iters: int = GP_ITERS) -> dict:
    """Batched-vs-serial wall clock on the seed-ensemble sweep (warm)."""
    kw = dict(alpha=0.1, max_iters=iters)
    skw = {"n_seeds": n_seeds}
    # warm both paths so the comparison measures steady-state solving, not
    # XLA compilation (the batched path compiles one program per compaction
    # bucket size, the serial path one chunk program)
    scenarios.run_sweep("seed-ensemble", sweep_kwargs=skw, **kw)
    scenarios.run_sweep_serial("seed-ensemble", sweep_kwargs={"n_seeds": 2}, **kw)

    batched = scenarios.run_sweep("seed-ensemble", sweep_kwargs=skw, **kw)
    serial = scenarios.run_sweep_serial("seed-ensemble", sweep_kwargs=skw, **kw)
    rel_errs = [
        abs(b.final_cost - s.final_cost) / max(s.final_cost, 1e-9)
        for b, s in zip(batched.results, serial.results)
    ]
    return {
        "n_seeds": n_seeds,
        "batched_seconds": batched.seconds,
        "serial_seconds": serial.seconds,
        "speedup": serial.seconds / max(batched.seconds, 1e-9),
        "max_rel_cost_err": max(rel_errs),
        "costs": [r.final_cost for r in batched.results],
    }


def main() -> dict:
    fig5 = run_fig5()
    table = fig5["table"]
    # paper-claim checks (0.5% tolerance: linear-cost scenarios tie exactly
    # at the shortest-path optimum, which IS the global optimum there)
    ok_best = all(
        t["normalized"]["GP"] <= 1.005 * min(
            t["normalized"][k] for k in ("SPOC", "LCOF", "LPR-SC"))
        for t in table.values())
    gain_lpr = max(1 - t["normalized"]["GP"] / max(t["normalized"]["LPR-SC"], 1e-9)
                   for t in table.values())
    sw_gap_queue = 1 - table["sw-queue"]["normalized"]["GP"]
    sw_gap_linear = 1 - table["sw-linear"]["normalized"]["GP"]

    ensemble = run_ensemble_speedup()
    summary = {
        "gp_best_everywhere": ok_best,
        "max_gain_vs_lpr_sc": gain_lpr,
        "sw_queue_gain": sw_gap_queue,
        "sw_linear_gain": sw_gap_linear,
        "queue_gain_exceeds_linear": sw_gap_queue >= sw_gap_linear,
        "ensemble": ensemble,
    }
    save_json("fig5.json", {"table": table, "summary": summary})
    emit("fig5_summary", 0.0,
         f"gp_best={ok_best} max_gain_vs_LPR={gain_lpr:.2f} "
         f"queue>{sw_gap_linear:.2f}linear={summary['queue_gain_exceeds_linear']}")
    emit("fig5_ensemble_speedup", ensemble["batched_seconds"] * 1e6,
         speedup_report(ensemble["serial_seconds"], ensemble["batched_seconds"],
                        ensemble["n_seeds"]))
    return {"table": table, "summary": summary}


if __name__ == "__main__":
    main()
