"""Fig. 5: normalized total cost across the Table II network scenarios,
GP vs SPOC / LCOF / LPR-SC.

Paper claims to validate:
  * GP achieves the lowest cost in every scenario,
  * up to ~50% improvement over LPR-SC (the joint-optimization baseline),
  * the advantage is larger with queueing (congestion-aware) costs
    (SW-queue vs SW-linear).
"""

from __future__ import annotations

import time

from benchmarks.common import Timer, emit, save_json
from repro.core import baselines, gp, network

SCENARIOS = ["connected-er", "balanced-tree", "fog", "abilene", "lhc",
             "geant", "sw-linear", "sw-queue"]
# input-rate scaling per scenario so the networks operate in the congested
# regime the paper targets (its absolute rates depend on unpublished
# simulator units; the *relative* algorithm ordering is the claim)
RATE = {"connected-er": 2.0, "balanced-tree": 2.0, "fog": 3.5, "abilene": 2.0,
        "lhc": 2.0, "geant": 2.0, "sw-linear": 1.5, "sw-queue": 1.5}
# fog's capacities (Table II: s=17, d=20) leave it lightly loaded at 2x —
# every algorithm already sits at the uncongested optimum — so fog runs at
# 3.5x to reach the congested regime the paper's Fig. 5 depicts.


def run_scenario(name: str, seed: int = 0, iters: int = 250) -> dict:
    inst = network.table_ii_instance(name, seed=seed, rate_scale=RATE[name])
    out = {}
    with Timer() as t:
        res = gp.solve(inst, alpha=0.1, max_iters=iters)
    out["GP"] = res.final_cost
    out["gp_us"] = t.us
    out["gp_iters"] = res.iterations
    out["SPOC"] = baselines.spoc(inst, alpha=0.1, max_iters=iters).final_cost
    out["LCOF"] = baselines.lcof(inst, alpha=0.1, max_iters=iters).final_cost
    out["LPR-SC"] = baselines.lpr_sc(inst).final_cost
    worst = max(out[k] for k in ("GP", "SPOC", "LCOF", "LPR-SC"))
    out["normalized"] = {k: out[k] / worst for k in ("GP", "SPOC", "LCOF", "LPR-SC")}
    return out


def main() -> dict:
    table = {}
    for name in SCENARIOS:
        r = run_scenario(name)
        table[name] = r
        emit(f"fig5_{name}_GP", r["gp_us"],
             "norm=" + "|".join(f"{k}:{v:.3f}" for k, v in r["normalized"].items()))
    # paper-claim checks (0.5% tolerance: linear-cost scenarios tie exactly
    # at the shortest-path optimum, which IS the global optimum there)
    ok_best = all(
        t["normalized"]["GP"] <= 1.005 * min(
            t["normalized"][k] for k in ("SPOC", "LCOF", "LPR-SC"))
        for t in table.values())
    gain_lpr = max(1 - t["normalized"]["GP"] / max(t["normalized"]["LPR-SC"], 1e-9)
                   for t in table.values())
    sw_gap_queue = 1 - table["sw-queue"]["normalized"]["GP"]
    sw_gap_linear = 1 - table["sw-linear"]["normalized"]["GP"]
    summary = {
        "gp_best_everywhere": ok_best,
        "max_gain_vs_lpr_sc": gain_lpr,
        "sw_queue_gain": sw_gap_queue,
        "sw_linear_gain": sw_gap_linear,
        "queue_gain_exceeds_linear": sw_gap_queue >= sw_gap_linear,
    }
    save_json("fig5.json", {"table": table, "summary": summary})
    emit("fig5_summary", 0.0,
         f"gp_best={ok_best} max_gain_vs_LPR={gain_lpr:.2f} "
         f"queue>{sw_gap_linear:.2f}linear={summary['queue_gain_exceeds_linear']}")
    return {"table": table, "summary": summary}


if __name__ == "__main__":
    main()
