"""Fig. 6: total cost vs exogenous input rate (Abilene).

Paper claim: GP's advantage grows quickly as the network becomes more
congested (the congestion-oblivious baselines blow up first).

The whole rate sweep is one batched scenario family — six Abilene
instances differing only in ``rate_scale`` solved in a single vmapped
device program; the baselines stay serial (per-instance direction masks).
"""

from __future__ import annotations

from benchmarks.common import emit, result_row, save_json, speedup_report
from repro.core import baselines, scenarios

SCALES = scenarios.FIG6_SCALES


def main() -> dict:
    kw = dict(alpha=0.1, max_iters=300)
    cold = scenarios.run_sweep("fig6-congestion", **kw)       # compiles
    sweep = scenarios.run_sweep("fig6-congestion", **kw)      # warm timing
    serial = scenarios.run_sweep_serial("fig6-congestion", **kw)

    curve = {}
    for sc, res in zip(sweep.scenarios, sweep.results):
        s = sc.meta["rate_scale"]
        row = {
            "GP": res.final_cost,
            "SPOC": baselines.spoc(sc.instance, alpha=0.1, max_iters=200).final_cost,
            "LCOF": baselines.lcof(sc.instance, alpha=0.1, max_iters=200).final_cost,
            "LPR-SC": baselines.lpr_sc(sc.instance).final_cost,
            "gp": result_row(res),    # convergence history for the figure
        }
        curve[s] = row
        emit(f"fig6_rate{s}", sweep.seconds * 1e6 / len(SCALES),
             f"GP:{row['GP']:.2f}|SPOC:{row['SPOC']:.2f}|"
             f"LCOF:{row['LCOF']:.2f}|LPR:{row['LPR-SC']:.2f}")
    # claim: advantage ratio (best baseline / GP) grows with the rate
    ratios = [min(r["SPOC"], r["LCOF"], r["LPR-SC"]) / max(r["GP"], 1e-9)
              for r in curve.values()]
    grows = ratios[-1] > ratios[0]
    save_json("fig6.json", {"curve": curve, "advantage_ratios": ratios,
                            "advantage_grows_with_congestion": grows,
                            "gp_batched_seconds_warm": sweep.seconds,
                            "gp_batched_seconds_cold": cold.seconds,
                            "gp_serial_seconds": serial.seconds})
    emit("fig6_summary", 0.0,
         "ratios=" + "|".join(f"{r:.2f}" for r in ratios) + f" grows={grows}")
    emit("fig6_gp_speedup", sweep.seconds * 1e6,
         speedup_report(serial.seconds, sweep.seconds, len(SCALES)))
    return curve


if __name__ == "__main__":
    main()
