"""Fig. 6: total cost vs exogenous input rate (Abilene).

Paper claim: GP's advantage grows quickly as the network becomes more
congested (the congestion-oblivious baselines blow up first).

The whole rate sweep is one batched scenario family — six Abilene
instances differing only in ``rate_scale`` — and now the iterative
baselines batch too: SPOC and LCOF run the same six-member family through
``scenarios.run_sweep(masks_fn=...)`` (their direction masks are pure jax,
vmapped over the padded batch), with a serial reference per solver for the
batched-vs-serial speedup report.
"""

from __future__ import annotations

from benchmarks.common import (
    bench_record, emit, result_row, save_json, speedup_report,
)
from repro.core import baselines, scenarios

SCALES = scenarios.FIG6_SCALES

SOLVERS = (("GP", None), *baselines.BASELINE_MASKS.items())


def main() -> dict:
    kw = dict(alpha=0.1, max_iters=300)
    sweeps, serials = {}, {}
    for solver, masks_fn in SOLVERS:
        # warm BOTH paths before timing (all six members share one shape,
        # but each solver's mask signature compiles its own programs)
        scenarios.run_sweep("fig6-congestion", masks_fn=masks_fn, **kw)
        scenarios.run_sweep_serial("fig6-congestion", masks_fn=masks_fn, **kw)
        sweeps[solver] = scenarios.run_sweep(
            "fig6-congestion", masks_fn=masks_fn, **kw)
        serials[solver] = scenarios.run_sweep_serial(
            "fig6-congestion", masks_fn=masks_fn, **kw)

    sweep = sweeps["GP"]
    curve = {}
    for i, sc in enumerate(sweep.scenarios):
        s = sc.meta["rate_scale"]
        row = {
            "GP": sweep.results[i].final_cost,
            "SPOC": sweeps["SPOC"].results[i].final_cost,
            "LCOF": sweeps["LCOF"].results[i].final_cost,
            "LPR-SC": baselines.lpr_sc(sc.instance).final_cost,
            "gp": result_row(sweep.results[i]),  # convergence history
        }
        curve[s] = row
        emit(f"fig6_rate{s}", sweep.seconds * 1e6 / len(SCALES),
             f"GP:{row['GP']:.2f}|SPOC:{row['SPOC']:.2f}|"
             f"LCOF:{row['LCOF']:.2f}|LPR:{row['LPR-SC']:.2f}")
    # claim: advantage ratio (best baseline / GP) grows with the rate
    ratios = [min(r["SPOC"], r["LCOF"], r["LPR-SC"]) / max(r["GP"], 1e-9)
              for r in curve.values()]
    grows = ratios[-1] > ratios[0]

    # warm-start chaining: solve the rate ladder sequentially, rate r_k
    # starting from r_{k-1}'s converged phi (scenarios.run_sweep_chained) —
    # the incremental-rate shortcut the ROADMAP flagged.  Compare against
    # the (already warm) serial GP reference above.
    chained = scenarios.run_sweep_chained("fig6-congestion", **kw)
    it_cold = sum(int(r.iterations) for r in serials["GP"].results)
    it_warm = sum(int(r.iterations) for r in chained.results)
    warm_start = {
        "chained_seconds": chained.seconds,
        "serial_seconds": serials["GP"].seconds,
        "chained_iters": it_warm,
        "serial_iters": it_cold,
        "iter_cut": 1 - it_warm / max(it_cold, 1),
        # signed: negative means the warm-started member landed LOWER
        "max_rel_cost_delta": max(
            (w.final_cost - c.final_cost) / max(abs(c.final_cost), 1e-9)
            for w, c in zip(chained.results, serials["GP"].results)),
    }
    bench_record("fig6", scenario="abilene-rates", V=11, solver="GP-chained",
                 seconds=chained.seconds, iters=it_warm, n=len(SCALES),
                 iters_cold=it_cold)
    emit("fig6_gp_chained", chained.seconds * 1e6,
         f"iters:{it_warm}|cold:{it_cold}|"
         f"iter_cut:{warm_start['iter_cut']:.0%}|"
         f"serial_s:{serials['GP'].seconds:.2f}")

    # accelerated batched GP over the same rate ladder (§15 layer): the
    # committed row pairs with GP-batched for the iters-reduction claim
    scenarios.run_sweep("fig6-congestion", accel=True, **kw)
    accel = scenarios.run_sweep("fig6-congestion", accel=True, **kw)
    it_plain = sum(int(r.iterations) for r in sweeps["GP"].results)
    it_accel = sum(int(r.iterations) for r in accel.results)
    bench_record("fig6", scenario="abilene-rates", V=11,
                 solver="GP-accel-batched", seconds=accel.seconds,
                 iters=it_accel, n=len(SCALES), plain_iters=it_plain)
    emit("fig6_gp_accel", accel.seconds * 1e6,
         f"iters:{it_accel}|plain:{it_plain}|"
         f"iter_cut:{1 - it_accel / max(it_plain, 1):.0%}")

    speedups = {}
    for solver, _ in SOLVERS:
        bat, ser = sweeps[solver], serials[solver]
        rel = max(
            abs(b.final_cost - s.final_cost) / max(abs(s.final_cost), 1e-9)
            for b, s in zip(bat.results, ser.results))
        speedups[solver] = {
            "batched_seconds": bat.seconds, "serial_seconds": ser.seconds,
            "speedup": ser.seconds / max(bat.seconds, 1e-9),
            "max_rel_cost_err": rel,
        }
        bench_record("fig6", scenario="abilene-rates", V=11,
                     solver=f"{solver}-batched", seconds=bat.seconds,
                     iters=sum(int(r.iterations) for r in bat.results),
                     n=len(SCALES),
                     speedup=round(speedups[solver]["speedup"], 3))
        bench_record("fig6", scenario="abilene-rates", V=11,
                     solver=f"{solver}-serial", seconds=ser.seconds,
                     iters=sum(int(r.iterations) for r in ser.results),
                     n=len(SCALES))
        emit(f"fig6_{solver.lower()}_speedup", bat.seconds * 1e6,
             speedup_report(ser.seconds, bat.seconds, len(SCALES)))

    save_json("fig6.json", {"curve": curve, "advantage_ratios": ratios,
                            "advantage_grows_with_congestion": grows,
                            "solver_speedups": speedups,
                            "warm_start": warm_start,
                            "accel": {"iters": it_accel,
                                      "plain_iters": it_plain,
                                      "seconds": accel.seconds}})
    emit("fig6_summary", 0.0,
         "ratios=" + "|".join(f"{r:.2f}" for r in ratios) + f" grows={grows}")
    return curve


if __name__ == "__main__":
    main()
