"""Fig. 6: total cost vs exogenous input rate (Abilene).

Paper claim: GP's advantage grows quickly as the network becomes more
congested (the congestion-oblivious baselines blow up first).
"""

from __future__ import annotations

from benchmarks.common import Timer, emit, save_json
from repro.core import baselines, gp, network

SCALES = [0.5, 1.0, 1.5, 2.0, 2.5, 3.0]


def main() -> dict:
    curve = {}
    for s in SCALES:
        inst = network.table_ii_instance("abilene", seed=0, rate_scale=s)
        with Timer() as t:
            res = gp.solve(inst, alpha=0.1, max_iters=300)
        row = {
            "GP": res.final_cost,
            "SPOC": baselines.spoc(inst, alpha=0.1, max_iters=200).final_cost,
            "LCOF": baselines.lcof(inst, alpha=0.1, max_iters=200).final_cost,
            "LPR-SC": baselines.lpr_sc(inst).final_cost,
            "gp_us": t.us,
        }
        curve[s] = row
        emit(f"fig6_rate{s}", row["gp_us"],
             f"GP:{row['GP']:.2f}|SPOC:{row['SPOC']:.2f}|"
             f"LCOF:{row['LCOF']:.2f}|LPR:{row['LPR-SC']:.2f}")
    # claim: advantage ratio (best baseline / GP) grows with the rate
    ratios = [min(r["SPOC"], r["LCOF"], r["LPR-SC"]) / max(r["GP"], 1e-9)
              for r in curve.values()]
    grows = ratios[-1] > ratios[0]
    save_json("fig6.json", {"curve": curve, "advantage_ratios": ratios,
                            "advantage_grows_with_congestion": grows})
    emit("fig6_summary", 0.0,
         "ratios=" + "|".join(f"{r:.2f}" for r in ratios) + f" grows={grows}")
    return curve


if __name__ == "__main__":
    main()
