"""Fig. 7: average hop count of data vs result packets, as a function of
the input packet size L_(a,0).

Paper claim: when input packets are larger (relative to results), GP
offloads computation closer to the requester — data packets travel fewer
hops, result packets more.

The L0 sweep runs as one batched scenario family (``fig7-packetsize``).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import bench_record, emit, save_json, speedup_report
from repro.core import scenarios, traffic

L0_VALUES = scenarios.FIG7_L0


def hop_counts(inst, phi) -> tuple[float, float]:
    """Average hops traveled by a data packet (stage 0) and a result packet
    (final stage), flow-weighted: total link crossings / packets injected."""
    fl = traffic.flows(inst, phi)
    f = np.asarray(fl.f)                 # (A,K1,V,V)
    r_tot = float(np.asarray(inst.r).sum())
    data_hops = f[:, 0].sum() / max(r_tot, 1e-9)
    last = np.asarray(inst.n_tasks)
    res_hops = sum(f[a, int(last[a])].sum() for a in range(inst.A)) / max(r_tot, 1e-9)
    return float(data_hops), float(res_hops)


def main() -> dict:
    kw = dict(alpha=0.1, max_iters=300)
    cold = scenarios.run_sweep("fig7-packetsize", **kw)       # compiles
    scenarios.run_sweep_serial("fig7-packetsize", **kw)       # warm serial too
    sweep = scenarios.run_sweep("fig7-packetsize", **kw)      # warm timing
    serial = scenarios.run_sweep_serial("fig7-packetsize", **kw)

    out = {}
    for sc, res in zip(sweep.scenarios, sweep.results):
        L0 = sc.meta["L0"]
        dh, rh = hop_counts(sc.instance, res.phi)
        out[L0] = {"data_hops": dh, "result_hops": rh, "cost": res.final_cost}
        emit(f"fig7_L0_{L0}", 0.0, f"data_hops:{dh:.2f}|result_hops:{rh:.2f}")
    # claim: data hop count decreases as L0 grows (offload near requester)
    dhs = [out[L]["data_hops"] for L in L0_VALUES]
    monotone_trend = dhs[-1] < dhs[0]
    save_json("fig7.json", {"curve": out, "data_hops_shrink": monotone_trend,
                            "gp_batched_seconds_warm": sweep.seconds,
                            "gp_batched_seconds_cold": cold.seconds,
                            "gp_serial_seconds": serial.seconds})
    emit("fig7_summary", 0.0,
         "data_hops=" + "|".join(f"{d:.2f}" for d in dhs) + f" shrink={monotone_trend}")
    emit("fig7_gp_speedup", sweep.seconds * 1e6,
         speedup_report(serial.seconds, sweep.seconds, len(L0_VALUES)))
    for solver, sw, it in (("GP-batched", sweep, sweep.results),
                           ("GP-serial", serial, serial.results)):
        bench_record("fig7", scenario="abilene-L0", V=11, solver=solver,
                     seconds=sw.seconds, n=len(L0_VALUES),
                     iters=sum(int(r.iterations) for r in it))
    return out


if __name__ == "__main__":
    main()
