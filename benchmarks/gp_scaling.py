"""GP algorithm scaling: per-iteration wall time vs network/application
count (complexity table of Section IV), the shard_map variant, and the
batched scenario engine's per-member iteration cost vs batch size."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Timer, bench_record, emit, save_json, speedup_report
from repro.core import batch, compat, distributed, gp, network, scenarios


def time_gp_iteration(inst, reps: int = 5, solver: str = "auto") -> float:
    phi = gp.init_phi(inst)
    state = gp._jit_step(inst, phi, 0.05, None, None, False, solver)  # warm
    with Timer() as t:
        for _ in range(reps):
            state = gp._jit_step(inst, state.phi, 0.05, None, None, False,
                                 solver)
        jax.block_until_ready(state.phi.e)
    return t.us / reps


def time_batched_iteration(name: str, B: int, chunk: int = 32) -> float:
    """us per iteration per member of the device-resident batched scan."""
    insts = [network.table_ii_instance(name, seed=s, rate_scale=2.0)
             for s in range(B)]
    binst = batch.pad_instances(insts)
    phi = jax.vmap(gp.init_phi)(binst)
    carry = jax.vmap(gp._init_carry)(binst, phi)
    args = (jnp.float32(0.05), jnp.float32(1e-4), jnp.int32(10**6),
            jnp.int32(10**6), None, None)
    carry, _ = gp._scan_chunk_batched(binst, carry, *args, length=chunk)  # warm
    jax.block_until_ready(carry.cost)
    with Timer() as t:
        carry, _ = gp._scan_chunk_batched(binst, carry, *args, length=chunk)
        jax.block_until_ready(carry.cost)
    return t.us / chunk / B


# Metro-scale leg (DESIGN.md §18): per-iteration cost of the sparse
# neighbor-list solve path vs the dense batched-LU path on O(V)-edge metro
# graphs.  Above _DENSE_MAX_V the dense path is not measured — a V=1000
# dense iteration factors (ladder * A * K1) 1000^3 LUs per step, which this
# box cannot complete in a sane budget — and is recorded as an explicit
# status="timeout" row at the budget wall clock instead (the honest
# "dense is not viable here" data point the scale claim rests on).
_METRO_VS = (300, 600, 1000)
_DENSE_MAX_V = 600
_DENSE_BUDGET_S = 600.0


def metro_leg(rows: dict, *, smoke: bool = False) -> None:
    """Sparse-vs-dense per-iteration rows on metro-scale graphs.

    ``smoke`` runs only the V=60 small-world point (the CI sparse bench
    row); the full leg covers V in ``_METRO_VS`` on both metro builders.
    The V=60 point is always included so the committed baseline carries a
    pair for the CI smoke run to gate against.
    """
    specs = [("sw", 60)]
    if not smoke:
        specs += [(t, v) for t in ("sw", "geant") for v in _METRO_VS]
    out = {}
    for topo, V in specs:
        inst = network.metro_instance(topo, V)
        E = network.n_edges(inst)
        reps = 3 if V <= 300 else 1
        us_sparse = time_gp_iteration(inst, reps=reps, solver="sparse")
        row = {"V": V, "edges": E, "sparse_us": us_sparse}
        extra = {}
        if V <= _DENSE_MAX_V:
            us_dense = time_gp_iteration(network.without_sparse(inst),
                                         reps=reps, solver="batched_lu")
            row["dense_us"] = us_dense
            row["speedup"] = us_dense / max(us_sparse, 1e-9)
            extra["speedup"] = round(row["speedup"], 3)
            bench_record("gp_scaling", scenario=f"metro-{topo}", V=V,
                         solver="batched_lu", seconds=us_dense / 1e6,
                         iters=1)
        else:
            row["dense_us"] = None
            bench_record("gp_scaling", scenario=f"metro-{topo}", V=V,
                         solver="batched_lu", seconds=_DENSE_BUDGET_S,
                         status="timeout")
        bench_record("gp_scaling", scenario=f"metro-{topo}", V=V,
                     solver="sparse", seconds=us_sparse / 1e6, iters=1,
                     edges=E, **extra)
        dense_str = ("timeout" if row["dense_us"] is None
                     else f"{row['dense_us']:.0f}us")
        emit(f"gp_metro_{topo}_V{V}", us_sparse, f"E:{E}|dense:{dense_str}")
        out[f"{topo}-{V}"] = row
    rows["metro"] = out


def main(argv=()):
    # argv defaults to () — NOT sys.argv — because benchmarks/run.py calls
    # mod.main() programmatically with run.py's own flags still on sys.argv.
    import argparse

    ap = argparse.ArgumentParser(prog="benchmarks.gp_scaling")
    ap.add_argument("--sparse", action="store_true",
                    help="run only the metro-scale sparse-vs-dense leg")
    ap.add_argument("--smoke", action="store_true",
                    help="with --sparse: only the V=60 CI smoke point")
    args = ap.parse_args(list(argv))
    if args.sparse:
        rows = {}
        metro_leg(rows, smoke=args.smoke)
        save_json("gp_metro.json", rows)
        return

    rows = {}
    for name in ["abilene", "balanced-tree", "fog", "geant", "sw-queue"]:
        inst = network.table_ii_instance(name, seed=0)
        us = time_gp_iteration(inst)
        rows[name] = {"V": inst.V, "A": inst.A, "S": inst.A * inst.K1,
                      "us_per_iter": us}
        emit(f"gp_iter_{name}", us, f"V:{inst.V}|stages:{inst.A * inst.K1}")

    # stage-solver comparison: the batched-LU kernel path (shared
    # factorization, kernels/batched_solve.py) vs the seed's per-stage
    # dense solves, across node counts.  "auto" picks dense below
    # traffic.AUTO_MIN_V on CPU — these rows are where that threshold
    # comes from (DESIGN.md §12).
    solver_rows = {}
    for name in ("connected-er", "geant", "sw-queue"):
        inst = network.table_ii_instance(name, seed=0, rate_scale=1.5)
        us_dense = time_gp_iteration(inst, reps=3, solver="dense")
        us_lu = time_gp_iteration(inst, reps=3, solver="batched_lu")
        solver_rows[name] = {"V": inst.V, "dense_us": us_dense,
                             "batched_lu_us": us_lu,
                             "speedup": us_dense / max(us_lu, 1e-9)}
        emit(f"gp_iter_solver_{name}", us_lu,
             f"V:{inst.V}|dense:{us_dense:.0f}us|"
             f"speedup:{us_dense / max(us_lu, 1e-9):.2f}x")
        bench_record("gp_scaling", scenario=name, V=inst.V,
                     solver="batched_lu", seconds=us_lu / 1e6, iters=1,
                     speedup=round(us_dense / max(us_lu, 1e-9), 3))
        bench_record("gp_scaling", scenario=name, V=inst.V,
                     solver="dense", seconds=us_dense / 1e6, iters=1)
    rows["stage_solver"] = solver_rows

    # batched engine: per-member iteration cost vs batch size (the
    # vectorization win the scenario layer exploits)
    batched = {}
    for B in (1, 8, 32):
        us = time_batched_iteration("abilene", B)
        batched[B] = us
        emit(f"gp_batched_iter_B{B}", us, f"us_per_iter_per_member|V:11")
    rows["batched_abilene"] = {str(b): u for b, u in batched.items()}

    # end-to-end ensemble: batched engine vs one-at-a-time (warm)
    kw = dict(alpha=0.1, max_iters=200)
    skw = {"n_seeds": 16}
    scenarios.run_sweep("seed-ensemble", sweep_kwargs=skw, **kw)          # warm
    scenarios.run_sweep_serial("seed-ensemble", sweep_kwargs={"n_seeds": 2}, **kw)
    bat = scenarios.run_sweep("seed-ensemble", sweep_kwargs=skw, **kw)
    ser = scenarios.run_sweep_serial("seed-ensemble", sweep_kwargs=skw, **kw)
    emit("gp_ensemble16_speedup", bat.seconds * 1e6,
         speedup_report(ser.seconds, bat.seconds, 16))
    rows["ensemble16"] = {"batched_s": bat.seconds, "serial_s": ser.seconds,
                          "speedup": ser.seconds / max(bat.seconds, 1e-9)}

    # shard_map distributed GP on the unified step engine (however many host
    # devices are present; the collective pattern is what the multi-device
    # CI job exercises).  Warm once so the row measures solving, not XLA.
    inst = network.table_ii_instance("abilene", seed=0)
    n_sh = min(len(jax.devices()), 2)
    mesh = compat.make_mesh((n_sh,), ("stage",))
    skw_sh = dict(alpha=0.05, max_iters=30, patience=10**6, tol=0.0)
    distributed.solve_sharded(inst, mesh, **skw_sh)                    # warm
    with Timer() as t:
        res = distributed.solve_sharded(inst, mesh, **skw_sh)
    emit("gp_sharded_30iters", t.us,
         f"shards:{n_sh}|final_cost:{float(res.cost_history[-1]):.3f}")
    bench_record("gp_scaling", scenario="abilene-sharded", V=inst.V,
                 solver=f"sharded-fused{n_sh}", seconds=t.seconds,
                 iters=int(res.iterations))
    rows["sharded"] = {"shards": n_sh, "seconds": t.seconds,
                       "iters": int(res.iterations)}

    # mesh-composed ensemble: the batch axis vmapped INSIDE each app shard
    # (scenarios.run_sweep(mesh=...), vmap-of-shard_map — DESIGN.md §14)
    skw8 = {"n_seeds": 8}
    scenarios.run_sweep("seed-ensemble", sweep_kwargs=skw8, mesh=mesh, **kw)  # warm
    msweep = scenarios.run_sweep("seed-ensemble", sweep_kwargs=skw8,
                                 mesh=mesh, **kw)
    m_iters = sum(int(r.iterations) for r in msweep.results)
    emit("gp_ensemble8_mesh", msweep.seconds * 1e6,
         f"shards:{n_sh}|iters:{m_iters}|n:8")
    bench_record("gp_scaling", scenario="ensemble8-mesh", V=11,
                 solver=f"sharded-vmap{n_sh}", seconds=msweep.seconds,
                 iters=m_iters, n=8)
    rows["ensemble8_mesh"] = {"shards": n_sh, "seconds": msweep.seconds,
                              "iters": m_iters}
    save_json("gp_scaling.json", rows)


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
