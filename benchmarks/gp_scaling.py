"""GP algorithm scaling: per-iteration wall time vs network/application
count (complexity table of Section IV), plus the shard_map variant."""

from __future__ import annotations

import jax

from benchmarks.common import Timer, emit, save_json
from repro.core import distributed, gp, network


def time_gp_iteration(inst, reps: int = 5) -> float:
    phi = gp.init_phi(inst)
    state = gp._jit_step(inst, phi, 0.05, None, None)   # warm compile
    with Timer() as t:
        for _ in range(reps):
            state = gp._jit_step(inst, state.phi, 0.05, None, None)
        jax.block_until_ready(state.phi.e)
    return t.us / reps


def main():
    rows = {}
    for name in ["abilene", "balanced-tree", "fog", "geant", "sw-queue"]:
        inst = network.table_ii_instance(name, seed=0)
        us = time_gp_iteration(inst)
        rows[name] = {"V": inst.V, "A": inst.A, "S": inst.A * inst.K1,
                      "us_per_iter": us}
        emit(f"gp_iter_{name}", us, f"V:{inst.V}|stages:{inst.A * inst.K1}")

    # shard_map distributed GP (1 host device here; the collective pattern
    # is what the multi-device dry-run exercises)
    inst = network.table_ii_instance("abilene", seed=0)
    mesh = jax.make_mesh((1,), ("stage",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    with Timer() as t:
        res = distributed.solve_sharded(inst, mesh, alpha=0.05, max_iters=30)
    emit("gp_sharded_30iters", t.us, f"final_cost:{res.cost_history[-1]:.3f}")
    save_json("gp_scaling.json", rows)


if __name__ == "__main__":
    main()
