"""Kernel microbenchmarks (interpret mode on CPU; structural numbers —
real-TPU wall times come from the roofline, not from this host)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Timer, emit
from repro.kernels import ops, ref


def _time(fn, *args, reps=3):
    fn(*args)                        # compile/warm
    with Timer() as t:
        for _ in range(reps):
            jax.block_until_ready(fn(*args))
    return t.us / reps


def main():
    # flash attention: kernel (interpret) vs jnp oracle
    B, S, H, KV, hd = 1, 512, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    t_kern = _time(lambda: ops.flash_attention(q, k, v, causal=True))
    tq = lambda x: x.transpose(0, 2, 1, 3)
    rf = jax.jit(lambda q, k, v: ref.flash_attention(q, k, v, causal=True))
    t_ref = _time(lambda: rf(tq(q), tq(k), tq(v)))
    emit("kernel_flash_attention_interp", t_kern, f"jnp_ref:{t_ref:.0f}us")

    # chain propagate: kernel vs jnp on the SW-scale problem (90 stages, 128 nodes)
    Sg, V = 90, 128
    M = jax.random.uniform(jax.random.PRNGKey(1), (Sg, V, V)) * 0.05
    src = jax.random.uniform(jax.random.PRNGKey(2), (Sg, V))
    t0 = jnp.zeros((Sg, V))
    t_kern = _time(lambda: ops.propagate_step(t0, M, src))
    rp = jax.jit(ref.propagate_step)
    t_ref = _time(lambda: rp(t0, M, src))
    emit("kernel_chain_propagate_interp", t_kern, f"jnp_ref:{t_ref:.0f}us")

    # ssd chunk
    Bz, nc, Q, Hh, P, N = 1, 4, 128, 4, 64, 64
    xs = jax.random.split(jax.random.PRNGKey(3), 4)
    xh = jax.random.normal(xs[0], (Bz, nc, Q, Hh, P))
    dt = jax.nn.softplus(jax.random.normal(xs[1], (Bz, nc, Q, Hh)))
    A = -jnp.exp(0.2 * jax.random.normal(xs[2], (Hh,)))
    cum = jnp.cumsum(dt * A[None, None, None], axis=2)
    BH = 0.3 * jax.random.normal(xs[3], (Bz, nc, Q, Hh, N))
    CH = 0.3 * jax.random.normal(jax.random.PRNGKey(9), (Bz, nc, Q, Hh, N))
    t_kern = _time(lambda: ops.ssd_chunk(xh, dt, None, cum, BH, CH))
    rs = jax.jit(ref.ssd_chunk)
    t_ref = _time(lambda: rs(xh, dt, cum, BH, CH))
    emit("kernel_ssd_chunk_interp", t_kern, f"jnp_ref:{t_ref:.0f}us")


if __name__ == "__main__":
    main()
