"""Kernel microbenchmarks (interpret mode on CPU; structural numbers —
real-TPU wall times come from the roofline, not from this host).

``--smoke`` runs only the GP hot paths (blocked-sets + batched-LU kernels
plus the sharded-vs-single step-engine parity) at V=20 and records rows to
BENCH_gp.json — the CI ``bench-smoke`` job, gated afterwards by
``benchmarks.common --check`` against the committed rows.
"""

from __future__ import annotations

import sys

import jax
import jax.numpy as jnp

from benchmarks.common import Timer, bench_record, emit
from repro.kernels import ops, ref


def _stage_systems(V: int):
    """Per-stage systems (I - Phi_k) and injections for the batched-LU
    bench: real fig5-family matrices where Table II has a member at that
    node count (connected-er V=20, sw-queue V=100), synthetic
    substochastic fill-ins otherwise (V=50)."""
    from repro.core import gp, network, scenarios

    by_v = {20: "connected-er", 100: "sw-queue"}
    if V in by_v:
        name = by_v[V]
        inst = network.table_ii_instance(
            name, seed=0, rate_scale=scenarios.FIG5_RATE[name])
        phi = gp.init_phi(inst)
        A, K1 = inst.A, inst.K1
        mats = (jnp.eye(V) - phi.e).reshape(A * K1, V, V)
        rhs = jnp.broadcast_to(inst.r[:, None, :], (A, K1, V)).reshape(A * K1, V)
        return mats, rhs, f"fig5:{name}"
    B = 90   # match sw-queue's A*K1 stage count
    P = jax.random.uniform(jax.random.PRNGKey(V), (B, V, V))
    P = 0.5 * P / jnp.sum(P, axis=-1, keepdims=True)
    rhs = jax.random.uniform(jax.random.PRNGKey(V + 1), (B, V))
    return jnp.eye(V) - P, rhs, "synthetic"


def _blocked_inputs(V: int):
    """route/improper matrices from a congested mid-solve GP iterate (where
    improper links actually appear), plus the instance label."""
    from repro.core import gp, marginals, network, scenarios

    by_v = {20: "connected-er", 100: "sw-queue"}
    name = by_v.get(V, "connected-er")
    inst = network.table_ii_instance(
        name, seed=0, rate_scale=scenarios.FIG5_RATE[name])
    res = gp.solve(inst, alpha=0.1, max_iters=8, patience=10**6, tol=0.0)
    m = marginals.marginals(inst, res.phi)
    route = res.phi.e > 0.0
    worse = m.pdt[:, :, None, :] > m.pdt[:, :, :, None] + gp._BLOCK_EPS
    return route, route & worse, name


def bench_blocked_sets(sizes=(20, 100)):
    """Bit-packed blocked-set kernel vs the seed's dense V-sweep scan.

    Both paths compute the identical tagged-node fixed point (parity is
    asserted, and tested in tests/test_blocked_sets.py); the bitset path
    packs successors into uint32 words and early-exits at the routing-DAG
    diameter (kernels/blocked_sets.py, DESIGN.md §13).
    """
    from repro.kernels import blocked_sets as bset

    for V in sizes:
        route, improper, src = _blocked_inputs(V)
        f_bit = ops.blocked_tagged          # already jitted at definition
        f_dense = jax.jit(bset.tagged_scan_dense)
        t_bit = _time_med(lambda: f_bit(route, improper))
        t_dense = _time_med(lambda: f_dense(route, improper))
        exact = bool(jnp.all(f_bit(route, improper) == f_dense(route, improper)))
        assert exact, f"bitset kernel diverged from dense scan at V={V}"
        speedup = t_dense / max(t_bit, 1e-9)
        emit(f"blocked_sets_V{V}", t_bit,
             f"fig5:{src}|dense_scan:{t_dense:.0f}us|"
             f"speedup:{speedup:.2f}x|exact:{exact}")
        bench_record("kernel_bench", scenario=f"blocked_sets:{src}", V=V,
                     solver="bitset", seconds=t_bit / 1e6,
                     speedup=round(speedup, 3))
        bench_record("kernel_bench", scenario=f"blocked_sets:{src}", V=V,
                     solver="dense-scan", seconds=t_dense / 1e6)


def bench_batched_solve():
    """Batched (B,V,V) factor+solve vs the looped per-stage LAPACK baseline.

    The baseline is one ``jnp.linalg.solve`` *dispatch* per stage system —
    the pre-batching access pattern the ROADMAP flags ("looped LAPACK on
    CPU ... serializes what is structurally one batched V x V solve").
    The derived field also reports the jit-unrolled variant (all B solves
    as separate HLOs inside one program) for reference.
    """
    bench_batched_solve_sizes((20, 50, 100))


def bench_batched_solve_sizes(sizes):
    for V in sizes:
        mats, rhs, src = _stage_systems(V)
        B = mats.shape[0]

        t_bat = _time_med(lambda: ops.batched_solve(mats, rhs)[0])

        solve1 = jax.jit(lambda m, b: jnp.linalg.solve(m, b))

        def eager_loop():
            return [solve1(mats[i], rhs[i]) for i in range(B)]

        t_loop = _time_med(eager_loop)

        @jax.jit
        def unrolled(mats, rhs):
            return jnp.stack([
                jnp.linalg.solve(mats[i], rhs[i]) for i in range(B)])

        t_unroll = _time_med(lambda: unrolled(mats, rhs))
        x_bat, _ = ops.batched_solve(mats, rhs)
        err = float(jnp.max(jnp.abs(x_bat - unrolled(mats, rhs))))
        emit(f"batched_lu_V{V}", t_bat,
             f"B:{B}|{src}|looped_lapack:{t_loop:.0f}us|"
             f"speedup:{t_loop / max(t_bat, 1e-9):.2f}x|"
             f"jit_unrolled:{t_unroll:.0f}us|max_err:{err:.2e}")
        bench_record("kernel_bench", scenario=f"batched_lu:{src}", V=V,
                     solver="batched_lu", seconds=t_bat / 1e6,
                     speedup=round(t_loop / max(t_bat, 1e-9), 3))
        bench_record("kernel_bench", scenario=f"batched_lu:{src}", V=V,
                     solver="looped-lapack", seconds=t_loop / 1e6)


def bench_sharded_parity(V: int = 20, iters: int = 12):
    """Sharded chunked solve (unified step engine under shard_map) vs
    ``gp.solve`` on one fig5 member: wall time plus the ≤1e-4 cost-history
    parity the engine contract promises (DESIGN.md §14).  Runs on however
    many host devices are available (CI's distributed job forces 4 CPU
    devices; a plain run exercises the 1-shard collective pattern)."""
    import numpy as np

    from repro.core import compat, distributed, gp, network, scenarios

    by_v = {20: "connected-er", 100: "sw-queue"}
    name = by_v[V]
    inst = network.table_ii_instance(
        name, seed=0, rate_scale=scenarios.FIG5_RATE[name])
    phi0 = gp.init_phi(inst)
    kw = dict(alpha=0.1, max_iters=iters, patience=10**6, tol=0.0)
    n = min(len(jax.devices()), 2)
    mesh = compat.make_mesh((n,), ("stage",))
    gp.solve(inst, phi0, **kw)                                  # warm
    distributed.solve_sharded(inst, mesh, phi0=phi0, **kw)      # warm
    with Timer() as t:
        ref = gp.solve(inst, phi0, **kw)
    t_single = t.us
    with Timer() as t:
        res = distributed.solve_sharded(inst, mesh, phi0=phi0, **kw)
    t_shard = t.us
    a = np.asarray(ref.cost_history, dtype=np.float64)
    b = np.asarray(res.cost_history, dtype=np.float64)
    dev = float(np.max(np.abs(a - b) / np.maximum(np.abs(a), 1e-9)))
    assert dev <= 1e-4, f"sharded GP diverged from gp.solve: {dev:.2e}"
    emit(f"gp_sharded_V{V}", t_shard,
         f"fig5:{name}|shards:{n}|single:{t_single:.0f}us|"
         f"cost_dev:{dev:.2e}")
    bench_record("kernel_bench", scenario=f"sharded_step:{name}", V=V,
                 solver=f"sharded{n}", seconds=t_shard / 1e6, iters=iters,
                 cost_dev=dev)
    bench_record("kernel_bench", scenario=f"sharded_step:{name}", V=V,
                 solver="single", seconds=t_single / 1e6, iters=iters)


def bench_gp_solver_parity():
    """End-to-end GP on a fig5 member: batched-LU stage solver vs the seed
    dense path — wall time and final-cost parity (acceptance: <= 1e-5)."""
    from repro.core import gp, network, scenarios

    inst = network.table_ii_instance(
        "sw-queue", seed=0, rate_scale=scenarios.FIG5_RATE["sw-queue"])
    kw = dict(alpha=0.1, max_iters=30, patience=10**6, tol=0.0)
    gp.solve(inst, solver="batched_lu", **kw)              # warm compile
    with Timer() as t:
        r_lu = gp.solve(inst, solver="batched_lu", **kw)
    t_lu = t.us
    gp.solve(inst, solver="dense", **kw)                   # warm compile
    with Timer() as t:
        r_dense = gp.solve(inst, solver="dense", **kw)
    t_dense = t.us
    rel = abs(r_lu.final_cost - r_dense.final_cost) / abs(r_dense.final_cost)
    emit("gp_sw_queue_30it_batched_lu", t_lu,
         f"dense:{t_dense:.0f}us|speedup:{t_dense / max(t_lu, 1e-9):.2f}x|"
         f"cost_rel_diff:{rel:.2e}")


def _time(fn, *args, reps=3):
    fn(*args)                        # compile/warm
    with Timer() as t:
        for _ in range(reps):
            jax.block_until_ready(fn(*args))
    return t.us / reps


def _time_med(fn, reps=11):
    """Median single-call time — robust to the multi-x outliers (GC, page
    faults) that skew short-call means on small shared CPUs."""
    jax.block_until_ready(fn())      # compile/warm
    ts = []
    for _ in range(reps):
        with Timer() as t:
            jax.block_until_ready(fn())
        ts.append(t.us)
    return sorted(ts)[len(ts) // 2]


def smoke():
    """CI bench-smoke: GP-hot-path kernels only, V=20, interpret-safe."""
    bench_blocked_sets(sizes=(20,))
    bench_batched_solve_sizes((20,))
    bench_sharded_parity(V=20)


def main():
    # flash attention: kernel (interpret) vs jnp oracle
    B, S, H, KV, hd = 1, 512, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    t_kern = _time(lambda: ops.flash_attention(q, k, v, causal=True))
    tq = lambda x: x.transpose(0, 2, 1, 3)
    rf = jax.jit(lambda q, k, v: ref.flash_attention(q, k, v, causal=True))
    t_ref = _time(lambda: rf(tq(q), tq(k), tq(v)))
    emit("kernel_flash_attention_interp", t_kern, f"jnp_ref:{t_ref:.0f}us")

    # chain propagate: kernel vs jnp on the SW-scale problem (90 stages, 128 nodes)
    Sg, V = 90, 128
    M = jax.random.uniform(jax.random.PRNGKey(1), (Sg, V, V)) * 0.05
    src = jax.random.uniform(jax.random.PRNGKey(2), (Sg, V))
    t0 = jnp.zeros((Sg, V))
    t_kern = _time(lambda: ops.propagate_step(t0, M, src))
    rp = jax.jit(ref.propagate_step)
    t_ref = _time(lambda: rp(t0, M, src))
    emit("kernel_chain_propagate_interp", t_kern, f"jnp_ref:{t_ref:.0f}us")

    # ssd chunk
    Bz, nc, Q, Hh, P, N = 1, 4, 128, 4, 64, 64
    xs = jax.random.split(jax.random.PRNGKey(3), 4)
    xh = jax.random.normal(xs[0], (Bz, nc, Q, Hh, P))
    dt = jax.nn.softplus(jax.random.normal(xs[1], (Bz, nc, Q, Hh)))
    A = -jnp.exp(0.2 * jax.random.normal(xs[2], (Hh,)))
    cum = jnp.cumsum(dt * A[None, None, None], axis=2)
    BH = 0.3 * jax.random.normal(xs[3], (Bz, nc, Q, Hh, N))
    CH = 0.3 * jax.random.normal(jax.random.PRNGKey(9), (Bz, nc, Q, Hh, N))
    t_kern = _time(lambda: ops.ssd_chunk(xh, dt, None, cum, BH, CH))
    rs = jax.jit(ref.ssd_chunk)
    t_ref = _time(lambda: rs(xh, dt, cum, BH, CH))
    emit("kernel_ssd_chunk_interp", t_kern, f"jnp_ref:{t_ref:.0f}us")

    # batched-LU stage solver: kernel-vs-LAPACK speedup + GP parity
    bench_batched_solve()
    # blocked-set propagation: bitset kernel vs the dense V-sweep scan
    bench_blocked_sets()
    bench_gp_solver_parity()
    # unified step engine under shard_map vs the single-device chunked solve
    bench_sharded_parity()


if __name__ == "__main__":
    if "--smoke" in sys.argv[1:]:
        smoke()
    else:
        main()
