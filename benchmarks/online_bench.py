"""Online-service benchmark: OnlineSolver vs per-event cold solves.

    PYTHONPATH=src python benchmarks/online_bench.py [--smoke] [--events N]

Drives a scripted event trace (rates, failures, arrivals — DESIGN.md §16)
over the fig6 fleet (abilene at the FIG6_SCALES rate ladder) and measures
the service's incremental re-convergence against two cold baselines solved
per event on the identical post-event instances:

  * ``cold-accel`` — ``gp.solve(..., accel=True)``: the §15-accelerated
    cold restart, the honest baseline (same solver configuration the
    service itself uses);
  * ``cold-plain`` — ``gp.solve(...)`` without acceleration, the legacy
    restart-from-scratch reference.

Asserts the paper-level claims the service is sold on (hard failures, not
just recorded numbers):

  * **cost parity** — no post-event online cost exceeds the cold-accel
    optimum by more than 1e-4 (relative); events where the warm start
    lands *below* the cold answer (cold ground into its iteration cap)
    are counted separately as ``n_online_better``;
  * **iteration cut** — total online iterations <= 0.5x the cold-accel
    total (warm starts + skip gates do real work);
  * **skip gate** — at least one event is skipped outright (0 iterations)
    or solves a strict subset of the member's live apps.

Rows land in BENCH_gp.json keyed (online, fig6-trace{N}, 11, *): the
``online`` solver row carries total seconds/iters plus the iteration ratio
and worst parity; the two cold rows carry their own totals so future PRs
can diff all three trajectories.

``--chaos`` runs the §17 fault-tolerance leg instead: a seeded
``faults.chaos_trace`` (flapping, destination-area node bursts,
over-capacity surges, event storms) with a ``faults.FaultInjector``
corrupting solver state at the solve boundary, under ``debug=True`` so the
runtime invariant checker screens every event.  Asserts survival — every
member ends feasible and finite, and no served cost ever exceeds the
member's last-known-good incumbent on the current instance — and records a
(online, chaos-trace{N}, 11, online-chaos) row with degradation-ladder hit
counts, status tallies, injection/quarantine counts.

``--trace-out PREFIX`` arms the §19 observability layer on either leg:
the solver runs with device telemetry + a metrics registry + a span
tracer, and four artifacts land next to PREFIX —

  * ``PREFIX.trace.json``   — Chrome-trace/perfetto span timeline
  * ``PREFIX.events.jsonl`` — one line per HealthReport
  * ``PREFIX.iters.jsonl``  — per-iteration device telemetry records
  * ``PREFIX.metrics.json`` — counters/gauges/histograms snapshot

``python -m repro.obs.report --trace PREFIX`` turns them into the
per-member timeline + fleet summary (and ``--check-bench`` cross-checks
the event iteration totals against the committed BENCH_gp.json row).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

sys.path.insert(0, "src")
sys.path.insert(0, ".")

import numpy as np

from benchmarks.common import bench_record, save_json
from repro import obs
from repro.core import events, faults, gp, network
from repro.core.scenarios import FIG6_SCALES
from repro.serve.online import OnlineSolver

ALPHA, TOL = 0.1, 1e-4
# LKG bound used by the chaos assertions: the service's own rollback
# margin (serve/online.py default) plus float32 re-costing headroom.
LKG_MARGIN = 2e-4


def _obs_kit(trace_out: str | None):
    """(solver kwargs, metrics, tracer) for ``--trace-out`` — all empty/None
    when tracing is off so the measured path stays exactly the shipped one."""
    if not trace_out:
        return {}, None, None
    metrics, tracer = obs.Metrics(), obs.Tracer()
    return dict(telemetry=True, metrics=metrics, tracer=tracer), metrics, tracer


def _jsonable(o):
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    return str(o)


def export_obs(prefix: str, solver: OnlineSolver, reports, metrics,
               tracer) -> dict:
    """Write the four ``--trace-out`` artifacts; returns {name: path}."""
    d = os.path.dirname(os.path.abspath(prefix))
    os.makedirs(d, exist_ok=True)
    obs.collect_compile_caches(metrics)
    paths = {"trace": prefix + ".trace.json",
             "events": prefix + ".events.jsonl",
             "iters": prefix + ".iters.jsonl",
             "metrics": prefix + ".metrics.json"}
    tracer.export_chrome(paths["trace"],
                         tid_names={b: f"member-{b}"
                                    for b in range(solver.B)})
    with open(paths["events"], "w") as f:
        for t, r in enumerate(reports):
            row = {fld.name: getattr(r, fld.name)
                   for fld in dataclasses.fields(r) if fld.name != "event"}
            row["t"] = t
            row["event"] = type(r.event).__name__
            f.write(json.dumps(row, default=_jsonable) + "\n")
    with open(paths["iters"], "w") as f:
        for rec in solver.iter_trace:
            f.write(json.dumps(rec, default=_jsonable) + "\n")
    metrics.export_json(paths["metrics"])
    return paths


def run_trace(scales, n_events: int, seed: int, spare_apps: int = 2,
              trace_out: str | None = None) -> dict:
    insts = [network.table_ii_instance("abilene", seed=seed, rate_scale=s)
             for s in scales]
    members = events.pad_fleet(insts, spare_apps=spare_apps)
    trace = events.random_trace(members, n_events=n_events, seed=seed)
    snaps = events.replay(members, trace)

    # --- online service ---
    obs_kw, metrics, tracer = _obs_kit(trace_out)
    solver = OnlineSolver(insts, spare_apps=spare_apps, alpha=ALPHA, tol=TOL,
                          accel=True, **obs_kw)
    t0 = time.perf_counter()
    reports = solver.step(trace)
    online_s = time.perf_counter() - t0
    online_iters = solver.event_iters

    # --- cold baselines on the identical post-event instances ---
    cold = {"cold-accel": dict(accel=True), "cold-plain": dict(accel=None)}
    cold_res, cold_s, cold_iters = {}, {}, {}
    for name, kw in cold.items():
        t0 = time.perf_counter()
        res = [gp.solve(inst, alpha=ALPHA, tol=TOL, **kw)
               for _ev, inst, _eff in snaps]
        cold_s[name] = time.perf_counter() - t0
        cold_res[name] = res
        cold_iters[name] = sum(r.iterations for r in res)

    # --- the three claims ---
    # parity is one-sided: the online cost must never EXCEED the cold
    # optimum by more than the tolerance.  Landing *below* cold is a win,
    # not a violation — on heavy members the cold baseline can grind into
    # its iteration cap while the warm start descends past it.
    signed = np.array([
        (rep.cost - ref.final_cost) / max(1.0, abs(ref.final_cost))
        for rep, ref in zip(reports, cold_res["cold-accel"])])
    parity = np.maximum(signed, 0.0)
    ratio = online_iters / max(cold_iters["cold-accel"], 1)
    gate_hits = sum(1 for r in reports
                    if r.iterations == 0 or r.skipped_apps > 0)
    per_event = [
        {"t": t, "event": type(r.event).__name__, "member": r.member,
         "iters": r.iterations,
         "cold_accel_iters": cold_res["cold-accel"][t].iterations,
         "cold_plain_iters": cold_res["cold-plain"][t].iterations,
         "cost": r.cost, "rel_dcost": float(signed[t]),
         "solved": r.solved_apps, "skipped": r.skipped_apps,
         "cold_restart": r.cold_restart, "kept_window": r.kept_window}
        for t, r in enumerate(reports)]
    trace_files = (export_obs(trace_out, solver, reports, metrics, tracer)
                   if trace_out else None)
    return {
        "trace_files": trace_files,
        "n_events": n_events, "seed": seed, "scales": list(scales),
        "online_s": online_s, "online_iters": online_iters,
        "cold_s": cold_s, "cold_iters": cold_iters,
        "max_rel_dcost": float(parity.max()),
        "n_online_better": int((signed < -1e-4).sum()),
        "iter_ratio": float(ratio), "gate_hits": gate_hits,
        "per_event": per_event,
    }


def run_chaos(scales, n_events: int, seed: int, spare_apps: int = 2,
              trace_out: str | None = None) -> dict:
    """The §17 survival leg: chaos trace + fault injection + debug checks."""
    insts = [network.table_ii_instance("abilene", seed=seed, rate_scale=s)
             for s in scales]
    members = events.pad_fleet(insts, spare_apps=spare_apps)
    steps = faults.chaos_trace(members, n_events=n_events, seed=seed)
    obs_kw, metrics, tracer = _obs_kit(trace_out)
    injector = faults.FaultInjector(seed=seed + 1, p_inject=0.15,
                                    metrics=metrics)
    solver = OnlineSolver(insts, spare_apps=spare_apps, alpha=ALPHA, tol=TOL,
                          accel=True, debug=True, fault_injector=injector,
                          **obs_kw)

    t0 = time.perf_counter()
    reports = []
    for batch in steps:
        reports.extend(solver.step(batch))
    chaos_s = time.perf_counter() - t0

    # --- survival claims (hard failures, not recorded numbers) ---
    # 1. every member's final served strategy is feasible and finite
    final = solver.verify_fleet()
    for h in final:
        assert not h.corrupt, f"member {h.member} ends corrupt: {h}"
        assert np.isfinite(h.cost), f"member {h.member} ends non-finite"
    # 2. no served cost ever exceeded the member's last-known-good
    #    incumbent re-costed on the SAME post-event instance ("rejected"
    #    means nothing finite existed, incumbent included — nothing to bound)
    for t, r in enumerate(reports):
        if r.status == "rejected" or not np.isfinite(r.incumbent_cost):
            continue
        assert r.cost <= r.incumbent_cost * (1 + LKG_MARGIN), (
            f"event {t}: served {r.cost} above incumbent {r.incumbent_cost}")

    statuses: dict[str, int] = {}
    for r in reports:
        statuses[r.status] = statuses.get(r.status, 0) + 1
    n_events_run = len(reports)
    trace_files = (export_obs(trace_out, solver, reports, metrics, tracer)
                   if trace_out else None)
    return {
        "trace_files": trace_files,
        "n_events": n_events_run, "n_steps": len(steps), "seed": seed,
        "scales": list(scales), "chaos_s": chaos_s,
        "online_iters": solver.event_iters,
        "statuses": statuses,
        "ladder_hits": dict(solver.ladder_hits),
        "injections": len(injector.log),
        "injected_members": sorted({i.member for i in injector.log}),
        "quarantines": solver.quarantines,
        "rollbacks": sum(1 for r in reports if r.rolled_back),
        "shed_apps": sum(len(r.shed) for r in reports),
        "final_costs": [h.cost for h in final],
        "final_slack": [h.capacity_slack for h in final],
    }


def chaos_main(args) -> dict:
    scales = FIG6_SCALES[:3] if args.smoke else FIG6_SCALES
    n_events = 30 if args.smoke else args.events
    out = run_chaos(scales, n_events, args.seed, trace_out=args.trace_out)

    label = f"chaos-trace{n_events}"
    bench_record("online", scenario=label, V=11, solver="online-chaos",
                 seconds=out["chaos_s"], iters=out["online_iters"],
                 events=out["n_events"], members=len(scales),
                 statuses=out["statuses"], ladder_hits=out["ladder_hits"],
                 injections=out["injections"],
                 quarantines=out["quarantines"],
                 rollbacks=out["rollbacks"], shed_apps=out["shed_apps"])
    save_json(f"online_{label}.json", out)

    print(f"chaos: events={out['n_events']} steps={out['n_steps']} "
          f"members={len(scales)} seed={args.seed}")
    print(f"online:      {out['online_iters']:5d} iters  "
          f"{out['chaos_s']:.2f}s")
    print(f"statuses:    {out['statuses']}")
    print(f"ladder hits: {out['ladder_hits'] or '(none needed)'}")
    print(f"injections:  {out['injections']} "
          f"(members {out['injected_members']}), "
          f"quarantines: {out['quarantines']}, "
          f"rollbacks: {out['rollbacks']}, shed: {out['shed_apps']}")
    print("OK: all members end feasible+finite; "
          "served costs never exceeded the LKG incumbent")
    if out["trace_files"]:
        print("trace artifacts: "
              + " ".join(sorted(out["trace_files"].values())))
    return out


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--events", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="small trace (10 events, 3 members) for CI")
    ap.add_argument("--chaos", action="store_true",
                    help="run the §17 chaos/fault-injection survival leg")
    ap.add_argument("--trace-out", default=None, metavar="PREFIX",
                    help="write §19 observability artifacts "
                         "(PREFIX.trace.json, .events.jsonl, .iters.jsonl, "
                         ".metrics.json)")
    args = ap.parse_args(argv)
    if args.chaos:
        if args.events == 50:
            args.events = 100       # chaos default: the 100-event criterion
        return chaos_main(args)

    scales = FIG6_SCALES[:3] if args.smoke else FIG6_SCALES
    n_events = 10 if args.smoke else args.events
    out = run_trace(scales, n_events, args.seed, trace_out=args.trace_out)

    label = f"fig6-trace{n_events}"
    bench_record("online", scenario=label, V=11, solver="online",
                 seconds=out["online_s"], iters=out["online_iters"],
                 events=n_events, members=len(scales),
                 iter_ratio=round(out["iter_ratio"], 4),
                 max_rel_dcost=out["max_rel_dcost"],
                 gate_hits=out["gate_hits"])
    for name in ("cold-accel", "cold-plain"):
        bench_record("online", scenario=label, V=11, solver=name,
                     seconds=out["cold_s"][name],
                     iters=out["cold_iters"][name], events=n_events,
                     members=len(scales))
    save_json(f"online_{label}.json", out)

    print(f"events={n_events} members={len(scales)} seed={args.seed}")
    print(f"online:      {out['online_iters']:5d} iters  "
          f"{out['online_s']:.2f}s")
    for name in ("cold-accel", "cold-plain"):
        print(f"{name}:  {out['cold_iters'][name]:5d} iters  "
              f"{out['cold_s'][name]:.2f}s")
    print(f"iter ratio (online/cold-accel): {out['iter_ratio']:.3f}")
    print(f"max relative cost excess:       {out['max_rel_dcost']:.2e}")
    print(f"events online beat cold by >1e-4: {out['n_online_better']}")
    print(f"skip-gate hits:                 {out['gate_hits']}/{n_events}")

    # the <=0.5x iteration-cut claim is defined on the 50-event trace; a
    # 10-event smoke trace is too short to amortize warm-up events, so CI
    # smoke only sanity-checks that warm starts never LOSE to cold
    ratio_cap = 1.0 if args.smoke else 0.5
    assert out["max_rel_dcost"] <= 1e-4, (
        f"cost parity broken: {out['max_rel_dcost']:.2e} > 1e-4")
    assert out["iter_ratio"] <= ratio_cap, (
        f"iteration cut missed: {out['iter_ratio']:.3f} > {ratio_cap}")
    assert out["gate_hits"] > 0, "skip gate never fired"
    print(f"OK: parity <= 1e-4, iters <= {ratio_cap}x cold-accel, "
          "skip gate active")
    if out["trace_files"]:
        print("trace artifacts: "
              + " ".join(sorted(out["trace_files"].values())))
    return out


if __name__ == "__main__":
    main()
