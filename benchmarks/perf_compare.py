"""§Perf helper: compare baseline dry-run records against optimization
variants and print before/after roofline terms per hillclimb pair."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import RESULTS_DIR, emit, save_json
from benchmarks.roofline import analyze


def load(path_glob: str) -> list[dict]:
    out = []
    for p in sorted(glob.glob(path_glob)):
        with open(p) as f:
            r = json.load(f)
        if "error" not in r and "skipped" not in r:
            out.append(r)
    return out


def row(rec: dict) -> dict:
    a = analyze(rec)
    a["opts"] = rec.get("opts", [])
    a["mesh_str"] = "x".join(str(v) for v in rec["mesh"].values())
    return a


def main():
    base = {(r["arch"], r["shape"]): row(r)
            for r in load(os.path.join(RESULTS_DIR, "dryrun", "*__pod.json"))}
    rows = []
    for r in load(os.path.join(RESULTS_DIR, "perf", "*.json")):
        v = row(r)
        b = base.get((v["arch"], v["shape"]))
        if b is None:
            continue
        cmp = {
            "arch": v["arch"], "shape": v["shape"],
            "variant": "+".join(v["opts"]) or f"mesh{v['mesh_str']}",
            "mesh": v["mesh_str"],
        }
        for term in ("compute_s", "memory_s", "collective_s"):
            cmp[f"{term}_before"] = b[term]
            cmp[f"{term}_after"] = v[term]
            cmp[f"{term}_x"] = b[term] / v[term] if v[term] > 0 else float("inf")
        cmp["dominant_before"], cmp["dominant_after"] = b["dominant"], v["dominant"]
        cmp["useful_before"], cmp["useful_after"] = (
            b["useful_compute_ratio"], v["useful_compute_ratio"])
        rows.append(cmp)
        emit(f"perf_{v['arch']}_{v['shape']}_{cmp['variant']}",
             (v["compute_s"] + v["memory_s"] + v["collective_s"]) * 1e6,
             f"dom:{b['dominant']}->{v['dominant']}|"
             f"{b['dominant']}_term_x:{cmp[b['dominant'] + '_s_x']:.1f}")
    save_json("perf_compare.json", rows)
    for c in rows:
        print(f"# {c['arch']} {c['shape']} [{c['variant']} mesh {c['mesh']}]")
        for term in ("compute_s", "memory_s", "collective_s"):
            print(f"#   {term}: {c[term + '_before']:.3e} -> "
                  f"{c[term + '_after']:.3e}  ({c[term + '_x']:.1f}x)")
    return rows


if __name__ == "__main__":
    main()
