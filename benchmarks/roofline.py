"""Roofline analysis from the dry-run artifacts (deliverable g).

For each (arch x input-shape) on the single-pod 16x16 mesh:

    compute term    = HLO_FLOPs   / (chips * peak)     [s]
    memory term     = HLO_bytes   / (chips * hbm_bw)   [s]
    collective term = coll_bytes  / (chips * link_bw)  [s]

The dry-run JSONs store *per-device* extrapolated numbers (the compiled
module is the per-device SPMD program), so the division by chips is already
done.  MODEL_FLOPS uses 6*N_active*D for training and 2*N_active*D for
inference; the ratio MODEL_FLOPS / (HLO_FLOPs * chips) is the useful-compute
fraction (remat / redundancy / routing waste shows up here).

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import RESULTS_DIR, emit, save_json
from repro import configs
from repro.launch.specs import INPUT_SHAPES
from repro.models import flops as F

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

RECO = {
    "compute": "raise useful-compute fraction (less remat recompute, fuse "
               "elementwise chains, larger per-chip tiles)",
    "memory": "cut HBM traffic (blockwise attention instead of materialized "
              "S^2 scores, fuse softmax, bf16 temps)",
    "collective": "reshard to shrink collectives (2D-shard activations, "
                  "overlap all-reduce with compute, expert-parallel a2a)",
}


def model_flops(arch: str, shape: str) -> float:
    cfg = configs.get(arch)
    seq, batch, kind = INPUT_SHAPES[shape]
    _, active = F.param_count(cfg)
    if kind == "train":
        tokens = batch * seq
        return 6.0 * active * tokens
    tokens = batch * (seq if kind == "prefill" else 1)
    return 2.0 * active * tokens


def analyze(rec: dict) -> dict:
    chips = rec["devices"]
    cost = rec.get("cost_extrapolated") or rec["cost"]
    coll = rec.get("collective_bytes_extrapolated") or rec["collective_bytes"]
    coll_total = sum(max(v, 0.0) for v in coll.values())
    hlo_flops = cost.get("flops", 0.0) or 0.0
    hlo_bytes = cost.get("bytes accessed", 0.0) or 0.0

    t_compute = hlo_flops / PEAK_FLOPS
    t_memory = hlo_bytes / HBM_BW
    t_coll = coll_total / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dom = max(terms, key=terms.get)

    mf = model_flops(rec["arch"], rec["shape"])
    useful = mf / max(hlo_flops * chips, 1e-9)
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "chips": chips,
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_coll,
        "dominant": dom,
        "model_flops": mf,
        "hlo_flops_global": hlo_flops * chips,
        "useful_compute_ratio": useful,
        "note": rec.get("note", ""),
        "move_down": RECO[dom],
        "memory_per_dev": rec["memory"],
        "collective_breakdown": coll,
    }


def load_records(dryrun_dir: str, mesh: str = "pod") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, f"*__{mesh}.json"))):
        with open(path) as f:
            r = json.load(f)
        if "error" in r or "skipped" in r:
            continue
        recs.append(r)
    return recs


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| useful ratio | note |\n|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        body += (f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
                 f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
                 f"**{r['dominant']}** | {r['useful_compute_ratio']:.3f} | "
                 f"{r['note']} |\n")
    return hdr + body


# ---------------------------------------------------------------------------
# GP stage-sweep arithmetic intensity (DESIGN.md §18)
#
# The dense fixed-point sweep x <- b + M x moves a V^2 matrix per stage per
# sweep; the sparse paths move O(E) values.  Counting bytes from V^2 for the
# sparse kernels would overstate their intensity by V^2/E (~V/D on metro
# graphs), so these rows derive bytes from the actual resident operands:
#
#   dense:  flops 2V^2,  bytes 4(V^2 + 2V)            (f32 matrix + x, b)
#   nbr:    flops 2E,    bytes 4(2VD + 2V)            (padded vals+idx, x, b)
#   bsr:    flops 2*nb*B^2, bytes 4(nb*B^2 + nb*2B)   (nonzero blocks only)
#
# per stage per sweep, where D = max degree, B = SPARSE_BLOCK and nb = count
# of nonzero partition blocks.  Intensity is flops/bytes — all three sit far
# below the CPU/TPU ridge point, i.e. every sweep variant is memory-bound
# and the E-vs-V^2 byte ratio IS the expected speedup, which is what the
# metro rows in BENCH_gp.json measure empirically.


def gp_sparse_rows(vs: tuple = (100, 300, 1000)) -> list[dict]:
    import numpy as np

    from repro.core import network
    from repro.kernels.sparse_solve import SPARSE_BLOCK

    rows = []
    for topo in ("sw", "geant"):
        for V in vs:
            inst = network.metro_instance(topo, V)
            E = network.n_edges(inst)
            D = int(inst.max_degree)
            nb = int(np.asarray(inst.blk_mask).sum())
            dense_flops, dense_bytes = 2.0 * V * V, 4.0 * (V * V + 2 * V)
            nbr_flops, nbr_bytes = 2.0 * E, 4.0 * (2.0 * V * D + 2 * V)
            bsr_flops = 2.0 * nb * SPARSE_BLOCK ** 2
            bsr_bytes = 4.0 * nb * (SPARSE_BLOCK ** 2 + 2 * SPARSE_BLOCK)
            rows.append({
                "topo": topo, "V": V, "E": E, "max_degree": D,
                "nnz_blocks": nb, "block": SPARSE_BLOCK,
                "dense_intensity": dense_flops / dense_bytes,
                "nbr_intensity": nbr_flops / nbr_bytes,
                "bsr_intensity": bsr_flops / bsr_bytes,
                "dense_bytes_per_sweep": dense_bytes,
                "nbr_bytes_per_sweep": nbr_bytes,
                "bsr_bytes_per_sweep": bsr_bytes,
                "byte_ratio_dense_over_nbr": dense_bytes / nbr_bytes,
            })
    return rows


def gp_markdown_table(rows: list[dict]) -> str:
    hdr = ("| topo | V | E | dense AI | nbr AI | bsr AI | dense/nbr bytes |\n"
           "|---|---|---|---|---|---|---|\n")
    body = ""
    for r in rows:
        body += (f"| {r['topo']} | {r['V']} | {r['E']} | "
                 f"{r['dense_intensity']:.3f} | {r['nbr_intensity']:.3f} | "
                 f"{r['bsr_intensity']:.3f} | "
                 f"{r['byte_ratio_dense_over_nbr']:.1f}x |\n")
    return hdr + body


def main() -> list[dict]:
    gp_rows = gp_sparse_rows()
    for r in gp_rows:
        emit(f"roofline_gp_{r['topo']}_V{r['V']}",
             r["nbr_bytes_per_sweep"] / 1e6,
             f"nbr_AI:{r['nbr_intensity']:.3f}|"
             f"dense_bytes:{r['byte_ratio_dense_over_nbr']:.1f}x")
    save_json("roofline_gp.json", gp_rows)
    with open(os.path.join(RESULTS_DIR, "roofline_gp.md"), "w") as f:
        f.write(gp_markdown_table(gp_rows))
    print(f"# wrote {len(gp_rows)} GP sweep rows -> results/roofline_gp.md")

    dd = os.path.join(RESULTS_DIR, "dryrun")
    recs = load_records(dd, "pod")
    rows = [analyze(r) for r in recs]
    for r in rows:
        total = r["compute_s"] + r["memory_s"] + r["collective_s"]
        emit(f"roofline_{r['arch']}_{r['shape']}", total * 1e6,
             f"dom:{r['dominant']}|useful:{r['useful_compute_ratio']:.3f}")
    save_json("roofline.json", rows)
    with open(os.path.join(RESULTS_DIR, "roofline.md"), "w") as f:
        f.write(markdown_table(rows))
    print(f"# wrote {len(rows)} roofline rows -> results/roofline.md")
    return rows


if __name__ == "__main__":
    main()
