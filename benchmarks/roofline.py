"""Roofline analysis from the dry-run artifacts (deliverable g).

For each (arch x input-shape) on the single-pod 16x16 mesh:

    compute term    = HLO_FLOPs   / (chips * peak)     [s]
    memory term     = HLO_bytes   / (chips * hbm_bw)   [s]
    collective term = coll_bytes  / (chips * link_bw)  [s]

The dry-run JSONs store *per-device* extrapolated numbers (the compiled
module is the per-device SPMD program), so the division by chips is already
done.  MODEL_FLOPS uses 6*N_active*D for training and 2*N_active*D for
inference; the ratio MODEL_FLOPS / (HLO_FLOPs * chips) is the useful-compute
fraction (remat / redundancy / routing waste shows up here).

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import RESULTS_DIR, emit, save_json
from repro import configs
from repro.launch.specs import INPUT_SHAPES
from repro.models import flops as F

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

RECO = {
    "compute": "raise useful-compute fraction (less remat recompute, fuse "
               "elementwise chains, larger per-chip tiles)",
    "memory": "cut HBM traffic (blockwise attention instead of materialized "
              "S^2 scores, fuse softmax, bf16 temps)",
    "collective": "reshard to shrink collectives (2D-shard activations, "
                  "overlap all-reduce with compute, expert-parallel a2a)",
}


def model_flops(arch: str, shape: str) -> float:
    cfg = configs.get(arch)
    seq, batch, kind = INPUT_SHAPES[shape]
    _, active = F.param_count(cfg)
    if kind == "train":
        tokens = batch * seq
        return 6.0 * active * tokens
    tokens = batch * (seq if kind == "prefill" else 1)
    return 2.0 * active * tokens


def analyze(rec: dict) -> dict:
    chips = rec["devices"]
    cost = rec.get("cost_extrapolated") or rec["cost"]
    coll = rec.get("collective_bytes_extrapolated") or rec["collective_bytes"]
    coll_total = sum(max(v, 0.0) for v in coll.values())
    hlo_flops = cost.get("flops", 0.0) or 0.0
    hlo_bytes = cost.get("bytes accessed", 0.0) or 0.0

    t_compute = hlo_flops / PEAK_FLOPS
    t_memory = hlo_bytes / HBM_BW
    t_coll = coll_total / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dom = max(terms, key=terms.get)

    mf = model_flops(rec["arch"], rec["shape"])
    useful = mf / max(hlo_flops * chips, 1e-9)
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "chips": chips,
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_coll,
        "dominant": dom,
        "model_flops": mf,
        "hlo_flops_global": hlo_flops * chips,
        "useful_compute_ratio": useful,
        "note": rec.get("note", ""),
        "move_down": RECO[dom],
        "memory_per_dev": rec["memory"],
        "collective_breakdown": coll,
    }


def load_records(dryrun_dir: str, mesh: str = "pod") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, f"*__{mesh}.json"))):
        with open(path) as f:
            r = json.load(f)
        if "error" in r or "skipped" in r:
            continue
        recs.append(r)
    return recs


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| useful ratio | note |\n|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        body += (f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
                 f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
                 f"**{r['dominant']}** | {r['useful_compute_ratio']:.3f} | "
                 f"{r['note']} |\n")
    return hdr + body


def main() -> list[dict]:
    dd = os.path.join(RESULTS_DIR, "dryrun")
    recs = load_records(dd, "pod")
    rows = [analyze(r) for r in recs]
    for r in rows:
        total = r["compute_s"] + r["memory_s"] + r["collective_s"]
        emit(f"roofline_{r['arch']}_{r['shape']}", total * 1e6,
             f"dom:{r['dominant']}|useful:{r['useful_compute_ratio']:.3f}")
    save_json("roofline.json", rows)
    with open(os.path.join(RESULTS_DIR, "roofline.md"), "w") as f:
        f.write(markdown_table(rows))
    print(f"# wrote {len(rows)} roofline rows -> results/roofline.md")
    return rows


if __name__ == "__main__":
    main()
