"""Benchmark harness: one module per paper table/figure.

  fig5_scenarios   — Fig. 5 normalized cost across Table II scenarios
  fig6_congestion  — Fig. 6 cost vs input rate (Abilene)
  fig7_packetsize  — Fig. 7 hop counts vs packet size
  gp_scaling       — Section IV complexity (per-iteration time scaling)
  kernel_bench     — Pallas kernels vs jnp oracles (interpret mode)
  roofline         — deliverable (g): per (arch x shape) roofline terms from
                     the dry-run artifacts (run launch/dryrun.py first)
  perf_compare     — before/after roofline terms per dry-run hillclimb pair

Prints ``name,us_per_call,derived`` CSV.  Use --only <name> for one section.
"""

from __future__ import annotations

import argparse
import sys
import traceback

SECTIONS = ["fig5_scenarios", "fig6_congestion", "fig7_packetsize",
            "gp_scaling", "kernel_bench", "roofline", "perf_compare"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    names = [args.only] if args.only else SECTIONS
    failed = []
    for name in names:
        print(f"# === {name} ===", flush=True)
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main()
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            print(f"# {name} FAILED: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
