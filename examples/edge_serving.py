"""End-to-end edge serving: GP places a vertically-split DNN, then the
placement actually executes.

    PYTHONPATH=src python examples/edge_serving.py

This is the paper's headline use case ("DNN with vertical split", Section I)
made concrete:
  1. take the internlm2 architecture (reduced), cut its layer stack into 3
     segments -> a service-chain application (core/chain.py),
  2. run GP on the Abilene edge topology to find the delay-optimal
     forwarding + offloading of those segments,
  3. execute the resulting placement: each network node that received
     offload mass runs its model segment on real activations, and the
     final logits are compared against a monolithic forward pass.
"""

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import chain, gp, network, traffic
from repro.models.transformer import Model


def main():
    cfg = configs.get("internlm2-1.8b", reduced=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # --- 1. derive the service chain from the model ---
    profile = chain.chain_from_arch(cfg, n_segments=2, tokens_per_packet=32,
                                    flops_unit=1e6, bits_unit=1e4)
    print(f"chain '{profile.name}': L={profile.L.round(3)} w={profile.w.round(3)}")

    # --- 2. GP placement on Abilene ---
    adj = network.TOPOLOGIES["abilene"]()
    inst = chain.instance_from_chains(
        adj, [profile], sources=[[0, 2]], rates=[[1.0, 1.0]], dests=[9],
        link_capacity=40.0, comp_capacity=30.0,
    )
    res = gp.solve(inst, alpha=0.1, max_iters=300)
    fl = traffic.flows(inst, res.phi)
    g = np.asarray(fl.g)            # (A, K1, V) offload rates
    print(f"GP cost {res.final_cost:.4f} after {res.iterations} iters")
    for k in range(profile.n_tasks):
        where = {i: round(float(g[0, k, i]), 3) for i in range(inst.V) if g[0, k, i] > 1e-3}
        print(f"  segment {k + 1} computed at nodes: {where}")

    # --- 3. execute the placement on real activations ---
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 0, cfg.vocab)
    ref_logits, _, _ = model.apply(params, {"tokens": toks})

    # split apply: run segment 1 (layers 0..bound) then segment 2 — the
    # activations that GP would ship between compute nodes
    bound = cfg.n_layers // 2
    x = model.embed(params, {"tokens": toks})
    positions = jnp.broadcast_to(jnp.arange(32)[None], (1, 32))
    from repro.models import blocks
    for li in range(cfg.n_layers):
        meta = blocks.layer_meta(cfg, li)
        psl = jax.tree_util.tree_map(lambda a: a[li], params["body"][0])
        x, _, _ = blocks.apply_block(psl, cfg, meta, x, positions=positions)
        if li == bound - 1:
            print(f"  [segment boundary] activation packet: {x.shape} "
                  f"{x.dtype} = {x.size * x.dtype.itemsize} bytes")
    logits = model.head(params, x)
    err = float(jnp.max(jnp.abs(logits - ref_logits)))
    print(f"split execution matches monolithic forward: max err {err:.2e}")
    assert err < 1e-3


if __name__ == "__main__":
    main()
