"""Online adaptation: the GP solver as a long-running service.

    PYTHONPATH=src python examples/online_adaptation.py

Demonstrates the paper's Section IV adaptivity claims through the online
service (``repro.serve.OnlineSolver``, DESIGN.md §16): one application's
rate jumps, then the whole network surges, the busiest link fails, and
the load falls back — each arrives as a typed event
(``repro.core.events``) and the service re-converges incrementally from
its live strategy instead of restarting.

Each event prints the service's :class:`EventReport` next to a cold
``gp.solve`` on the identical post-event instance: warm iterations vs
cold iterations, the per-app skip gate's solved/skipped split (the first
event re-solves ONE app and freezes the other two — their strategies are
provably still optimal), whether phi was repaired (topology events) and
whether the §15 Anderson window survived (small rate deltas).

The service's answer tracks the cold optimum; the headline numbers (cost
excess <= 1e-4, total iterations <= 0.5x cold over a 50-event trace) are
measured by ``benchmarks/online_bench.py``.
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import events, gp, network, traffic
from repro.serve import OnlineSolver

ALPHA, TOL = 0.1, 1e-4


def report(solver, rep, label):
    inst = solver.member(rep.member)
    cold = gp.solve(inst, alpha=ALPHA, tol=TOL, accel=True)
    print(f"{label:28s} cost {rep.cost:8.3f}  "
          f"iters {rep.iterations:3d} (cold {int(cold.iterations):3d})  "
          f"solved/skipped {rep.solved_apps}/{rep.skipped_apps}  "
          f"repaired={rep.repaired} kept_window={rep.kept_window}")
    # warm and cold runs may latch onto different near-stationary points;
    # the demo only checks the service never loses more than 1% (the
    # 50-event bench pins the one-sided excess at <= 1e-4)
    assert rep.cost <= cold.final_cost * 1.01, (
        f"online answer worse than cold: {rep.cost} vs {cold.final_cost}")


def main():
    inst = network.table_ii_instance("abilene", seed=0, rate_scale=0.5)
    solver = OnlineSolver([inst], alpha=ALPHA, tol=TOL, accel=True)
    print(f"{'initial convergence':28s} cost {float(solver.costs()[0]):8.3f}  "
          f"iters {int(solver.cold_iters[0]):3d}")

    # event 1: one application's input rate jumps; at this load the other
    # two apps' residuals stay below the gate tolerance, so the service
    # re-solves a single app and freezes the rest
    rep = solver.process(events.RateScale(member=0, factor=1.8, app=0))
    report(solver, rep, "after app-0 surge (warm)")

    # event 2: the whole network surges (x2 is inside SMALL_RATE_WINDOW,
    # so the Anderson acceleration window survives the event)
    rep = solver.process(events.RateScale(member=0, factor=2.0))
    report(solver, rep, "after global surge (warm)")

    # event 3: the busiest link fails (topology -> phi repair)
    fl = traffic.flows(solver.member(0), solver.phi(0))
    F = np.asarray(fl.F)
    i, j = np.unravel_index(F.argmax(), F.shape)
    print(f"  -> failing busiest link ({i},{j}) carrying {F[i, j]:.2f} bit/s")
    rep = solver.process(events.LinkDown(member=0, i=int(i), j=int(j)))
    report(solver, rep, "after link failure (warm)")

    # event 4: rates fall back
    rep = solver.process(events.RateScale(member=0, factor=0.5))
    report(solver, rep, "after load returns (warm)")

    print(f"total event iterations: {solver.event_iters} "
          f"(initial cold solve: {int(solver.cold_iters[0])})")
    print("OK: the online service adapted to rate and topology changes, "
          "staying within 1% of the cold optimum at every step.")


if __name__ == "__main__":
    main()
