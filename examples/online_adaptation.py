"""Online adaptation: the GP algorithm tracking a time-varying network.

    PYTHONPATH=src python examples/online_adaptation.py

Demonstrates the paper's Section IV adaptivity claims: input rates change
and a link fails mid-run; the algorithm keeps iterating from its current
strategy (no restart) and re-converges each time.

Each segment runs twice — plain GP and the §15-accelerated solver
(``accel=True``: Anderson mixing, adaptive stepsize, residual stopping) —
and prints both iteration counts.  Only the converged phi warm-starts the
next segment: every ``gp.solve`` call builds a fresh carry, so the
Anderson history window is cleared at each rate/topology event and the
mixer never extrapolates across a physics change.
"""

import sys

sys.path.insert(0, "src")

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import conditions, gp, network, traffic


def converge(inst, phi, label, iters=250):
    plain = gp.solve(inst, phi0=phi, alpha=0.1, max_iters=iters)
    res = gp.solve(inst, phi0=phi, alpha=0.1, max_iters=iters, accel=True)
    r = float(conditions.sufficiency_residual(inst, res.phi, active_eps=1e-3))
    print(f"{label:28s} cost {res.final_cost:10.3f}  "
          f"iters {int(plain.iterations):4d} -> {int(res.iterations):4d} "
          f"(accel)  suff-residual {r:.2e}")
    return res.phi


def main():
    inst = network.table_ii_instance("abilene", seed=0, rate_scale=1.5)
    phi = converge(inst, None, "initial convergence")

    # event 1: traffic surge (rates x2)
    inst2 = dataclasses.replace(inst, r=inst.r * 2.0)
    phi = converge(inst2, phi, "after rate surge (warm)")

    # event 2: a loaded link fails
    fl = traffic.flows(inst2, phi)
    F = np.asarray(fl.F)
    i, j = np.unravel_index(F.argmax(), F.shape)
    print(f"  -> failing busiest link ({i},{j}) carrying {F[i, j]:.2f} bit/s")
    adj = np.asarray(inst2.adj).copy(); adj[i, j] = False
    lp = np.asarray(inst2.link_param).copy(); lp[i, j] = 0.0
    inst3 = dataclasses.replace(inst2, adj=jnp.asarray(adj), link_param=jnp.asarray(lp))
    phi = traffic.renormalize(inst3, phi)
    tot = phi.e.sum(-1) + phi.c
    empty = (tot < 0.5) & ~inst3.degenerate_mask()
    if bool(empty.any()):
        sp = gp.init_phi(inst3)
        phi = traffic.Phi(e=jnp.where(empty[..., None], sp.e, phi.e),
                          c=jnp.where(empty, sp.c, phi.c))
    phi = converge(inst3, phi, "after link failure (warm)")

    # event 3: rates fall back
    inst4 = dataclasses.replace(inst3, r=inst.r)
    converge(inst4, phi, "after load returns (warm)")
    print("OK: GP adapted online to rate changes and topology changes "
          "(accelerated solves, fresh Anderson history per event).")


if __name__ == "__main__":
    main()
