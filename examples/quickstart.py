"""Quickstart: solve a CEC service-chain instance with GP and inspect it.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's Abilene scenario, runs the distributed gradient-projection
algorithm (Algorithm 1), verifies the sufficiency optimality condition (6),
compares against the three baselines of Section V — then solves a 32-seed
ensemble of the same scenario in ONE batched call via the scenario engine.
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import baselines, conditions, gp, network, scenarios, traffic


def main():
    # the paper's Abilene scenario (Table II), moderately congested
    inst = network.table_ii_instance("abilene", seed=0, rate_scale=2.0)
    print(f"network: |V|={inst.V} |E|={int(np.asarray(inst.adj).sum())} "
          f"|A|={inst.A} stages={inst.A * inst.K1}")

    res = gp.solve(inst, alpha=0.1, max_iters=400)
    print(f"GP: cost {res.final_cost:.3f} after {res.iterations} iterations")
    print(f"    sufficiency residual {float(conditions.sufficiency_residual(inst, res.phi)):.2e}"
          f"  (0 => provably global optimum, Theorem 1)")

    for name, fn in baselines.ALL_BASELINES.items():
        b = fn(inst) if name == "LPR-SC" else fn(inst, alpha=0.1, max_iters=250)
        print(f"{name:7s}: cost {b.final_cost:10.3f} "
              f"(GP is {b.final_cost / res.final_cost:5.2f}x better)")

    # where did computation land?
    fl = traffic.flows(inst, res.phi)
    G = np.asarray(fl.G)
    caps = np.asarray(inst.comp_param)
    print("\nper-node CPU load (workload / capacity):")
    for i in range(inst.V):
        bar = "#" * int(30 * G[i] / caps[i])
        print(f"  node {i:2d}: {G[i]:6.2f} / {caps[i]:5.2f} {bar}")

    # the batched scenario engine: a 32-seed ensemble of the same scenario,
    # padded into one pytree and solved by a single vmapped device program
    print("\n32-seed ensemble (one batched call):")
    sweep = scenarios.run_sweep(
        "seed-ensemble",
        sweep_kwargs={"scenario": "abilene", "n_seeds": 32, "rate_scale": 2.0},
        alpha=0.1, max_iters=250,
    )
    costs = np.array([r.final_cost for r in sweep.results])
    iters = np.array([r.iterations for r in sweep.results])
    print(f"  solved {len(costs)} seeds in {sweep.seconds:.2f}s "
          f"({sweep.n_batches} device program{'s' if sweep.n_batches > 1 else ''})")
    print(f"  cost  mean {costs.mean():.3f}  std {costs.std():.3f}  "
          f"min {costs.min():.3f}  max {costs.max():.3f}")
    print(f"  iters mean {iters.mean():.0f}  max {int(iters.max())}")


if __name__ == "__main__":
    main()
