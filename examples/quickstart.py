"""Quickstart: solve a CEC service-chain instance with GP and inspect it.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's Abilene scenario, runs the distributed gradient-projection
algorithm (Algorithm 1), verifies the sufficiency optimality condition (6),
and compares against the three baselines of Section V.
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import baselines, conditions, gp, network, traffic


def main():
    # the paper's Abilene scenario (Table II), moderately congested
    inst = network.table_ii_instance("abilene", seed=0, rate_scale=2.0)
    print(f"network: |V|={inst.V} |E|={int(np.asarray(inst.adj).sum())} "
          f"|A|={inst.A} stages={inst.A * inst.K1}")

    res = gp.solve(inst, alpha=0.1, max_iters=400)
    print(f"GP: cost {res.final_cost:.3f} after {res.iterations} iterations")
    print(f"    sufficiency residual {float(conditions.sufficiency_residual(inst, res.phi)):.2e}"
          f"  (0 => provably global optimum, Theorem 1)")

    for name, fn in baselines.ALL_BASELINES.items():
        b = fn(inst) if name == "LPR-SC" else fn(inst, alpha=0.1, max_iters=250)
        print(f"{name:7s}: cost {b.final_cost:10.3f} "
              f"(GP is {b.final_cost / res.final_cost:5.2f}x better)")

    # where did computation land?
    fl = traffic.flows(inst, res.phi)
    G = np.asarray(fl.G)
    caps = np.asarray(inst.comp_param)
    print("\nper-node CPU load (workload / capacity):")
    for i in range(inst.V):
        bar = "#" * int(30 * G[i] / caps[i])
        print(f"  node {i:2d}: {G[i]:6.2f} / {caps[i]:5.2f} {bar}")


if __name__ == "__main__":
    main()
