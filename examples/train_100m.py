"""End-to-end training driver: train a ~100M-parameter model for a few
hundred steps on CPU and verify the loss decreases.

    PYTHONPATH=src python examples/train_100m.py [--steps 300]

Uses the tinyllama family scaled to ~100M params on a synthetic Markov
token stream (repro/data/pipeline.py), with the in-house AdamW + cosine
schedule and checkpointing.
"""

import argparse
import sys

sys.path.insert(0, "src")

import dataclasses

from repro import configs
from repro.data.pipeline import SyntheticTokens
from repro.models import flops
from repro.models.transformer import Model
from repro.train import trainer


def build_100m_config():
    base = configs.get("tinyllama-1.1b", reduced=True)
    cfg = dataclasses.replace(
        base, name="tinyllama-100m",
        n_layers=8, d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
        vocab=8192, head_dim=None,
    )
    cfg.validate()
    return cfg


def main():
    ap = argparse.ArgumentParser()
    # default sized for a 1-core CPU container; on real hardware run
    # --steps 300+ (the loss keeps falling)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = build_100m_config()
    total, _ = flops.param_count(cfg)
    print(f"config {cfg.name}: {total / 1e6:.0f}M params")
    model = Model(cfg)
    data = iter(SyntheticTokens(vocab=cfg.vocab, seq_len=args.seq,
                                batch=args.batch, seed=0))
    state, history = trainer.train_loop(
        model, data, steps=args.steps,
        peak_lr=1e-3, warmup=min(20, max(args.steps // 3, 1)), total=args.steps,
    )
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"\nloss {first:.4f} -> {last:.4f} "
          f"({(1 - last / first) * 100:.1f}% reduction over {args.steps} steps)")
    # short CPU runs spend most steps inside warmup; only gate longer runs
    want = 0.98 if args.steps >= 60 else 0.995
    assert last < first * want, "training failed to reduce loss"
    print("OK: the model learns the planted Markov structure.")


if __name__ == "__main__":
    main()
