from repro.checkpoint.checkpoint import (  # noqa: F401
    load_checkpoint, restore_latest, save_checkpoint,
)
