"""Checkpointing: msgpack-serialized pytrees with dtype/shape manifest.

Arrays are gathered to host (fully addressable) — adequate for the CPU
examples; on a real multi-host pod this is where a tensorstore-style
per-shard writer would slot in (the layout manifest already records the
tree structure needed for resharded restore).
"""

from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree) -> tuple[list, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(path: str, tree, *, step: int = 0) -> str:
    os.makedirs(path, exist_ok=True)
    leaves, treedef = _flatten(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "leaves": [
            {"shape": list(np.shape(l)), "dtype": str(np.asarray(l).dtype)}
            for l in leaves
        ],
    }
    blob = msgpack.packb(
        [np.ascontiguousarray(np.asarray(l)).tobytes() for l in leaves]
    )
    fn = os.path.join(path, f"ckpt_{step:08d}")
    with open(fn + ".msgpack", "wb") as f:
        f.write(blob)
    with open(fn + ".json", "w") as f:
        json.dump(manifest, f)
    return fn


def load_checkpoint(fn: str, like) -> Any:
    """Restore into the structure of ``like`` (shapes/dtypes must match)."""
    with open(fn + ".msgpack", "rb") as f:
        raws = msgpack.unpackb(f.read())
    with open(fn + ".json") as f:
        manifest = json.load(f)
    leaves, treedef = _flatten(like)
    assert len(leaves) == len(raws), "checkpoint/tree leaf count mismatch"
    out = []
    for raw, meta, leaf in zip(raws, manifest["leaves"], leaves):
        arr = np.frombuffer(raw, dtype=meta["dtype"]).reshape(meta["shape"])
        assert tuple(arr.shape) == tuple(np.shape(leaf)), (arr.shape, np.shape(leaf))
        out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def restore_latest(path: str, like) -> tuple[Any, int]:
    cands = sorted(
        f[:-5] for f in os.listdir(path) if f.endswith(".json") and f.startswith("ckpt_")
    )
    if not cands:
        raise FileNotFoundError(f"no checkpoints under {path}")
    fn = os.path.join(path, cands[-1])
    with open(fn + ".json") as f:
        step = json.load(f)["step"]
    return load_checkpoint(fn, like), step
