"""Architecture registry: the 10 assigned architectures + the paper's own
CEC scenarios.  ``get(name)`` returns the full config; ``get(name,
reduced=True)`` the CPU smoke-test variant."""

from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig, reduced_config  # noqa: F401

ARCH_IDS = [
    "deepseek_v3_671b",
    "mixtral_8x22b",
    "phi4_mini_3_8b",
    "internlm2_1_8b",
    "jamba_v0_1_52b",
    "hubert_xlarge",
    "llava_next_34b",
    "tinyllama_1_1b",
    "mamba2_780m",
    "gemma2_9b",
]

# canonical assignment ids -> module names
ALIASES = {
    "deepseek-v3-671b": "deepseek_v3_671b",
    "mixtral-8x22b": "mixtral_8x22b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "internlm2-1.8b": "internlm2_1_8b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "hubert-xlarge": "hubert_xlarge",
    "llava-next-34b": "llava_next_34b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "mamba2-780m": "mamba2_780m",
    "gemma2-9b": "gemma2_9b",
}

ARCH_NAMES = list(ALIASES)   # canonical dash-form ids


def get(name: str, reduced: bool = False) -> ModelConfig:
    mod_name = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg: ModelConfig = mod.CONFIG
    cfg.validate()
    return reduced_config(cfg) if reduced else cfg
