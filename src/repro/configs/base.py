"""Config system: architecture descriptions for the model zoo.

Every assigned architecture gets one module in ``repro/configs/`` exporting
``CONFIG`` (the exact full-scale configuration from the assignment) plus a
``reduced()`` variant used by CPU smoke tests (2 layers, d_model <= 512,
<= 4 experts).  ``repro.configs.get(name)`` resolves either.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden size
    n_shared: int = 0             # always-on shared experts (DeepSeek-V3)
    every: int = 1                # MoE every Nth layer (Jamba: 2)
    first_k_dense: int = 0        # leading dense layers (DeepSeek-V3: 3)
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V3, arXiv:2412.19437)."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD (arXiv:2405.21060)."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    attn_kind: str = "gqa"                  # gqa | mla | none
    window: Optional[int] = None            # sliding-window size (SWA)
    local_global: bool = False              # gemma2 alternating local/global
    attn_softcap: Optional[float] = None    # gemma2: 50.0
    final_softcap: Optional[float] = None   # gemma2: 30.0
    rope_theta: float = 10_000.0
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid_attn_period: int = 0    # Jamba: 1 attention layer per 8 (1:7)
    hybrid_attn_offset: int = 3    # position of the attn layer in the period
    encoder_only: bool = False     # HuBERT: bidirectional, no decode
    frontend: Optional[str] = None  # None | "audio" | "vision" (stubbed)
    n_patches: int = 0             # VLM: image patch-embedding prefix length
    tie_embeddings: bool = False
    post_norm: bool = False        # gemma2: extra norm after mixer/FFN
    norm_eps: float = 1e-6
    act: str = "silu"
    # serving variants (beyond-paper; see DESIGN.md §6)
    serve_window: Optional[int] = None      # SWA window used only for long-
    #                                         context serving of dense archs
    source: str = ""               # citation for the configuration

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def is_attention_free(self) -> bool:
        return self.attn_kind == "none" and self.hybrid_attn_period == 0

    def layer_kind(self, idx: int) -> str:
        """'attn' | 'ssm' for layer idx (hybrid interleave)."""
        if self.arch_type == "ssm":
            return "ssm"
        if self.hybrid_attn_period:
            return "attn" if idx % self.hybrid_attn_period == self.hybrid_attn_offset else "ssm"
        return "attn"

    def layer_is_moe(self, idx: int) -> bool:
        if self.moe is None:
            return False
        if idx < self.moe.first_k_dense:
            return False
        return (idx - self.moe.first_k_dense) % self.moe.every == 0

    def layer_window(self, idx: int) -> Optional[int]:
        """Effective sliding window for layer idx (None = full attention)."""
        if self.local_global:
            return self.window if idx % 2 == 0 else None    # even layers local
        return self.window

    def validate(self) -> None:
        assert self.n_heads % max(self.n_kv_heads, 1) == 0 or self.attn_kind != "gqa"
        if self.arch_type == "moe":
            assert self.moe is not None
        if self.arch_type == "ssm":
            assert self.ssm is not None and self.attn_kind == "none"
        if self.arch_type == "hybrid":
            assert self.ssm is not None and self.hybrid_attn_period > 0
        if self.attn_kind == "mla":
            assert self.mla is not None


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Smoke-test variant: same family, 2 layers, d_model <= 512, <= 4 experts."""
    d_model = min(cfg.d_model, 256)
    n_heads = min(cfg.n_heads, 4)
    n_kv = min(cfg.n_kv_heads, n_heads) or 1
    updates = dict(
        name=cfg.name + "-reduced",
        n_layers=2,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=max(1, n_kv if n_heads % max(n_kv, 1) == 0 else 1),
        d_ff=min(cfg.d_ff, 512) or 0,
        vocab=min(cfg.vocab, 512),
        head_dim=64 if cfg.head_dim is not None else None,
        window=min(cfg.window, 64) if cfg.window else None,
        serve_window=min(cfg.serve_window, 64) if cfg.serve_window else None,
        n_patches=min(cfg.n_patches, 16) if cfg.n_patches else 0,
    )
    if cfg.moe is not None:
        updates["moe"] = dataclasses.replace(
            cfg.moe,
            n_experts=min(cfg.moe.n_experts, 4),
            top_k=min(cfg.moe.top_k, 2),
            d_expert=min(cfg.moe.d_expert, 256),
            n_shared=min(cfg.moe.n_shared, 1),
            first_k_dense=min(cfg.moe.first_k_dense, 1),
        )
    if cfg.mla is not None:
        updates["mla"] = MLAConfig(
            q_lora_rank=64, kv_lora_rank=32, rope_head_dim=16,
            nope_head_dim=32, v_head_dim=32,
        )
    if cfg.ssm is not None:
        updates["ssm"] = dataclasses.replace(cfg.ssm, d_state=32, head_dim=32)
    if cfg.hybrid_attn_period:
        updates["n_layers"] = 2
        updates["hybrid_attn_period"] = 2     # 1 attn + 1 ssm in the pair
        updates["hybrid_attn_offset"] = 1
    if cfg.local_global:
        updates["n_layers"] = 2               # one local + one global pair
    out = dataclasses.replace(cfg, **updates)
    out.validate()
    return out
