"""DeepSeek-V3 671B — MLA, 1 shared + 256 routed experts top-8, MTP.

Assignment: [moe] 61L d_model=7168 128H (GQA kv=128) d_ff=2048 vocab=129280,
MoE 256e top-8  [arXiv:2412.19437]
"""

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,                 # dense-FFN size of the first_k_dense layers
    vocab=129280,
    head_dim=128,
    attn_kind="mla",
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        rope_head_dim=64,
        nope_head_dim=128,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        n_experts=256,
        top_k=8,
        d_expert=2048,          # assignment d_ff=2048 = routed expert hidden
        n_shared=1,
        first_k_dense=3,
        capacity_factor=1.25,
    ),
    rope_theta=10_000.0,
    norm_eps=1e-6,
    source="arXiv:2412.19437",
)
