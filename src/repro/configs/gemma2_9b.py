"""Gemma 2 9B — local/global alternating attention, logit soft-capping.

Assignment: [dense] 42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000
[arXiv:2408.00118]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    arch_type="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    d_ff=14336,
    vocab=256000,
    head_dim=256,
    attn_kind="gqa",
    window=4096,                # even layers local (SWA-4096), odd global
    local_global=True,
    attn_softcap=50.0,
    final_softcap=30.0,
    rope_theta=10_000.0,
    norm_eps=1e-6,
    post_norm=True,
    tie_embeddings=True,
    act="gelu",
    source="arXiv:2408.00118",
)
