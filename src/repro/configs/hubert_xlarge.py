"""HuBERT X-Large — encoder-only audio transformer (wav2vec2 architecture).

Assignment: [audio] 48L d_model=1280 16H (GQA kv=16) d_ff=5120 vocab=504
[arXiv:2106.07447].  The conv/mel frontend is a stub: input_specs supplies
precomputed frame embeddings (DESIGN.md §5); training is masked cluster
prediction over a 504-unit codebook.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    arch_type="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,                  # k-means cluster codebook
    attn_kind="gqa",
    encoder_only=True,
    frontend="audio",
    act="gelu",
    norm_eps=1e-5,
    source="arXiv:2106.07447",
)
