"""InternLM2 1.8B — dense GQA.

Assignment: [dense] 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92544
[arXiv:2403.17297]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b",
    arch_type="dense",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92544,
    attn_kind="gqa",
    rope_theta=1_000_000.0,
    norm_eps=1e-5,
    serve_window=8192,          # long_500k serving variant only (DESIGN.md §6)
    source="arXiv:2403.17297",
)
