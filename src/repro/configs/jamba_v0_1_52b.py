"""Jamba v0.1 52B — hybrid Mamba + attention 1:7 interleave, MoE 16e top-2.

Assignment: [hybrid] 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536,
MoE 16e top-2  [arXiv:2403.19887]
"""

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    arch_type="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    attn_kind="gqa",
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64),
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=14336, every=2),
    hybrid_attn_period=8,       # 1 attention layer per 8 (1:7 ratio)
    hybrid_attn_offset=3,
    rope_theta=10_000.0,
    norm_eps=1e-6,
    source="arXiv:2403.19887",
)
