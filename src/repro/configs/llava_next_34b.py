"""LLaVA-NeXT 34B — VLM with anyres tiling; language backbone only.

Assignment: [vlm] 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000
[hf:llava-hf/llava-v1.6-mistral-7b-hf].  The vision tower + projector are a
stub: input_specs supplies projected patch embeddings (anyres 2x2 tiles +
base image = 5 x 576 = 2880 patches) interleaved before the text tokens
(DESIGN.md §5).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    arch_type="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    attn_kind="gqa",
    frontend="vision",
    n_patches=2880,             # anyres: (2x2 + 1 base) tiles x (336/14)^2
    rope_theta=5_000_000.0,
    norm_eps=1e-5,
    serve_window=8192,          # long_500k serving variant only (DESIGN.md §6)
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
