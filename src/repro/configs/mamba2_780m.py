"""Mamba-2 780M — attention-free SSM with SSD (state-space duality).

Assignment: [ssm] 48L d_model=1536 (attn-free) d_ff=0 vocab=50280,
ssm_state=128  [arXiv:2405.21060]
"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    arch_type="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,                  # attention-free
    n_kv_heads=0,
    d_ff=0,                     # no FFN: the mamba mixer is the whole block
    vocab=50280,
    attn_kind="none",
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64),
    norm_eps=1e-5,
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
