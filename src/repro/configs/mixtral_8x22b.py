"""Mixtral 8x22B — 8 experts top-2, sliding-window attention.

Assignment: [moe] 56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768,
MoE 8e top-2  [arXiv:2401.04088]
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    arch_type="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    head_dim=128,
    attn_kind="gqa",
    window=4096,                # SWA (Mistral lineage)
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=16384),
    rope_theta=1_000_000.0,
    norm_eps=1e-5,
    source="arXiv:2401.04088",
)
