"""Phi-4-mini 3.8B — dense, RoPE + SwiGLU + GQA.

Assignment: [dense] 32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064
[arXiv:2412.08905]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    arch_type="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab=200064,
    attn_kind="gqa",
    rope_theta=10_000.0,
    norm_eps=1e-5,
    tie_embeddings=True,
    serve_window=8192,          # long_500k serving variant only (DESIGN.md §6)
    source="arXiv:2412.08905",
)
