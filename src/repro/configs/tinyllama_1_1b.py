"""TinyLlama 1.1B — llama2-architecture small model.

Assignment: [dense] 22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000
[arXiv:2401.02385]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    arch_type="dense",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=5632,
    vocab=32000,
    attn_kind="gqa",
    rope_theta=10_000.0,
    norm_eps=1e-5,
    serve_window=8192,          # long_500k serving variant only (DESIGN.md §6)
    source="arXiv:2401.02385",
)
