"""Core library: the paper's contribution (delay-optimal service-chain
forwarding and offloading in collaborative edge computing) as composable
JAX modules — network model, traffic/marginal computations, optimality
conditions, the GP algorithm, its baselines and its shard_map distribution.
"""

from repro.core.network import Instance, build_instance, table_ii_instance  # noqa: F401
from repro.core.traffic import Phi, flows, total_cost, renormalize  # noqa: F401
from repro.core.marginals import dD_dphi  # noqa: F401
from repro.core.conditions import kkt_residual, sufficiency_residual  # noqa: F401
from repro.core import baselines, chain, costs, gp  # noqa: F401
from repro.core import marginals as marginals_mod  # noqa: F401
