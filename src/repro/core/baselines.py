"""Baselines of Section V: SPOC, LCOF, LPR-SC.

All three are expressed as restrictions of the GP machinery (direction
masks), exactly mirroring their definitions:

  * SPOC  — forwarding fixed to the zero-flow shortest path toward d_a per
            stage; only the offloading split (CPU vs. next hop) is optimized.
  * LCOF  — all tasks computed at the data sources (phi_c forced for k<K);
            only the final-result forwarding (stage K) is optimized.
  * LPR-SC — the joint uncongested routing+offloading solution on the
            stage-expanded graph (zero-flow marginals), evaluated as-is;
            it ignores link congestion by construction ([16] extended to
            service chains).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import costs, gp
from repro.core.network import Instance
from repro.core.traffic import Phi, renormalize, total_cost


def _sp_next_hop_mask(inst: Instance) -> jnp.ndarray:
    """(A,K1,V,V) bool: the single shortest-path next hop toward d_a for
    each stage, measured with zero-flow marginals L_k * D'(0) (SPOC's
    'shortest path measured with marginal cost at F_ij = 0')."""
    Dp0 = jnp.where(
        inst.adj,
        costs.marginal(inst.link_kind, jnp.zeros_like(inst.link_param), inst.link_param),
        jnp.inf,
    )
    V = inst.V
    INF = jnp.float32(1e18)

    def per_app(L_a, dst_a):
        def per_stage(L_k):
            base = jnp.where(jnp.arange(V) == dst_a, 0.0, INF)
            wmat = L_k * Dp0 + 1e-5   # hop tie-break (see gp.expanded_shortest_path)

            def relax(dist, _):
                return jnp.minimum(dist, jnp.min(wmat + dist[None, :], axis=1)), None

            dist, _ = jax.lax.scan(relax, base, None, length=V)
            nxt = jnp.argmin(wmat + dist[None, :], axis=1)          # (V,)
            return jax.nn.one_hot(nxt, V, dtype=bool)

        return jax.vmap(per_stage)(L_a)

    return jax.vmap(per_app)(inst.L, inst.dst)


def spoc_masks(inst: Instance) -> tuple[jnp.ndarray, jnp.ndarray, Phi]:
    """SPOC as a pure direction-mask restriction: (allowed_e, allowed_c,
    phi0), all plain jax arrays — vmappable over ``batch.pad_instances``
    pytrees, which is how the batched baseline sweeps are built
    (``scenarios.run_sweep(..., masks_fn=spoc_masks)``).

    On a padded instance the real (node, app, stage) block is identical to
    the unpadded computation (dead nodes are unreachable at infinite
    zero-flow weight, and ``renormalize`` zeroes degenerate rows), so
    batched SPOC reproduces serial SPOC (tests/test_blocked_sets.py).
    """
    allowed_e = _sp_next_hop_mask(inst)
    # start from a feasible point inside the restriction: forward everything
    # along the shortest path, never compute...
    phi_e0 = allowed_e.astype(jnp.float32)
    phi0 = renormalize(inst, Phi(e=phi_e0, c=jnp.zeros_like(inst.r[:, None, :].repeat(inst.K1, 1))))
    # ... except that intermediate stages must eventually be computed for
    # the chain to terminate; seed a uniform offload split so every stage
    # carries finite traffic.
    phi0 = renormalize(
        inst,
        Phi(e=phi0.e * 0.5, c=jnp.where(inst.cpu_allowed()[:, :, None], 0.5, 0.0)),
    )
    # offloading is unrestricted for SPOC; an all-True mask is identical to
    # passing allowed_c=None but batches as a plain array
    allowed_c = jnp.ones((inst.A, inst.K1, inst.V), dtype=bool)
    return allowed_e, allowed_c, phi0


def spoc(inst: Instance, **solve_kwargs) -> gp.GPResult:
    """Shortest Path Optimal Computation placement."""
    allowed_e, allowed_c, phi0 = spoc_masks(inst)
    return gp.solve(inst, phi0, allowed_e=allowed_e, allowed_c=allowed_c,
                    **solve_kwargs)


def lcof_masks(inst: Instance) -> tuple[jnp.ndarray, jnp.ndarray, Phi]:
    """LCOF as a pure direction-mask restriction (see :func:`spoc_masks`)."""
    karr = jnp.arange(inst.K1)[None, :]
    last = karr == inst.n_tasks[:, None]                            # (A,K1)
    allowed_e = jnp.broadcast_to(
        last[:, :, None, None] & inst.adj[None, None],
        (inst.A, inst.K1, inst.V, inst.V),
    )
    allowed_c = jnp.broadcast_to(
        (~last)[:, :, None], (inst.A, inst.K1, inst.V)
    )
    phi_c0 = jnp.where(inst.cpu_allowed()[:, :, None], 1.0, 0.0)
    _, sp_phi = gp.expanded_shortest_path(inst)
    phi0 = renormalize(inst, Phi(e=jnp.where(last[:, :, None, None], sp_phi.e, 0.0), c=phi_c0))
    return allowed_e, allowed_c, phi0


def lcof(inst: Instance, **solve_kwargs) -> gp.GPResult:
    """Local Computation placement, Optimal Forwarding."""
    allowed_e, allowed_c, phi0 = lcof_masks(inst)
    return gp.solve(inst, phi0, allowed_e=allowed_e, allowed_c=allowed_c,
                    **solve_kwargs)


def lpr_sc(inst: Instance) -> gp.GPResult:
    """Linear-Program-Rounded for Service Chains (congestion-oblivious)."""
    _, phi = gp.expanded_shortest_path(inst)
    cost = total_cost(inst, phi)
    return gp.GPResult(phi=phi, cost_history=cost[None],
                       residual_history=jnp.zeros((0,)), iterations=0)


def fallback_strategy(inst: Instance, order: tuple = ("SPOC", "LCOF")):
    """Pick the first finite-cost baseline strategy — the degradation
    ladder's floor (DESIGN.md §17).

    Tries each mask constructor in ``order`` and returns
    ``(name, allowed_e, allowed_c, phi0, cost)`` for the first whose seed
    point already has a finite total cost on ``inst``; the online
    watchdog then runs a short *restricted* GP inside those masks.  SPOC
    leads because its shortest-path restriction tracks the optimum far
    closer than compute-at-source LCOF; LCOF is the backstop when the
    shortest path itself is saturated.  Returns None when no baseline is
    finite (the instance is unservable — e.g. a destination with no
    in-links), letting the caller keep its incumbent instead.
    """
    for name in order:
        allowed_e, allowed_c, phi0 = BASELINE_MASKS[name](inst)
        cost = total_cost(inst, phi0)
        if bool(jnp.isfinite(cost)):
            return name, allowed_e, allowed_c, phi0, float(cost)
    return None


ALL_BASELINES = {"SPOC": spoc, "LCOF": lcof, "LPR-SC": lpr_sc}

# Pure-mask constructors for the batched sweep drivers: each maps an
# Instance (possibly a padded batch member under jax.vmap) to
# (allowed_e, allowed_c, phi0) — see scenarios.run_sweep(masks_fn=...).
BASELINE_MASKS = {"SPOC": spoc_masks, "LCOF": lcof_masks}
