"""Instance padding & stacking: heterogeneous scenarios as one batched pytree.

The paper's evaluation (Figs. 5-7) is a statement about *families* of
scenarios — seven Table II topologies x input-rate scalings x random seeds.
To solve a family in one device program we pad every :class:`Instance` to a
common (V, A, K1) envelope and stack the results along a leading batch axis;
``jax.vmap(gp.solve_scan)`` then runs the whole family as one XLA executable.

Padding invariants (DESIGN.md §9 records the full argument):

  * **Dead nodes** (index >= the instance's true V): no adjacency, zero
    input rate, unit CPU capacity.  They receive zero traffic, so with
    D(0) = C(0) = 0 they contribute exactly nothing to the objective, and
    the per-stage linear systems stay nonsingular (their rows reduce to the
    identity).
  * **Dead applications / stages**: zero rate, ``stage_mask`` False, so
    ``renormalize`` forces their strategy rows to zero and ``cpu_allowed``
    excludes them from every direction set.
  * **Cost-family kinds are static metadata** and must agree across the
    batch (they select python-level code paths); mixed-kind families must be
    grouped by kind first (``scenarios.run_sweep`` does this automatically).

Under these invariants ``flows``, ``marginals`` and ``gp_step`` restricted
to the real (node, app, stage) block are identical to the unpadded
computation, so batched solves reproduce serial solves (tests/test_batch.py).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import network as network_mod
from repro.core.network import Instance
from repro.core.traffic import Phi

# Packet-size fill for padded stages — keep the same positive floor the
# builder applies to real stages (DESIGN.md §8) so padded entries can never
# introduce a zero-size degeneracy if a masked stage is ever touched.
_L_FILL = 0.01

# Heterogeneous-degree guard for sparse batches: padding every member's
# neighbor lists to the family max degree D costs O(V * D) per member, so a
# family mixing a near-regular metro graph with a hub-heavy one would
# silently densify the cheap members' sparse representation.  When the max
# over min member degree exceeds this ratio, ``pad_instances`` refuses
# (hetero_degree="raise", the default) unless the caller explicitly opts
# into padding ("pad") or falls back to dense ("strip").
_HETERO_DEGREE_RATIO = 4


def next_pow2(n: int) -> int:
    """Bucket quantizer shared by solver compaction (gp.solve_batched) and
    sweep size-class grouping (scenarios.run_sweep) — the two must agree."""
    return 1 << max(n - 1, 0).bit_length()


def _pad_axis(x: jnp.ndarray, axis: int, target: int, fill) -> jnp.ndarray:
    cur = x.shape[axis]
    if cur == target:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, target - cur)
    return jnp.pad(x, widths, constant_values=fill)


def _pad_degree(nbr: jnp.ndarray, mask: jnp.ndarray, D: int
                ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Pad neighbor-list columns to degree ``D`` (self-index, mask False)."""
    cur = int(nbr.shape[1])
    if cur == D:
        return nbr, mask
    n = nbr.shape[0]
    self_col = jnp.tile(jnp.arange(n, dtype=nbr.dtype)[:, None],
                        (1, D - cur))
    return (jnp.concatenate([nbr, self_col], axis=1),
            _pad_axis(mask, 1, D, False))


def pad_instance(inst: Instance, V: int, A: int, K1: int) -> Instance:
    """Pad one instance to the (V, A, K1) envelope (no batch axis yet).

    A sparse topology (``inst.has_sparse``) is re-derived from the padded
    adjacency: dead nodes are isolated (self-pointing all-masked neighbor
    rows), so the max degree — and the per-sweep O(E) work — is unchanged,
    only the row count grows to V.
    """
    if V < inst.V or A < inst.A or K1 < inst.K1:
        raise ValueError(
            f"target shape ({V},{A},{K1}) smaller than instance "
            f"({inst.V},{inst.A},{inst.K1})"
        )

    adj = _pad_axis(_pad_axis(inst.adj, 0, V, False), 1, V, False)
    link_param = _pad_axis(_pad_axis(inst.link_param, 0, V, 0.0), 1, V, 0.0)
    # dead nodes get unit CPU capacity: they carry zero workload, and
    # C(0) = 0 for every cost family, but a zero capacity would make the
    # queue family's marginal blow up at exactly 0 flow.
    comp_param = _pad_axis(inst.comp_param, 0, V, 1.0)
    wnode = _pad_axis(inst.wnode, 0, V, 1.0)

    L = _pad_axis(_pad_axis(inst.L, 1, K1, _L_FILL), 0, A, _L_FILL)
    w = _pad_axis(_pad_axis(inst.w, 1, K1, 0.0), 0, A, 0.0)
    r = _pad_axis(_pad_axis(inst.r, 1, V, 0.0), 0, A, 0.0)
    dst = _pad_axis(inst.dst, 0, A, 0)
    n_tasks = _pad_axis(inst.n_tasks, 0, A, 0)
    stage_mask = _pad_axis(_pad_axis(inst.stage_mask, 1, K1, False), 0, A, False)

    out = dataclasses.replace(
        inst, adj=adj, link_param=link_param, comp_param=comp_param,
        wnode=wnode, L=L, w=w, r=r, dst=dst, n_tasks=n_tasks,
        stage_mask=stage_mask,
    )
    if inst.has_sparse:
        out = network_mod.with_sparse(out)
    return out


def batch_envelope(insts: Sequence[Instance]) -> tuple[int, int, int]:
    """Common (V, A, K1) envelope of a scenario family."""
    return (
        max(i.V for i in insts),
        max(i.A for i in insts),
        max(i.K1 for i in insts),
    )


def pad_instances(insts: Sequence[Instance], *,
                  hetero_degree: str = "raise") -> Instance:
    """Stack heterogeneous instances into one Instance with a leading batch
    axis (every array field becomes ``(B, ...)``).

    Each member is first padded to the family envelope ``(V, A, K1) =
    (max V_i, max A_i, max K1_i)`` under the §9 invariants (dead nodes /
    apps / stages contribute exactly nothing), so e.g. ``adj`` becomes
    ``(B, V, V)``, ``r`` becomes ``(B, A, V)`` and ``stage_mask``
    ``(B, A, K1)``.  The result feeds ``jax.vmap(gp.solve_scan)`` or
    ``gp.solve_batched`` directly.

    All instances must share ``link_kind`` / ``comp_kind`` — these are
    static pytree metadata selecting python-level cost code, so they cannot
    vary along a traced batch axis (``scenarios.run_sweep`` groups by kind
    first).

    Sparse topologies (``with_sparse``) must be attached to *every* member
    or none — a mixed family raises (silent stripping would silently change
    solver dispatch).  Sparse members' neighbor lists are padded to the
    family max degree; when the family's degrees are very different
    (max > ``_HETERO_DEGREE_RATIO`` × min) that padding would densify the
    low-degree members' O(E) representation, so ``hetero_degree`` governs
    it explicitly: ``"raise"`` (default) refuses, ``"pad"`` pads anyway
    (opt-in, the batch stays sparse but low-degree members pay the hub
    member's D), ``"strip"`` falls back to the dense-only representation
    for the whole family.

    Example::

        >>> insts = [network.table_ii_instance("abilene", seed=s)
        ...          for s in range(8)]
        >>> binst = batch.pad_instances(insts)
        >>> binst.adj.shape, binst.r.shape
        ((8, 11, 11), (8, 3, 11))
        >>> scan = gp.solve_batched(binst, alpha=0.1)   # one device program
    """
    if not insts:
        raise ValueError("pad_instances needs at least one instance")
    if hetero_degree not in ("raise", "pad", "strip"):
        raise ValueError(
            f"hetero_degree must be 'raise'|'pad'|'strip', got {hetero_degree!r}")
    kinds = {(i.link_kind, i.comp_kind) for i in insts}
    if len(kinds) > 1:
        raise ValueError(
            f"cannot batch across cost families {sorted(kinds)}; group "
            "instances by (link_kind, comp_kind) first"
        )
    flags = {i.has_sparse for i in insts}
    if flags == {True, False}:
        raise ValueError(
            "cannot batch a mix of sparse and dense members; attach "
            "network.with_sparse to every member or strip it from all "
            "(network.without_sparse)"
        )
    sparse = flags == {True}
    if sparse:
        degs = [max(1, int(i.max_degree)) for i in insts]
        if max(degs) > _HETERO_DEGREE_RATIO * min(degs):
            if hetero_degree == "strip":
                insts = [network_mod.without_sparse(i) for i in insts]
                sparse = False
            elif hetero_degree == "raise":
                raise ValueError(
                    f"heterogeneous max degrees {min(degs)}..{max(degs)} "
                    f"(> {_HETERO_DEGREE_RATIO}x spread): padding would "
                    "densify the sparse representation. Pass "
                    "hetero_degree='pad' to pad anyway or 'strip' to fall "
                    "back to dense."
                )
            # "pad": explicit opt-in, fall through to degree padding below
    V, A, K1 = batch_envelope(insts)
    padded = [pad_instance(i, V, A, K1) for i in insts]
    if sparse:
        D = max(int(p.out_nbr.shape[1]) for p in padded)
        BD = max(int(p.blk_nbr.shape[1]) for p in padded)
        padded = [
            dataclasses.replace(
                p,
                **dict(zip(("out_nbr", "out_mask"),
                           _pad_degree(p.out_nbr, p.out_mask, D))),
                **dict(zip(("in_nbr", "in_mask"),
                           _pad_degree(p.in_nbr, p.in_mask, D))),
                **dict(zip(("blk_nbr", "blk_mask"),
                           _pad_degree(p.blk_nbr, p.blk_mask, BD))),
            )
            for p in padded
        ]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *padded)


def batch_size(binst: Instance) -> int:
    """Leading batch-axis length of a stacked Instance."""
    return int(binst.adj.shape[0])


def instance_slice(binst: Instance, b: int) -> Instance:
    """Extract padded member ``b`` of a stacked Instance (still padded)."""
    return jax.tree_util.tree_map(lambda x: x[b], binst)


def pad_phi(phi: Phi, V: int, A: int, K1: int,
            inst: Optional[Instance] = None) -> Phi:
    """Pad a strategy to the (V, A, K1) envelope.

    Padded rows are zero, which is exactly right for every degenerate row
    (dead apps/stages, final stages at forwarding-dead nodes).  The one
    non-degenerate padded row class is (real app, non-final stage, dead
    node) — constraint (1) wants those to sum to 1 even though they carry
    zero traffic.  When ``inst`` (the unpadded instance) is given, those
    rows are seeded with full local offloading (phi_c = 1), matching what
    ``init_phi`` converges to there and keeping the padded strategy
    feasible everywhere.
    """
    V0 = phi.e.shape[2]
    e = phi.e
    for axis, tgt in ((0, A), (1, K1), (2, V), (3, V)):
        e = _pad_axis(e, axis, tgt, 0.0)
    c = phi.c
    for axis, tgt in ((0, A), (1, K1), (2, V)):
        c = _pad_axis(c, axis, tgt, 0.0)
    if inst is not None and V > V0:
        dead = jnp.arange(V)[None, None, :] >= V0                 # (1,1,V)
        cpu_ok = _pad_axis(_pad_axis(
            inst.cpu_allowed(), 1, K1, False), 0, A, False)       # (A,K1)
        c = jnp.where(dead & cpu_ok[:, :, None], 1.0, c)
    return Phi(e=e, c=c)


def pad_phis(phis: Sequence[Phi], insts: Sequence[Instance]) -> Phi:
    """Stack per-instance strategies to match ``pad_instances(insts)``."""
    V, A, K1 = batch_envelope(insts)
    padded = [pad_phi(p, V, A, K1, inst) for p, inst in zip(phis, insts)]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *padded)


def unpad_phi(phi: Phi, inst: Instance) -> Phi:
    """Strip padding back to an instance's true (V, A, K1)."""
    A, K1, V = inst.A, inst.K1, inst.V
    return Phi(e=phi.e[:A, :K1, :V, :V], c=phi.c[:A, :K1, :V])


def valid_mask(binst: Instance, insts: Sequence[Instance]) -> np.ndarray:
    """(B, V) bool: which nodes of each padded member are real."""
    B, V = batch_size(binst), int(binst.adj.shape[1])
    mask = np.zeros((B, V), dtype=bool)
    for b, inst in enumerate(insts):
        mask[b, : inst.V] = True
    return mask
