"""Service chains, including chains derived from DNN vertical splits.

The paper's motivating application (Section I, Fig. 1) is a service chain
of sequential tasks; its headline use case is "DNN with vertical split".
``chain_from_arch`` makes that concrete for the 10 assigned architectures:
the layer stack of a model config is cut into ``n_segments`` tasks, the
inter-segment activation byte-rate gives the stage packet sizes
``L_(a,k)``, and the per-segment FLOP count gives the computation weights
``w(a,k)``.  The resulting applications drive the GP optimizer exactly like
the paper's synthetic chains.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.network import QUEUE, Instance


@dataclasses.dataclass(frozen=True)
class ChainProfile:
    """One service-chain application, in network units.

    L[k]  — packet size (bits per request-packet) of stage k, k = 0..K
    w[k]  — computation workload per packet for task k+1 (w[K] unused)
    """

    name: str
    L: np.ndarray
    w: np.ndarray

    @property
    def n_tasks(self) -> int:
        return len(self.L) - 1


def chain_from_arch(
    cfg,
    *,
    n_segments: int = 3,
    tokens_per_packet: int = 128,
    flops_unit: float = 1e12,
    bits_unit: float = 1e6,
) -> ChainProfile:
    """Vertical-split service chain for a model config.

    cfg is a ``repro.configs.base.ModelConfig``.  Stage-0 packets are token
    ids (or frame/patch embeddings for audio/VLM); stages 1..K-1 are the
    residual-stream activations between segments; stage K is the output
    logits-argmax (tiny).  Workloads are the analytic segment FLOPs (the
    same model the roofline uses), expressed in ``flops_unit``; packet
    sizes in ``bits_unit``.
    """
    from repro.models.flops import layer_flops, embed_bits_per_token

    act_bits = cfg.d_model * 16 * tokens_per_packet          # bf16 residual
    in_bits = embed_bits_per_token(cfg) * tokens_per_packet
    out_bits = 32 * tokens_per_packet                        # token ids out

    per_layer = layer_flops(cfg, seq_len=tokens_per_packet) / tokens_per_packet
    bounds = np.linspace(0, cfg.n_layers, n_segments + 1).round().astype(int)
    seg_layers = np.diff(bounds)

    L = np.empty(n_segments + 1)
    L[0] = in_bits / bits_unit
    L[1:n_segments] = act_bits / bits_unit
    L[n_segments] = out_bits / bits_unit
    w = np.zeros(n_segments + 1)
    w[:n_segments] = seg_layers * per_layer * tokens_per_packet / flops_unit
    return ChainProfile(name=cfg.name, L=L, w=w)


def instance_from_chains(
    adj: np.ndarray,
    chains: Sequence[ChainProfile],
    *,
    sources: Sequence[Sequence[int]],
    rates: Sequence[Sequence[float]],
    dests: Sequence[int],
    link_capacity: float | np.ndarray = 100.0,
    comp_capacity: float | np.ndarray = 50.0,
    link_kind: int = QUEUE,
    comp_kind: int = QUEUE,
    wnode: np.ndarray | None = None,
) -> Instance:
    """Build an Instance whose applications are the given chains."""
    V = adj.shape[0]
    A = len(chains)
    K1 = max(c.n_tasks for c in chains) + 1

    L = np.zeros((A, K1))
    w = np.zeros((A, K1))
    stage_mask = np.zeros((A, K1), dtype=bool)
    n_tasks = np.zeros(A, dtype=np.int64)
    r = np.zeros((A, V))
    for a, c in enumerate(chains):
        k1 = c.n_tasks + 1
        L[a, :k1] = c.L
        w[a, :k1] = c.w
        stage_mask[a, :k1] = True
        n_tasks[a] = c.n_tasks
        for s, rate in zip(sources[a], rates[a]):
            r[a, s] += rate

    link_param = np.where(adj, np.broadcast_to(np.asarray(link_capacity, dtype=float), (V, V)), 0.0)
    comp_param = np.broadcast_to(np.asarray(comp_capacity, dtype=float), (V,))

    return Instance(
        adj=jnp.asarray(adj),
        link_param=jnp.asarray(link_param, dtype=jnp.float32),
        link_kind=link_kind,
        comp_param=jnp.asarray(comp_param, dtype=jnp.float32),
        comp_kind=comp_kind,
        L=jnp.asarray(L, dtype=jnp.float32),
        w=jnp.asarray(w, dtype=jnp.float32),
        wnode=jnp.asarray(wnode if wnode is not None else np.ones(V), dtype=jnp.float32),
        r=jnp.asarray(r, dtype=jnp.float32),
        dst=jnp.asarray(dests),
        n_tasks=jnp.asarray(n_tasks),
        stage_mask=jnp.asarray(stage_mask),
    )
