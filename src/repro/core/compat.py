"""Version-adaptive shims over JAX APIs that moved between releases.

The repo targets the public post-0.6 spellings (``jax.shard_map`` with
``check_vma``, ``jax.make_mesh(..., axis_types=...)``); on older runtimes
(e.g. 0.4.x, where shard_map lives in ``jax.experimental`` and takes
``check_rep``, and ``make_mesh`` has no ``axis_types``) these helpers fall
back to the equivalent legacy call so the same call sites run everywhere.
"""

from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):           # jax >= 0.6 spelling

    def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)

else:                                    # 0.4.x: experimental, check_rep
    from jax.experimental.shard_map import shard_map as _shard_map_legacy

    def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
        return _shard_map_legacy(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check)


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with Auto axis types where the runtime supports
    them (newer jax made Explicit sharding opt-in per axis; older versions
    have no ``axis_types`` parameter and are Auto-only anyway)."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            tuple(axis_shapes), tuple(axis_names),
            axis_types=(jax.sharding.AxisType.Auto,) * len(tuple(axis_names)),
        )
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))
