"""Optimality-condition checkers: KKT (5) and the sufficiency condition (6).

Both conditions compare per-direction marginals against the per-(i,a,k)
minimum:

  * KKT (5) uses dD/dphi_ij = t_i * delta_ij  — necessary only, degenerate
    (automatically satisfied) wherever t_i(a,k) = 0 (Proposition 1).
  * Sufficiency (6) uses the modified marginals delta_ij directly — if it
    holds everywhere, phi is globally optimal (Theorem 1).

The checkers return a *residual*: the largest amount by which a direction
carrying flow exceeds the minimum marginal.  A strategy satisfies the
condition iff its residual is ~0.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.marginals import BIG, marginals
from repro.core.network import Instance
from repro.core.traffic import Phi, flows


def _residual(min_margin, margin_e, margin_c, phi: Phi, active_eps: float) -> jnp.ndarray:
    """Max excess (margin - min) over directions with phi > active_eps."""
    exc_e = jnp.where(phi.e > active_eps, margin_e - min_margin[..., None], 0.0)
    exc_c = jnp.where(phi.c > active_eps, margin_c - min_margin, 0.0)
    return jnp.maximum(jnp.max(exc_e), jnp.max(exc_c))


def kkt_residual(inst: Instance, phi: Phi, active_eps: float = 1e-6) -> jnp.ndarray:
    """Residual of the KKT necessary condition (5).  0 <=> (5) holds."""
    fl = flows(inst, phi)
    m = marginals(inst, phi, fl)
    ge = fl.t[..., None] * jnp.where(m.delta_e < BIG, m.delta_e, 0.0)
    gc = fl.t * jnp.where(m.delta_c < BIG, m.delta_c, 0.0)
    ge = jnp.where(m.delta_e < BIG, ge, BIG)
    gc = jnp.where(m.delta_c < BIG, gc, BIG)
    min_margin = jnp.minimum(ge.min(-1), gc)                 # (A,K1,V)
    return _residual(min_margin, ge, gc, phi, active_eps)


def sufficiency_residual(inst: Instance, phi: Phi, active_eps: float = 1e-6) -> jnp.ndarray:
    """Residual of the sufficiency condition (6).  0 <=> global optimum."""
    m = marginals(inst, phi)
    min_margin = jnp.minimum(m.delta_e.min(-1), m.delta_c)   # (A,K1,V)
    return _residual(min_margin, m.delta_e, m.delta_c, phi, active_eps)


def per_app_residual(inst: Instance, phi: Phi,
                     active_eps: float = 1e-6) -> jnp.ndarray:
    """(A,) sufficiency residual of condition (6), reduced per application.

    Same excess as :func:`sufficiency_residual` — marginals are computed
    under the *global* flows F/G, so an application's residual reflects the
    congestion every other application imposes on it — but the max is taken
    only over that application's own (k, i, j) directions.  An entry of ~0
    certifies the application's strategy is stationary given everyone
    else's; this is the skip gate the online solver uses to avoid
    re-solving applications an event did not disturb
    (``serve/online.py``).  Applications with no active directions (dead /
    padded rows) report exactly 0.
    """
    m = marginals(inst, phi)
    min_margin = jnp.minimum(m.delta_e.min(-1), m.delta_c)   # (A,K1,V)
    exc_e = jnp.where(phi.e > active_eps,
                      m.delta_e - min_margin[..., None], 0.0)
    exc_c = jnp.where(phi.c > active_eps, m.delta_c - min_margin, 0.0)
    return jnp.maximum(exc_e.max(axis=(1, 2, 3)), exc_c.max(axis=(1, 2)))


def satisfies_sufficiency(inst: Instance, phi: Phi, tol: float = 1e-3) -> bool:
    return bool(sufficiency_residual(inst, phi) <= tol)


def satisfies_kkt(inst: Instance, phi: Phi, tol: float = 1e-3) -> bool:
    return bool(kkt_residual(inst, phi) <= tol)
