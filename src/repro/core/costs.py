"""Congestion-dependent link/computation cost families (Section II).

The paper requires cost functions that are increasing, continuously
differentiable and convex with D(0) = 0.  We provide:

  * LINEAR:  D(F) = d * F          (pure transmission delay)
  * QUEUE:   D(F) = F / (d - F)    (M/M/1 expected queue occupancy)

The M/M/1 family is only finite for F < d.  Following standard practice in
flow-level optimization (and so that *any* feasible phi has finite cost and
finite gradients — needed by the GP algorithm to recover from congested
iterates), we extend it above ``theta * d`` with its second-order Taylor
model, which keeps the extension C^1, convex, and increasing.  This is an
implementation detail, not a model change: at the optimum all flows lie in
the un-extended region whenever the instance is feasible.
"""

from __future__ import annotations

import jax.numpy as jnp

LINEAR = 0
QUEUE = 1

# Fraction of capacity above which the M/M/1 cost switches to its quadratic
# Taylor extension.
_THETA = 0.98


# Taylor data at the knee F = theta*cap, written with the cap powers
# cancelled analytically so no intermediate under/overflows in float32
# (cap can be ~0 on non-links):
#   value  v  = theta / (1-theta)
#   slope  d1 = 1 / (cap (1-theta)^2)
#   curv   d2 = 2 / (cap^2 (1-theta)^3)
_V_KNEE = _THETA / (1.0 - _THETA)
_S1 = 1.0 / (1.0 - _THETA) ** 2
_S2 = 2.0 / (1.0 - _THETA) ** 3


def _queue_cost(F: jnp.ndarray, cap: jnp.ndarray) -> jnp.ndarray:
    """M/M/1 queue length F/(cap-F), quadratically extended above theta*cap."""
    cap = jnp.maximum(cap, 1e-6)
    knee = _THETA * cap
    inside = F / jnp.maximum(cap - F, 1e-12)
    u = (F - knee) / cap                      # normalized overload
    outside = _V_KNEE + _S1 * u + 0.5 * _S2 * u * u
    return jnp.where(F <= knee, inside, outside)


def _queue_marginal(F: jnp.ndarray, cap: jnp.ndarray) -> jnp.ndarray:
    cap = jnp.maximum(cap, 1e-6)
    knee = _THETA * cap
    inside = cap / jnp.maximum(cap - F, 1e-12) ** 2
    u = (F - knee) / cap
    outside = (_S1 + _S2 * u) / cap
    return jnp.where(F <= knee, inside, outside)


def cost(kind: int, F: jnp.ndarray, param: jnp.ndarray) -> jnp.ndarray:
    """Elementwise cost D(F) (or C(G)) for the given family."""
    if kind == LINEAR:
        return param * F
    if kind == QUEUE:
        return _queue_cost(F, param)
    raise ValueError(f"unknown cost kind {kind}")


def marginal(kind: int, F: jnp.ndarray, param: jnp.ndarray) -> jnp.ndarray:
    """Elementwise marginal cost D'(F) for the given family."""
    if kind == LINEAR:
        return param * jnp.ones_like(F)
    if kind == QUEUE:
        return _queue_marginal(F, param)
    raise ValueError(f"unknown cost kind {kind}")


def saturated(kind: int, F: jnp.ndarray, param: jnp.ndarray) -> jnp.ndarray:
    """Bool mask of links/CPUs operating beyond the modelled region."""
    if kind == LINEAR:
        return jnp.zeros_like(F, dtype=bool)
    return F > _THETA * param
