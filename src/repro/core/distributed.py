"""Device-parallel GP via shard_map (the paper's protocol on a TPU mesh).

The paper's Algorithm 1 is node-parallel: every network node updates its own
phi_i from locally measurable marginals plus a broadcast.  On an accelerator
mesh the natural data-parallel axis is the *application* axis — each device
owns a contiguous slab of applications (and all their chain stages, so the
stage-(k) -> stage-(k+1) coupling never crosses devices), computes their
traffic and marginal recursions locally, and the only cross-device coupling
is the network-wide flow measurement:

  1. total link flows F_ij = sum_apps L * f     -> jax.lax.psum
  2. total workloads  G_i  = sum_apps w * g     -> jax.lax.psum

This mirrors the paper's measurement model exactly: every node measures the
*total* F_ij and G_i on its links/CPU (an implicit all-reduce over flows in
the real network), while the per-stage marginal broadcast stays within the
application's owner device.  Per-iteration collective volume: 2 x (V^2 + V)
floats per ladder rung — independent of |A| and |S| — matching the paper's
claim that control overhead scales with the network size, not the task
count.

This module contains NO GP-step math of its own: it is a mesh adapter over
the ONE shared step core (:mod:`repro.core.engine`, DESIGN.md §14).  The
engine's ``scan_chunk`` — identical to the one ``gp.solve`` jits — is traced
inside ``shard_map`` with ``axis`` bound to the app-shard mesh axis, so the
mesh path runs the same fused kernels (batched-LU stage factors, fused
chain sweeps, bitset blocked sets, the stepsize ladder) and the host loop
reads back only the ``done`` latch once per chunk, exactly like the
single-device chunked driver.

Two entry points:

  * :func:`solve_sharded`          — one Instance, apps sharded over the mesh.
  * :func:`solve_sharded_batched`  — a ``batch.pad_instances`` family; the
    member axis is vmapped INSIDE each shard (vmap-of-shard_map), so a
    scenario sweep composes the §9/§10 batch machinery with the mesh
    (``scenarios.run_sweep(mesh=...)``).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import compat, engine, gp
from repro.core.network import Instance
from repro.core.traffic import Phi

# Host reads the done latch once per chunk, at the same cadence as
# gp.solve — one source of truth so the two drivers' chunk-length cache
# keys stay aligned.
_CHUNK = gp._SOLVE_CHUNK


def _pad_apps(inst: Instance, n_shards: int, *, batched: bool = False
              ) -> tuple[Instance, int]:
    """Pad the application axis to a multiple of n_shards with dead apps.

    Dead apps carry zero rate and an all-False ``stage_mask``, so they are
    degenerate everywhere (§9 invariants) and contribute exactly nothing to
    the measured F/G.  ``batched=True`` pads axis 1 of a stacked
    ``pad_instances`` pytree instead of axis 0.
    """
    ax = 1 if batched else 0
    A = int(inst.L.shape[ax])
    A_pad = -(-A // n_shards) * n_shards
    if A_pad == A:
        return inst, A
    pad = A_pad - A

    def padA(x, fill=0):
        widths = [(0, 0)] * x.ndim
        widths[ax] = (0, pad)
        return jnp.pad(x, widths, constant_values=fill)

    return dataclasses.replace(
        inst,
        L=padA(inst.L),
        w=padA(inst.w),
        r=padA(inst.r),
        dst=padA(inst.dst),
        n_tasks=padA(inst.n_tasks),
        stage_mask=padA(inst.stage_mask, fill=False),
    ), A


def _pad_tree_apps(tree, A_pad: int, *, batched: bool = False):
    """Zero-pad the app axis of a Phi / mask pytree to ``A_pad`` entries."""
    ax = 1 if batched else 0
    if tree is None:
        return None

    def padA(x):
        pad = A_pad - x.shape[ax]
        if pad == 0:
            return x
        widths = [(0, 0)] * x.ndim
        widths[ax] = (0, pad)
        return jnp.pad(x, widths)

    return jax.tree_util.tree_map(padA, tree)


@functools.lru_cache(maxsize=None)
def _chunk_program(mesh: Mesh, axis: str, link_kind: int, comp_kind: int,
                   length: int, scaled: bool, solver: str, blocked: str,
                   has_masks: bool, accel=None):
    """Build the jitted shard_map'd chunk for one (mesh, config) combination.

    The stacked Instance is decomposed into per-application (app-sharded)
    and network-level (replicated) arrays so the shard_map specs stay
    simple; each shard reassembles its local Instance, vmaps
    :func:`engine.scan_chunk` over the member axis, and the ``axis``-bound
    collectives inside the engine provide the network-wide measurement.
    Cached so each chunk length compiles once per mesh; ``accel`` (a
    resolved hashable :class:`engine.AccelConfig` or None) is part of the
    cache key, like ``solver``/``blocked``.

    The §15 Anderson ring buffers travel as *opaque per-shard slabs*: the
    flat feature axis of ``ax``/``af`` is sharded (``P(None, None, axis)``)
    into slices exactly the size of each shard's locally flattened phi, and
    a slice is only ever produced and consumed by the same shard — the
    global buffer layout is never interpreted.  The adaptive ``alpha`` and
    history count ``ak`` are replicated (the winning rung and the push
    cadence are shard-identical by construction).
    """
    app = P(None, axis)     # (B, A, ...): member axis plain, apps sharded
    buf = P(None, None, axis)   # (B, m, N): Anderson slab, N axis sharded
    rep = P()

    def chunk(L, w, r, dst, n_tasks, stage_mask,          # app-sharded
              adj, link_param, comp_param, wnode,         # replicated
              phi_e, phi_c,                               # app-sharded carry
              best_cost, stall, done, iters, cost, residual,
              aalpha, ax, af, ak,                         # accel carry (§15)
              alpha, tol, patience, max_iters, *masks):

        def one(L, w, r, dst, n_tasks, stage_mask, adj, link_param,
                comp_param, wnode, phi_e, phi_c, best_cost, stall, done,
                iters, cost, residual, aalpha, ax, af, ak, ae, ac):
            inst_l = Instance(
                adj=adj, link_param=link_param, link_kind=link_kind,
                comp_param=comp_param, comp_kind=comp_kind,
                L=L, w=w, wnode=wnode, r=r, dst=dst, n_tasks=n_tasks,
                stage_mask=stage_mask,
            )
            carry = engine.ScanCarry(
                phi=Phi(e=phi_e, c=phi_c), best_cost=best_cost, stall=stall,
                done=done, iters=iters, cost=cost, residual=residual,
                alpha=aalpha, ax=ax, af=af, ak=ak,
            )
            carry, (cs, rs) = engine.scan_chunk(
                inst_l, carry, alpha, tol, patience, max_iters, ae, ac,
                length=length, scaled=scaled, solver=solver, blocked=blocked,
                axis=axis, accel=accel,
            )
            return (carry.phi.e, carry.phi.c, carry.best_cost, carry.stall,
                    carry.done, carry.iters, carry.cost, carry.residual,
                    carry.alpha, carry.ax, carry.af, carry.ak,
                    cs, rs)

        ae, ac = masks if has_masks else (None, None)
        in_axes = (0,) * 22 + ((0, 0) if has_masks else (None, None))
        return jax.vmap(one, in_axes=in_axes)(
            L, w, r, dst, n_tasks, stage_mask, adj, link_param, comp_param,
            wnode, phi_e, phi_c, best_cost, stall, done, iters, cost,
            residual, aalpha, ax, af, ak, ae, ac)

    in_specs = ((app,) * 6 + (rep,) * 4 + (app, app) + (rep,) * 6
                + (rep, buf, buf, rep)
                + (rep,) * 4 + ((app, app) if has_masks else ()))
    out_specs = ((app, app) + (rep,) * 6 + (rep, buf, buf, rep)
                 + (rep, rep))
    smapped = compat.shard_map(chunk, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, check=False)
    return jax.jit(smapped)


def solve_sharded_batched(
    binst: Instance,
    mesh: Mesh,
    *,
    axis: str = "stage",
    alpha: float = 0.02,
    max_iters: int = 300,
    tol: float = 1e-4,
    patience: int = 40,
    phi0: Phi | None = None,
    allowed_e: jnp.ndarray | None = None,
    allowed_c: jnp.ndarray | None = None,
    scaled: bool = False,
    solver: str = "auto",
    blocked: str = "bitset",
    accel=None,
) -> gp.GPScan:
    """Solve a padded scenario family with applications sharded over `axis`.

    ``binst`` is a ``batch.pad_instances`` pytree (leading member axis B);
    inside each shard the member axis is vmapped over the SAME chunked
    done-latch scan ``gp.solve`` runs (``engine.scan_chunk``), so large
    ensembles spread their per-member app slabs across the mesh while the
    host reads back only the batched ``done`` latch once per ``_CHUNK``
    iterations.  No convergence compaction on this path (members stay in
    their mesh lanes); histories follow the dense :class:`gp.GPScan`
    contract.  ``solver=``/``blocked=``/``accel=`` dispatch exactly as in
    ``gp.solve`` (accelerated sharded trajectories match the accelerated
    single-device ones — tests/test_accel.py).
    """
    accel = engine.resolve_accel(accel)
    n_shards = mesh.shape[axis]
    B = int(binst.adj.shape[0])
    binst_p, A_orig = _pad_apps(binst, n_shards, batched=True)
    A_pad = int(binst_p.L.shape[1])
    if phi0 is None:
        phi0 = jax.vmap(gp.init_phi)(binst_p)
    else:
        phi0 = _pad_tree_apps(phi0, A_pad, batched=True)
    allowed_e = _pad_tree_apps(allowed_e, A_pad, batched=True)
    allowed_c = _pad_tree_apps(allowed_c, A_pad, batched=True)
    has_masks = allowed_e is not None or allowed_c is not None
    if has_masks and (allowed_e is None or allowed_c is None):
        raise ValueError("pass both allowed_e and allowed_c, or neither")

    carry = jax.vmap(
        lambda i, p: engine.init_carry(i, p, accel=accel))(binst_p, phi0)
    alpha_, tol_ = jnp.float32(alpha), jnp.float32(tol)
    patience_, max_iters_ = jnp.int32(patience), jnp.int32(max_iters)

    cost_hist = np.zeros((B, max_iters + 1), np.float32)
    cost_hist[:, 0] = np.asarray(carry.cost)
    res_hist = np.zeros((B, max_iters), np.float32)

    c = carry
    steps = 0
    while steps < max_iters:
        length = min(_CHUNK, max_iters - steps)
        fn = _chunk_program(mesh, axis, binst_p.link_kind, binst_p.comp_kind,
                            length, scaled, solver, blocked, has_masks,
                            accel)
        mask_args = (allowed_e, allowed_c) if has_masks else ()
        (phi_e, phi_c, best, stall, done, iters, cost, residual,
         aalpha, ax, af, ak, cs, rs
         ) = fn(binst_p.L, binst_p.w, binst_p.r, binst_p.dst,
                binst_p.n_tasks, binst_p.stage_mask, binst_p.adj,
                binst_p.link_param, binst_p.comp_param, binst_p.wnode,
                c.phi.e, c.phi.c, c.best_cost, c.stall, c.done, c.iters,
                c.cost, c.residual, c.alpha, c.ax, c.af, c.ak,
                alpha_, tol_, patience_, max_iters_,
                *mask_args)
        c = engine.ScanCarry(phi=Phi(e=phi_e, c=phi_c), best_cost=best,
                             stall=stall, done=done, iters=iters, cost=cost,
                             residual=residual, alpha=aalpha, ax=ax, af=af,
                             ak=ak)
        cost_hist[:, steps + 1: steps + 1 + length] = np.asarray(cs)
        res_hist[:, steps: steps + length] = np.asarray(rs)
        steps += length
        if bool(np.asarray(done).all()):
            break

    # dense-history contract: repeat converged values past the last chunk
    cost_hist[:, steps + 1:] = cost_hist[:, steps: steps + 1]
    if steps > 0:
        res_hist[:, steps:] = res_hist[:, steps - 1: steps]

    phi = Phi(e=jnp.asarray(np.asarray(c.phi.e)[:, :A_orig]),
              c=jnp.asarray(np.asarray(c.phi.c)[:, :A_orig]))
    return gp.GPScan(
        phi=phi, cost=c.cost, residual=c.residual,
        cost_history=jnp.asarray(cost_hist),
        residual_history=jnp.asarray(res_hist),
        iterations=c.iters,
    )


def solve_sharded(
    inst: Instance,
    mesh: Mesh,
    *,
    axis: str = "stage",
    alpha: float = 0.02,
    max_iters: int = 300,
    tol: float = 1e-4,
    patience: int = 40,
    phi0: Phi | None = None,
    allowed_e: jnp.ndarray | None = None,
    allowed_c: jnp.ndarray | None = None,
    scaled: bool = False,
    solver: str = "auto",
    blocked: str = "bitset",
    accel=None,
) -> gp.GPResult:
    """Run GP with applications sharded across a device mesh axis.

    The B=1 member of :func:`solve_sharded_batched`: the same fused step
    engine ``gp.solve`` runs, traced under ``shard_map`` with the F/G
    measurement psum-reduced over ``axis`` — cost trajectories match the
    single-device solve (tests/test_distributed.py asserts ≤1e-4 over
    ≥2 shards).  Returns a trimmed :class:`gp.GPResult`.
    """
    lift = lambda t: jax.tree_util.tree_map(lambda x: jnp.asarray(x)[None], t)
    scan = solve_sharded_batched(
        lift(inst), mesh, axis=axis, alpha=alpha, max_iters=max_iters,
        tol=tol, patience=patience,
        phi0=None if phi0 is None else lift(phi0),
        allowed_e=None if allowed_e is None else lift(allowed_e),
        allowed_c=None if allowed_c is None else lift(allowed_c),
        scaled=scaled, solver=solver, blocked=blocked, accel=accel)
    member = jax.tree_util.tree_map(lambda x: x[0], scan)
    return gp.GPResult(
        phi=member.phi, cost_history=member.cost_history,
        residual_history=member.residual_history,
        iterations=int(member.iterations),
    ).trim()
