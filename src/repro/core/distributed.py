"""Device-parallel GP via shard_map (the paper's protocol on a TPU mesh).

The paper's Algorithm 1 is node-parallel: every network node updates its own
phi_i from locally measurable marginals plus a broadcast.  On an accelerator
mesh the natural data-parallel axis is the *application* axis — each device
owns a contiguous slab of applications (and all their chain stages, so the
stage-(k) -> stage-(k+1) coupling never crosses devices), computes their
traffic and marginal recursions locally, and the only cross-device coupling
is the network-wide flow measurement:

  1. total link flows F_ij = sum_apps L * f     -> jax.lax.psum
  2. total workloads  G_i  = sum_apps w * g     -> jax.lax.psum

This mirrors the paper's measurement model exactly: every node measures the
*total* F_ij and G_i on its links/CPU (an implicit all-reduce over flows in
the real network), while the per-stage marginal broadcast stays within the
application's owner device.

Per-iteration collective volume: 2 x (V^2 + V) floats — independent of |A|
and |S| — matching the paper's claim that control overhead scales with the
network size, not the task count.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import compat, costs, gp
from repro.core.marginals import BIG
from repro.core.network import Instance
from repro.core.traffic import (
    Phi, comp_marginals, link_marginals, renormalize, stage_traffic,
)
from repro.core.marginals import pdt_recursion


def _pad_apps(inst: Instance, n_shards: int) -> tuple[Instance, int]:
    """Pad the application axis to a multiple of n_shards with zero apps."""
    A = inst.A
    A_pad = -(-A // n_shards) * n_shards
    if A_pad == A:
        return inst, A
    pad = A_pad - A

    def padA(x, fill=0):
        widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
        return jnp.pad(x, widths, constant_values=fill)

    return dataclasses.replace(
        inst,
        L=padA(inst.L),
        w=padA(inst.w),
        r=padA(inst.r),
        dst=padA(inst.dst),
        n_tasks=padA(inst.n_tasks),
        stage_mask=padA(inst.stage_mask, fill=False),
    ), A


def sharded_gp_step(mesh: Mesh, inst_template: Instance, axis: str = "stage"):
    """Build a shard_mapped GP iteration with applications sharded on `axis`.

    The Instance is decomposed into per-application (sharded) arrays and
    network-level (replicated) arrays to keep shard_map specs simple; the
    local Instance is reassembled inside each shard.
    """
    link_kind, comp_kind = inst_template.link_kind, inst_template.comp_kind
    app = P(axis)
    rep = P()

    def step(L, w, r, dst, n_tasks, stage_mask,          # sharded over apps
             adj, link_param, comp_param, wnode,         # replicated
             phi_e, phi_c, alpha):
        inst_l = Instance(
            adj=adj, link_param=link_param, link_kind=link_kind,
            comp_param=comp_param, comp_kind=comp_kind,
            L=L, w=w, wnode=wnode, r=r, dst=dst, n_tasks=n_tasks,
            stage_mask=stage_mask,
        )
        phi = Phi(e=phi_e, c=phi_c)

        # --- local traffic for this shard's applications ---
        t, g = stage_traffic(inst_l, phi)
        f = t[..., None] * phi.e
        F_local = jnp.einsum("ak,akij->ij", L, f)
        G_local = jnp.einsum("ak,aki->i", w, g) * wnode

        # --- the network-wide measurement: all-reduce over app shards ---
        F = jax.lax.psum(F_local, axis)
        G = jax.lax.psum(G_local, axis)

        Dp = link_marginals(inst_l, F)
        Cp = comp_marginals(inst_l, G)

        # --- per-stage marginal broadcast stays local ---
        pdt = pdt_recursion(inst_l, phi, Dp, Cp)
        delta_e = L[:, :, None, None] * Dp[None, None] + pdt[:, :, None, :]
        delta_e = jnp.where(adj[None, None], delta_e, BIG)
        pdt_next = jnp.concatenate([pdt[:, 1:], jnp.zeros_like(pdt[:, :1])], axis=1)
        delta_c = w[:, :, None] * wnode[None, None] * Cp[None, None] + pdt_next
        delta_c = jnp.where(inst_l.cpu_allowed()[:, :, None], delta_c, BIG)

        # --- blocked sets + projection update (all local) ---
        avail_e = adj[None, None] & ~gp.blocked_sets(inst_l, phi, pdt)
        de = jnp.where(avail_e, delta_e, BIG)
        dc = delta_c
        min_delta = jnp.minimum(de.min(-1), dc)
        stuck = min_delta >= BIG / 2
        de = jnp.where(stuck[..., None], jnp.where(adj[None, None], delta_e, BIG), de)
        min_delta = jnp.minimum(de.min(-1), dc)

        e_e, e_c = de - min_delta[..., None], dc - min_delta
        is_min_e = (e_e <= 1e-6) & (de < BIG / 2)
        is_min_c = (e_c <= 1e-6) & (dc < BIG / 2)
        N = is_min_e.sum(-1) + is_min_c
        red_e = jnp.where(de >= BIG / 2, phi.e,
                          jnp.where(is_min_e, 0.0, jnp.minimum(phi.e, alpha * e_e)))
        red_c = jnp.where(dc >= BIG / 2, phi.c,
                          jnp.where(is_min_c, 0.0, jnp.minimum(phi.c, alpha * e_c)))
        share = (red_e.sum(-1) + red_c) / jnp.maximum(N, 1)
        new_phi = renormalize(
            inst_l,
            Phi(e=phi.e - red_e + share[..., None] * is_min_e,
                c=phi.c - red_c + share * is_min_c),
        )

        D_links = jnp.where(adj, costs.cost(link_kind, F, link_param), 0.0)
        C_nodes = costs.cost(comp_kind, G, comp_param)
        cost = jnp.sum(D_links) + jnp.sum(C_nodes)

        exc_e = jnp.where(phi.e > 1e-6, delta_e - min_delta[..., None], 0.0)
        exc_c = jnp.where(phi.c > 1e-6, delta_c - min_delta, 0.0)
        residual = jax.lax.pmax(jnp.maximum(jnp.max(exc_e), jnp.max(exc_c)), axis)
        return new_phi.e, new_phi.c, cost, residual

    smapped = compat.shard_map(
        step,
        mesh=mesh,
        in_specs=(app, app, app, app, app, app, rep, rep, rep, rep, app, app, rep),
        out_specs=(app, app, rep, rep),
        check=False,
    )
    return jax.jit(smapped)


def solve_sharded(
    inst: Instance,
    mesh: Mesh,
    *,
    axis: str = "stage",
    alpha: float = 0.02,
    max_iters: int = 300,
    tol: float = 1e-4,
    phi0: Phi | None = None,
) -> gp.GPResult:
    """Run GP with applications sharded across a device mesh axis."""
    n_shards = mesh.shape[axis]
    inst_p, A_orig = _pad_apps(inst, n_shards)
    phi = phi0 if phi0 is not None else gp.init_phi(inst_p)

    step = sharded_gp_step(mesh, inst_p, axis)
    shard = NamedSharding(mesh, P(axis))
    phi_e = jax.device_put(phi.e, shard)
    phi_c = jax.device_put(phi.c, shard)

    cost_hist, res_hist = [], []
    it = 0
    for it in range(1, max_iters + 1):
        phi_e, phi_c, cost, residual = step(
            inst_p.L, inst_p.w, inst_p.r, inst_p.dst, inst_p.n_tasks,
            inst_p.stage_mask, inst_p.adj, inst_p.link_param,
            inst_p.comp_param, inst_p.wnode, phi_e, phi_c, jnp.float32(alpha),
        )
        cost_hist.append(float(cost))
        res_hist.append(float(residual))
        if float(residual) <= tol:
            break

    phi_full = Phi(e=jnp.asarray(np.asarray(phi_e)[:A_orig]),
                   c=jnp.asarray(np.asarray(phi_c)[:A_orig]))
    return gp.GPResult(phi=phi_full, cost_history=jnp.asarray(cost_hist),
                       residual_history=jnp.asarray(res_hist), iterations=it)
