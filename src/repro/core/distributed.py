"""Device-parallel GP via shard_map (the paper's protocol on a TPU mesh).

The paper's Algorithm 1 is node-parallel: every network node updates its own
phi_i from locally measurable marginals plus a broadcast.  On an accelerator
mesh the natural data-parallel axis is the *application* axis — each device
owns a contiguous slab of applications (and all their chain stages, so the
stage-(k) -> stage-(k+1) coupling never crosses devices), computes their
traffic and marginal recursions locally, and the only cross-device coupling
is the network-wide flow measurement:

  1. total link flows F_ij = sum_apps L * f     -> jax.lax.psum
  2. total workloads  G_i  = sum_apps w * g     -> jax.lax.psum

This mirrors the paper's measurement model exactly: every node measures the
*total* F_ij and G_i on its links/CPU (an implicit all-reduce over flows in
the real network), while the per-stage marginal broadcast stays within the
application's owner device.  Per-iteration collective volume: 2 x (V^2 + V)
floats per ladder rung — independent of |A| and |S| — matching the paper's
claim that control overhead scales with the network size, not the task
count.

This module contains NO GP-step math of its own: it is a mesh adapter over
the ONE shared step core (:mod:`repro.core.engine`, DESIGN.md §14).  The
engine's ``scan_chunk`` — identical to the one ``gp.solve`` jits — is traced
inside ``shard_map`` with ``axis`` bound to the app-shard mesh axis, so the
mesh path runs the same fused kernels (batched-LU stage factors, fused
chain sweeps, bitset blocked sets, the stepsize ladder) and the host loop
reads back only the ``done`` latch once per chunk, exactly like the
single-device chunked driver.

Two entry points:

  * :func:`solve_sharded`          — one Instance, apps sharded over the mesh.
  * :func:`solve_sharded_batched`  — a ``batch.pad_instances`` family; the
    member axis is vmapped INSIDE each shard (vmap-of-shard_map), so a
    scenario sweep composes the §9/§10 batch machinery with the mesh
    (``scenarios.run_sweep(mesh=...)``).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import batch as batch_mod
from repro.core import compat, engine, gp
from repro.core.network import Instance
from repro.core.traffic import Phi

# Host reads the done latch once per chunk, at the same cadence as
# gp.solve — one source of truth so the two drivers' chunk-length cache
# keys stay aligned.
_CHUNK = gp._SOLVE_CHUNK


def _pad_apps(inst: Instance, n_shards: int, *, batched: bool = False
              ) -> tuple[Instance, int]:
    """Pad the application axis to a multiple of n_shards with dead apps.

    Dead apps carry zero rate and an all-False ``stage_mask``, so they are
    degenerate everywhere (§9 invariants) and contribute exactly nothing to
    the measured F/G.  ``batched=True`` pads axis 1 of a stacked
    ``pad_instances`` pytree instead of axis 0.
    """
    ax = 1 if batched else 0
    A = int(inst.L.shape[ax])
    A_pad = -(-A // n_shards) * n_shards
    if A_pad == A:
        return inst, A
    pad = A_pad - A

    def padA(x, fill=0):
        widths = [(0, 0)] * x.ndim
        widths[ax] = (0, pad)
        return jnp.pad(x, widths, constant_values=fill)

    return dataclasses.replace(
        inst,
        L=padA(inst.L),
        w=padA(inst.w),
        r=padA(inst.r),
        dst=padA(inst.dst),
        n_tasks=padA(inst.n_tasks),
        stage_mask=padA(inst.stage_mask, fill=False),
    ), A


def _pad_tree_apps(tree, A_pad: int, *, batched: bool = False):
    """Zero-pad the app axis of a Phi / mask pytree to ``A_pad`` entries."""
    ax = 1 if batched else 0
    if tree is None:
        return None

    def padA(x):
        pad = A_pad - x.shape[ax]
        if pad == 0:
            return x
        widths = [(0, 0)] * x.ndim
        widths[ax] = (0, pad)
        return jnp.pad(x, widths)

    return jax.tree_util.tree_map(padA, tree)


_N_SPARSE = 7   # sparse-topology arrays threaded through the chunk program


@functools.lru_cache(maxsize=None)
def _chunk_program(mesh: Mesh, axis: str, node_axis, link_kind: int,
                   comp_kind: int, length: int, scaled: bool, solver: str,
                   blocked: str, has_masks: bool, has_sparse: bool = False,
                   accel=None, telemetry=None):
    """Build the jitted shard_map'd chunk for one (mesh, config) combination.

    The stacked Instance is decomposed into per-application (app-sharded)
    and network-level (replicated) arrays so the shard_map specs stay
    simple; each shard reassembles its local Instance, vmaps
    :func:`engine.scan_chunk` over the member axis, and the ``axis``-bound
    collectives inside the engine provide the network-wide measurement.
    Cached so each chunk length compiles once per mesh; ``accel`` (a
    resolved hashable :class:`engine.AccelConfig` or None) is part of the
    cache key, like ``solver``/``blocked``.

    With ``node_axis`` set (the 2-D app × node-space mesh, DESIGN.md §18)
    the V² strategy rows are *storage*-sharded: ``phi_e`` arrives as a
    contiguous (Vp / node_shards)-row slab per node shard — slabs align
    with the BFS graph-partition blocks, since both are contiguous index
    ranges — is ``all_gather``-ed to full V inside the chunk (one gather
    per chunk, not per iteration), and each shard's slab is sliced back
    out at the end.  Per-iteration compute is replicated across the node
    shards *except* the blocked-set tagged sweep, which runs genuinely
    node-parallel over the row slabs (``engine._tagged_nbr_sharded``).
    Replication makes the 2-D trajectories exactly the 1-D ones.

    ``has_sparse`` threads the instance's 7 sparse-topology arrays
    (replicated) into the per-shard Instance so the "sparse" stage solver
    and the neighbor-list tagged sweep see them.

    The §15 Anderson ring buffers travel as *opaque per-shard slabs*: the
    flat feature axis of ``ax``/``af`` is sharded (``P(None, None, axis)``)
    into slices exactly the size of each shard's locally flattened phi, and
    a slice is only ever produced and consumed by the same shard — the
    global buffer layout is never interpreted.  The adaptive ``alpha`` and
    history count ``ak`` are replicated (the winning rung and the push
    cadence are shard-identical by construction).

    The §19 telemetry ring ``tb`` is replicated: every recorded column is
    already a psum/pmax-reduced fleet quantity inside the engine, so each
    shard writes the identical rows and the ring adds no collectives.
    ``telemetry`` (a resolved hashable :class:`engine.TelemetryConfig` or
    None) is part of the cache key; with None the ring is (0, TEL_WIDTH)
    and the program is identical to the pre-telemetry one.
    """
    node_shards = int(mesh.shape[node_axis]) if node_axis is not None else 1
    app = P(None, axis)     # (B, A, ...): member axis plain, apps sharded
    # (B, A, K1, Vp, V): member plain, apps sharded, strategy ROWS sharded
    # along the node-space axis (1-D mesh: plain app sharding)
    row = P(None, axis, None, node_axis, None) if node_axis else app
    buf = P(None, None, axis)   # (B, m, N): Anderson slab, N axis sharded
    rep = P()

    def chunk(L, w, r, dst, n_tasks, stage_mask,          # app-sharded
              adj, link_param, comp_param, wnode,         # replicated
              phi_e, phi_c,                               # app-sharded carry
              best_cost, stall, done, iters, cost, residual,
              aalpha, ax, af, ak, tb,                     # accel (§15) + ring (§19)
              alpha, tol, patience, max_iters, *extra):

        def one(L, w, r, dst, n_tasks, stage_mask, adj, link_param,
                comp_param, wnode, phi_e, phi_c, best_cost, stall, done,
                iters, cost, residual, aalpha, ax, af, ak, tb,
                out_nbr, out_mask, in_nbr, in_mask, node_part,
                blk_nbr, blk_mask, ae, ac):
            V = adj.shape[-1]
            if node_axis is not None:
                # storage-sharded rows -> full strategy for the iteration
                phi_e = jax.lax.all_gather(
                    phi_e, node_axis, axis=2, tiled=True)[:, :, :V]
            inst_l = Instance(
                adj=adj, link_param=link_param, link_kind=link_kind,
                comp_param=comp_param, comp_kind=comp_kind,
                L=L, w=w, wnode=wnode, r=r, dst=dst, n_tasks=n_tasks,
                stage_mask=stage_mask,
                out_nbr=out_nbr, out_mask=out_mask,
                in_nbr=in_nbr, in_mask=in_mask, node_part=node_part,
                blk_nbr=blk_nbr, blk_mask=blk_mask,
            )
            carry = engine.ScanCarry(
                phi=Phi(e=phi_e, c=phi_c), best_cost=best_cost, stall=stall,
                done=done, iters=iters, cost=cost, residual=residual,
                alpha=aalpha, ax=ax, af=af, ak=ak, tb=tb,
            )
            carry, (cs, rs) = engine.scan_chunk(
                inst_l, carry, alpha, tol, patience, max_iters, ae, ac,
                length=length, scaled=scaled, solver=solver, blocked=blocked,
                axis=axis, node_axis=node_axis, node_shards=node_shards,
                accel=accel, telemetry=telemetry,
            )
            pe = carry.phi.e
            if node_axis is not None:
                # slice this shard's row slab back out (pad V -> Vp first)
                Vp = -(-V // node_shards) * node_shards
                rl = Vp // node_shards
                pe = jnp.pad(pe, ((0, 0), (0, 0), (0, Vp - V), (0, 0)))
                i0 = jax.lax.axis_index(node_axis) * rl
                pe = jax.lax.dynamic_slice_in_dim(pe, i0, rl, axis=2)
            return (pe, carry.phi.c, carry.best_cost, carry.stall,
                    carry.done, carry.iters, carry.cost, carry.residual,
                    carry.alpha, carry.ax, carry.af, carry.ak, carry.tb,
                    cs, rs)

        off = _N_SPARSE if has_sparse else 0
        sparse_arrs = extra[:off] if has_sparse else (None,) * _N_SPARSE
        masks = extra[off:]
        ae, ac = masks if has_masks else (None, None)
        in_axes = ((0,) * 23 + ((0,) * _N_SPARSE if has_sparse
                                else (None,) * _N_SPARSE)
                   + ((0, 0) if has_masks else (None, None)))
        return jax.vmap(one, in_axes=in_axes)(
            L, w, r, dst, n_tasks, stage_mask, adj, link_param, comp_param,
            wnode, phi_e, phi_c, best_cost, stall, done, iters, cost,
            residual, aalpha, ax, af, ak, tb, *sparse_arrs, ae, ac)

    in_specs = ((app,) * 6 + (rep,) * 4 + (row, app) + (rep,) * 6
                + (rep, buf, buf, rep, rep)
                + (rep,) * 4
                + ((rep,) * _N_SPARSE if has_sparse else ())
                + ((app, app) if has_masks else ()))
    out_specs = ((row, app) + (rep,) * 6 + (rep, buf, buf, rep, rep)
                 + (rep, rep))
    smapped = compat.shard_map(chunk, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, check=False)
    return jax.jit(smapped)


def _pad_rows(x: jnp.ndarray, Vp: int, ax: int) -> jnp.ndarray:
    """Zero-pad axis ``ax`` (a V-row axis) up to ``Vp`` entries."""
    pad = Vp - x.shape[ax]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[ax] = (0, pad)
    return jnp.pad(x, widths)


def _sparse_args(binst: Instance) -> tuple:
    return (binst.out_nbr, binst.out_mask, binst.in_nbr, binst.in_mask,
            binst.node_part, binst.blk_nbr, binst.blk_mask)


def solve_sharded_batched(
    binst: Instance,
    mesh: Mesh,
    *,
    axis: str = "stage",
    node_axis: str | None = None,
    alpha: float = 0.02,
    max_iters: int = 300,
    tol: float = 1e-4,
    patience: int = 40,
    phi0: Phi | None = None,
    allowed_e: jnp.ndarray | None = None,
    allowed_c: jnp.ndarray | None = None,
    scaled: bool = False,
    compact: bool = True,
    solver: str = "auto",
    blocked: str = "bitset",
    accel=None,
    telemetry=None,
) -> gp.GPScan:
    """Solve a padded scenario family with applications sharded over `axis`.

    ``binst`` is a ``batch.pad_instances`` pytree (leading member axis B);
    inside each shard the member axis is vmapped over the SAME chunked
    done-latch scan ``gp.solve`` runs (``engine.scan_chunk``), so large
    ensembles spread their per-member app slabs across the mesh while the
    host reads back only the batched ``done`` latch once per ``_CHUNK``
    iterations.  Histories follow the dense :class:`gp.GPScan` contract.
    ``solver=``/``blocked=``/``accel=`` dispatch exactly as in ``gp.solve``
    (accelerated sharded trajectories match the accelerated single-device
    ones — tests/test_accel.py).

    ``node_axis`` names the second mesh axis of a 2-D (app × node-space)
    mesh (DESIGN.md §18): strategy rows are storage-sharded along it and
    the blocked-set tagged sweep runs node-parallel; trajectories are
    exactly the 1-D-mesh (and single-device) ones.

    ``compact=True`` (default) re-packs the *member lanes* at chunk
    boundaries exactly like ``gp.solve_batched``: converged members retire
    (their finals snapshot into the result buffers) and the active set
    compacts into the next power-of-two bucket, so a long-tailed metro
    ensemble stops paying mesh time for members that finished early.
    Bucket sizes are quantized to powers of two to bound XLA recompiles.
    """
    accel = engine.resolve_accel(accel)
    telemetry = engine.resolve_telemetry(telemetry)
    n_shards = mesh.shape[axis]
    node_shards = int(mesh.shape[node_axis]) if node_axis is not None else 1
    B = int(binst.adj.shape[0])
    V = int(binst.adj.shape[-1])
    Vp = -(-V // node_shards) * node_shards
    binst_p, A_orig = _pad_apps(binst, n_shards, batched=True)
    has_sparse = binst_p.has_sparse
    A_pad = int(binst_p.L.shape[1])
    if phi0 is None:
        phi0 = jax.vmap(gp.init_phi)(binst_p)
    else:
        phi0 = _pad_tree_apps(phi0, A_pad, batched=True)
    allowed_e = _pad_tree_apps(allowed_e, A_pad, batched=True)
    allowed_c = _pad_tree_apps(allowed_c, A_pad, batched=True)
    has_masks = allowed_e is not None or allowed_c is not None
    if has_masks and (allowed_e is None or allowed_c is None):
        raise ValueError("pass both allowed_e and allowed_c, or neither")

    carry = jax.vmap(
        lambda i, p: engine.init_carry(i, p, accel=accel,
                                       telemetry=telemetry))(binst_p, phi0)
    alpha_, tol_ = jnp.float32(alpha), jnp.float32(tol)
    patience_, max_iters_ = jnp.int32(patience), jnp.int32(max_iters)

    # host-side result buffers, indexed by original member id (§10 lane
    # compaction — mirrors gp.solve_batched)
    cost_hist = np.zeros((B, max_iters + 1), np.float32)
    cost_hist[:, 0] = np.asarray(carry.cost)
    res_hist = np.zeros((B, max_iters), np.float32)
    out_phi_e = np.asarray(phi0.e).copy()
    out_phi_c = np.asarray(phi0.c).copy()
    out_cost = np.asarray(carry.cost).copy()
    out_res = np.full((B,), np.inf, np.float32)
    out_iters = np.zeros((B,), np.int32)
    ring = telemetry.ring if telemetry is not None else 0
    out_tb = np.zeros((B, ring, engine.TEL_WIDTH), np.float32)
    written = np.zeros((B,), np.int64)

    ids = np.arange(B)                    # lane -> original member (-1: pad)
    inst_p, ae_p, ac_p = binst_p, allowed_e, allowed_c
    c = carry
    if compact:
        bucket0 = batch_mod.next_pow2(B)
        if bucket0 > B:
            sel_j = jnp.asarray(np.concatenate(
                [np.arange(B), np.zeros(bucket0 - B, np.int64)]))
            inst_p = gp._gather(inst_p, sel_j)
            c = gp._gather(c, sel_j)
            if ae_p is not None:
                ae_p = ae_p[sel_j]
            if ac_p is not None:
                ac_p = ac_p[sel_j]
            pad0 = jnp.arange(bucket0) >= B
            c = c._replace(done=c.done | pad0)
            ids = np.concatenate([ids, np.full(bucket0 - B, -1)])

    steps = 0
    while steps < max_iters:
        length = min(_CHUNK, max_iters - steps)
        fn = _chunk_program(mesh, axis, node_axis, inst_p.link_kind,
                            inst_p.comp_kind, length, scaled, solver,
                            blocked, has_masks, has_sparse, accel, telemetry)
        sparse_args = _sparse_args(inst_p) if has_sparse else ()
        mask_args = (ae_p, ac_p) if has_masks else ()
        phi_e_in = _pad_rows(c.phi.e, Vp, ax=3)
        (phi_e, phi_c, best, stall, done, iters, cost, residual,
         aalpha, ax, af, ak, tb, cs, rs
         ) = fn(inst_p.L, inst_p.w, inst_p.r, inst_p.dst,
                inst_p.n_tasks, inst_p.stage_mask, inst_p.adj,
                inst_p.link_param, inst_p.comp_param, inst_p.wnode,
                phi_e_in, c.phi.c, c.best_cost, c.stall, c.done, c.iters,
                c.cost, c.residual, c.alpha, c.ax, c.af, c.ak, c.tb,
                alpha_, tol_, patience_, max_iters_,
                *sparse_args, *mask_args)
        c = engine.ScanCarry(phi=Phi(e=phi_e[:, :, :, :V], c=phi_c),
                             best_cost=best, stall=stall, done=done,
                             iters=iters, cost=cost, residual=residual,
                             alpha=aalpha, ax=ax, af=af, ak=ak, tb=tb)
        valid = ids >= 0
        vids = ids[valid]
        cost_hist[vids, steps + 1: steps + 1 + length] = np.asarray(cs)[valid]
        res_hist[vids, steps: steps + length] = np.asarray(rs)[valid]
        steps += length
        written[vids] = steps

        done_h = np.asarray(c.done)
        retiring = valid & (done_h | (steps >= max_iters))
        if retiring.any():
            rids = ids[retiring]
            out_phi_e[rids] = np.asarray(c.phi.e)[retiring]
            out_phi_c[rids] = np.asarray(c.phi.c)[retiring]
            out_cost[rids] = np.asarray(c.cost)[retiring]
            out_res[rids] = np.asarray(c.residual)[retiring]
            out_iters[rids] = np.asarray(c.iters)[retiring]
            if telemetry is not None:
                out_tb[rids] = np.asarray(c.tb)[retiring]

        active = valid & ~done_h
        n_act = int(active.sum())
        if n_act == 0:
            break
        if compact:
            bucket = batch_mod.next_pow2(n_act)
            if bucket < len(ids):
                keep = np.flatnonzero(active)
                sel = np.concatenate(
                    [keep, np.full(bucket - n_act, keep[0], np.int64)])
                sel_j = jnp.asarray(sel)
                inst_p = gp._gather(inst_p, sel_j)
                c = gp._gather(c, sel_j)
                if ae_p is not None:
                    ae_p = ae_p[sel_j]
                if ac_p is not None:
                    ac_p = ac_p[sel_j]
                pad = jnp.arange(bucket) >= n_act
                c = c._replace(done=c.done | pad)
                ids = np.where(np.arange(bucket) < n_act, ids[sel], -1)

    # dense-history contract: repeat converged values past each member's
    # retirement chunk
    for m in range(B):
        w = int(written[m])
        cost_hist[m, w + 1:] = cost_hist[m, w]
        if w > 0:
            res_hist[m, w:] = res_hist[m, w - 1]

    return gp.GPScan(
        phi=Phi(e=jnp.asarray(out_phi_e[:, :A_orig]),
                c=jnp.asarray(out_phi_c[:, :A_orig])),
        cost=jnp.asarray(out_cost), residual=jnp.asarray(out_res),
        cost_history=jnp.asarray(cost_hist),
        residual_history=jnp.asarray(res_hist),
        iterations=jnp.asarray(out_iters),
        telemetry=jnp.asarray(out_tb) if telemetry is not None else None,
    )


def solve_sharded(
    inst: Instance,
    mesh: Mesh,
    *,
    axis: str = "stage",
    node_axis: str | None = None,
    alpha: float = 0.02,
    max_iters: int = 300,
    tol: float = 1e-4,
    patience: int = 40,
    phi0: Phi | None = None,
    allowed_e: jnp.ndarray | None = None,
    allowed_c: jnp.ndarray | None = None,
    scaled: bool = False,
    solver: str = "auto",
    blocked: str = "bitset",
    accel=None,
    telemetry=None,
) -> gp.GPResult:
    """Run GP with applications sharded across a device mesh axis.

    The B=1 member of :func:`solve_sharded_batched`: the same fused step
    engine ``gp.solve`` runs, traced under ``shard_map`` with the F/G
    measurement psum-reduced over ``axis`` — cost trajectories match the
    single-device solve (tests/test_distributed.py asserts ≤1e-4 over
    ≥2 shards).  ``node_axis`` selects the 2-D app × node-space mesh
    (DESIGN.md §18; tests/test_sparse.py asserts 2-D == single-device).
    Returns a trimmed :class:`gp.GPResult`.
    """
    lift = lambda t: jax.tree_util.tree_map(lambda x: jnp.asarray(x)[None], t)
    scan = solve_sharded_batched(
        lift(inst), mesh, axis=axis, node_axis=node_axis, alpha=alpha,
        max_iters=max_iters, tol=tol, patience=patience,
        phi0=None if phi0 is None else lift(phi0),
        allowed_e=None if allowed_e is None else lift(allowed_e),
        allowed_c=None if allowed_c is None else lift(allowed_c),
        scaled=scaled, solver=solver, blocked=blocked, accel=accel,
        telemetry=telemetry)
    member = jax.tree_util.tree_map(lambda x: x[0], scan)
    return gp.GPResult(
        phi=member.phi, cost_history=member.cost_history,
        residual_history=member.residual_history,
        iterations=int(member.iterations),
        telemetry=member.telemetry,
    ).trim()
