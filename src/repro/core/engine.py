"""One GP step engine: the fused Algorithm-1 iteration, shared by all drivers.

The paper's iteration is node-parallel with exactly ONE network-wide
coupling: the measured total link flows ``F_ij`` and workloads ``G_i``.
This module owns the full fused iteration — stage factorization (one
batched LU per step, ``traffic.stage_factors``), the fused forward/reverse
chain sweeps (``ops.fused_chain_solve``), the bitset blocked sets
(``ops.blocked_tagged``), the blocked-node fallback, the stepsize-ladder
projection + renormalize, and the cost/residual bookkeeping — and is
parameterized over how that one measurement is reduced:

  * ``axis=None``   — plain sums over the whole application axis; this is
                      the single-device path ``gp.gp_step`` / ``gp.solve*``
                      wrap.
  * ``axis="name"`` — ``lax.psum`` over the named mesh axis the application
                      dimension is sharded on; this is the ``shard_map``
                      path ``distributed.solve_sharded*`` wraps (the paper's
                      implicit all-reduce of locally measured flows).

Everything except the F/G reduction, the traffic-validity vote and the
residual max is local to an application shard, so both paths execute the
same fused kernels and produce matching cost trajectories (DESIGN.md §14,
tests/test_distributed.py).

``scan_chunk`` is the shared chunked-scan loop body with the on-device
early-stop latch (DESIGN.md §10); the single-device drivers jit it
directly, the mesh driver runs it inside ``shard_map`` (optionally under
``jax.vmap`` for mesh-composed scenario families).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import costs
from repro.core import traffic as traffic_mod
from repro.core.marginals import BIG, marginals
from repro.core.network import Instance
from repro.core.traffic import (
    Phi, flows, renormalize, total_cost, traffic_is_valid,
)
from repro.kernels import blocked_sets as blocked_sets_mod
from repro.kernels import ops

TIE_EPS = 1e-6      # directions within this of the min-delta receive mass
BLOCK_EPS = 1e-7    # strictness slack for pdt comparisons

# Backtracking multipliers tried each iteration (vmapped inside the jitted
# step).  The paper assumes a "sufficiently small" fixed alpha (Theorem 2 /
# [11]); with congestion-level queue marginals (D' ~ 1e6 near saturation) a
# fixed alpha either diverges or crawls, so we evaluate the same projection
# direction at several stepsizes and keep the best — a monotone-descent
# safeguard that preserves the convergence argument (descent + stationarity
# of condition (6)).  Multiplier 0 is included so the cost never increases.
ALPHA_LADDER = tuple(4.0 ** (1 - k) for k in range(11)) + (0.0,)


class GPState(NamedTuple):
    phi: Phi
    cost: jnp.ndarray
    residual: jnp.ndarray    # sufficiency-condition residual (0 => optimal)


class ScanCarry(NamedTuple):
    """Carry of the chunked GP scan (DESIGN.md §10)."""

    phi: Phi
    best_cost: jnp.ndarray   # float32, monotone-descent tracker
    stall: jnp.ndarray       # int32, iterations without improvement
    done: jnp.ndarray        # bool, early-stop latch
    iters: jnp.ndarray       # int32, #iterations committed so far
    cost: jnp.ndarray        # float32, last committed cost
    residual: jnp.ndarray    # float32, last committed residual


def _pmax(x: jnp.ndarray, axis: Optional[str]) -> jnp.ndarray:
    return x if axis is None else jax.lax.pmax(x, axis)


# ---------------------------------------------------------------------------
# Blocked node sets
# ---------------------------------------------------------------------------

def blocked_sets(inst: Instance, phi: Phi, pdt: jnp.ndarray,
                 method: str = "bitset") -> jnp.ndarray:
    """(A,K1,V,V) bool: j in B_i(a,k).

    j is blocked for i at stage (a,k) if (Section IV "Blocked node set"):
      1) (i,j) not in E, or
      2) dD/dt_j(a,k) > dD/dt_i(a,k), or
      3) j's routing subtree for (a,k) contains an improper link (p,q)
         with dD/dt_q > dD/dt_p.

    Category 3 ("tagged" nodes) is a monotone boolean fixed point along the
    routing DAG.  method="bitset" (default) runs it through the bit-packed
    kernel — uint32-packed successor words, while-loop frontier early exit
    at the DAG diameter (kernels/blocked_sets.py, DESIGN.md §13);
    method="scan" keeps the seed's dense V-sweep ``lax.scan`` as the
    differential reference (tests/test_blocked_sets.py asserts bit-exact
    agreement — the early exit stops precisely at the shared fixed point).

    Entirely local to an application shard: the routing DAG of stage (a,k)
    never couples applications, so the mesh path calls this unchanged.
    """
    route = phi.e > 0.0                                         # (A,K1,V,V)
    worse = pdt[:, :, None, :] > pdt[:, :, :, None] + BLOCK_EPS  # pdt_q > pdt_p
    improper = route & worse

    if method == "bitset":
        tagged = ops.blocked_tagged(route, improper)
    else:
        tagged = blocked_sets_mod.tagged_scan_dense(route, improper)

    blocked = (~inst.adj[None, None]) | improper | worse | tagged[:, :, None, :]
    return blocked


# ---------------------------------------------------------------------------
# One GP iteration (eqs. 8-10)
# ---------------------------------------------------------------------------

def gp_step(
    inst: Instance,
    phi: Phi,
    alpha: float,
    allowed_e: Optional[jnp.ndarray] = None,
    allowed_c: Optional[jnp.ndarray] = None,
    scaled: bool = False,
    solver: str = "auto",
    *,
    blocked: str = "bitset",
    axis: Optional[str] = None,
) -> GPState:
    """One fused GP iteration; ``axis`` selects the F/G reduction (above)."""
    # One batched LU of every (app, stage) system per iteration: the traffic
    # sweep solves the transposed systems and the marginal recursion the
    # plain ones from the SAME factors (traffic.stage_factors, DESIGN.md
    # §12).  The ladder's candidate evaluations below factor their own
    # (ladder, A, K1)-stacked batch inside the vmap.  "auto" resolves per
    # backend/size at trace time (traffic.resolve_solver).
    solver = traffic_mod.resolve_solver(solver, inst.V)
    fact = traffic_mod.stage_factors(phi.e) if solver == "batched_lu" else None
    fl = flows(inst, phi, fact, solver=solver, axis=axis)
    m = marginals(inst, phi, fl, fact, solver=solver)

    avail_e = inst.adj[None, None] & ~blocked_sets(inst, phi, m.pdt,
                                                   method=blocked)
    if allowed_e is not None:
        avail_e = avail_e & allowed_e
    avail_c = inst.cpu_allowed()[:, :, None]
    if allowed_c is not None:
        avail_c = avail_c & allowed_c

    delta_e = jnp.where(avail_e, m.delta_e, BIG)
    delta_c = jnp.where(avail_c, m.delta_c, BIG)
    min_delta = jnp.minimum(delta_e.min(-1), delta_c)           # (A,K1,V)

    # Fallback guard: if blocking removed every direction at a row that must
    # forward (can happen transiently on congested iterates), fall back to
    # the unblocked-by-topology direction set for that row.
    stuck = min_delta >= BIG / 2
    fb_e = jnp.where(inst.adj[None, None] & (allowed_e if allowed_e is not None else True), m.delta_e, BIG)
    fb_c = jnp.where(inst.cpu_allowed()[:, :, None] & (allowed_c if allowed_c is not None else True), m.delta_c, BIG)
    delta_e = jnp.where(stuck[..., None], fb_e, delta_e)
    delta_c = jnp.where(stuck, fb_c, delta_c)
    min_delta = jnp.minimum(delta_e.min(-1), delta_c)

    e_e = delta_e - min_delta[..., None]                        # e_ij >= 0
    e_c = delta_c - min_delta
    if scaled:
        # quasi-Newton diagonal scaling (the second-order speedup the paper
        # attributes to [5]): normalize the projection step by a curvature
        # surrogate so stepsizes are comparable across congestion levels.
        # D'' of the M/M/1 cost ~ 2 D'/(cap-F) ~ D'^2-scale; we use the
        # per-row marginal magnitude as the diagonal preconditioner.
        scale_row = jnp.maximum(jnp.abs(min_delta), 1e-6)
        e_e = e_e / scale_row[..., None]
        e_c = e_c / scale_row

    is_min_e = (e_e <= TIE_EPS) & (delta_e < BIG / 2)
    is_min_c = (e_c <= TIE_EPS) & (delta_c < BIG / 2)
    N = is_min_e.sum(-1) + is_min_c                             # (A,K1,V)

    # reductions: blocked directions surrender everything; positive-e
    # directions surrender min(phi, alpha * e)   (eq. 9)
    def apply(a):
        red_e = jnp.where(
            delta_e >= BIG / 2, phi.e,
            jnp.where(is_min_e, 0.0, jnp.minimum(phi.e, a * e_e)),
        )
        red_c = jnp.where(
            delta_c >= BIG / 2, phi.c,
            jnp.where(is_min_c, 0.0, jnp.minimum(phi.c, a * e_c)),
        )
        share = (red_e.sum(-1) + red_c) / jnp.maximum(N, 1)     # (A,K1,V)
        cand = renormalize(inst, Phi(
            e=phi.e - red_e + share[..., None] * is_min_e,
            c=phi.c - red_c + share * is_min_c,
        ))
        cand_fl = flows(inst, cand, solver=solver, axis=axis)
        valid = traffic_is_valid(inst, cand_fl.t, axis=axis)
        c_links = jnp.where(inst.adj, costs.cost(inst.link_kind, cand_fl.F, inst.link_param), 0.0)
        c_nodes = costs.cost(inst.comp_kind, cand_fl.G, inst.comp_param)
        cost = jnp.sum(c_links) + jnp.sum(c_nodes)
        return cand, jnp.where(valid, cost, jnp.inf)

    ladder = alpha * jnp.asarray(ALPHA_LADDER, dtype=jnp.float32)
    cands, cand_costs = jax.vmap(apply)(ladder)
    # a too-aggressive candidate can form a routing loop -> divergent traffic
    # fixed point -> inf/NaN cost; such candidates must lose the argmin.
    # cand_costs derive from the psum-reduced F/G, so every shard computes
    # the identical replicated ladder and picks the same argmin.
    cand_costs = jnp.where(jnp.isnan(cand_costs), jnp.inf, cand_costs)
    best = jnp.argmin(cand_costs)
    new_phi = jax.tree_util.tree_map(lambda x: x[best], cands)

    # residual of sufficiency condition (6) at the *new* iterate, computed
    # cheaply from the current marginals (exact residual is recomputed by
    # the caller when it matters)
    exc_e = jnp.where(phi.e > 1e-6, m.delta_e - min_delta[..., None], 0.0)
    exc_c = jnp.where(phi.c > 1e-6, m.delta_c - min_delta, 0.0)
    residual = _pmax(jnp.maximum(jnp.max(exc_e), jnp.max(exc_c)), axis)

    return GPState(phi=new_phi, cost=cand_costs[best], residual=residual)


# ---------------------------------------------------------------------------
# Chunked scan loop body (shared by gp.solve* and distributed.solve_sharded*)
# ---------------------------------------------------------------------------

def init_carry(inst: Instance, phi: Phi, *, solver: str = "auto",
               axis: Optional[str] = None) -> ScanCarry:
    cost0 = jnp.asarray(total_cost(inst, phi, solver=solver, axis=axis),
                        jnp.float32)
    return ScanCarry(
        phi=phi,
        best_cost=cost0,
        stall=jnp.int32(0),
        done=jnp.asarray(False),
        iters=jnp.int32(0),
        cost=cost0,
        residual=jnp.float32(jnp.inf),
    )


def scan_chunk(
    inst: Instance,
    carry: ScanCarry,
    alpha, tol, patience, max_iters,
    allowed_e: Optional[jnp.ndarray], allowed_c: Optional[jnp.ndarray],
    *,
    length: int,
    scaled: bool = False,
    solver: str = "auto",
    blocked: str = "bitset",
    axis: Optional[str] = None,
):
    """Advance the solve by up to ``length`` iterations entirely on device.

    Early-stop is a *mask*, not a break: once ``done`` latches (residual
    below tol, ladder-stationary for ``patience`` iterations, or the
    ``max_iters`` budget spent) the carry is frozen and subsequent steps
    re-emit the converged (cost, residual), keeping history shapes static.

    Not jitted here — the single-device drivers wrap it in ``jax.jit``
    (``gp._scan_chunk``) and the mesh driver traces it inside
    ``shard_map`` (``distributed._chunk_program``), where the ``axis``
    collectives bind to the mesh.
    """

    def body(c: ScanCarry, _):
        state = gp_step(inst, c.phi, alpha, allowed_e, allowed_c, scaled,
                        solver, blocked=blocked, axis=axis)
        frz = c.done
        phi = jax.tree_util.tree_map(
            lambda new, old: jnp.where(frz, old, new), state.phi, c.phi)
        cost = jnp.where(frz, c.cost, state.cost)
        residual = jnp.where(frz, c.residual, state.residual)
        improved = state.cost < c.best_cost * (1 - 1e-6)
        best = jnp.where(frz | ~improved, c.best_cost, state.cost)
        stall = jnp.where(frz, c.stall, jnp.where(improved, 0, c.stall + 1))
        iters = c.iters + jnp.where(frz, 0, 1).astype(jnp.int32)
        done = frz | (residual <= tol) | (stall >= patience) | (iters >= max_iters)
        nc = ScanCarry(phi=phi, best_cost=best, stall=stall, done=done,
                       iters=iters, cost=cost, residual=residual)
        return nc, (cost, residual)

    return jax.lax.scan(body, carry, None, length=length)
