"""One GP step engine: the fused Algorithm-1 iteration, shared by all drivers.

The paper's iteration is node-parallel with exactly ONE network-wide
coupling: the measured total link flows ``F_ij`` and workloads ``G_i``.
This module owns the full fused iteration — stage factorization (one
batched LU per step, ``traffic.stage_factors``), the fused forward/reverse
chain sweeps (``ops.fused_chain_solve``), the bitset blocked sets
(``ops.blocked_tagged``), the blocked-node fallback, the stepsize-ladder
projection + renormalize, and the cost/residual bookkeeping — and is
parameterized over how that one measurement is reduced:

  * ``axis=None``   — plain sums over the whole application axis; this is
                      the single-device path ``gp.gp_step`` / ``gp.solve*``
                      wrap.
  * ``axis="name"`` — ``lax.psum`` over the named mesh axis the application
                      dimension is sharded on; this is the ``shard_map``
                      path ``distributed.solve_sharded*`` wraps (the paper's
                      implicit all-reduce of locally measured flows).

Everything except the F/G reduction, the traffic-validity vote and the
residual max is local to an application shard, so both paths execute the
same fused kernels and produce matching cost trajectories (DESIGN.md §14,
tests/test_distributed.py).

``scan_chunk`` is the shared chunked-scan loop body with the on-device
early-stop latch (DESIGN.md §10); the single-device drivers jit it
directly, the mesh driver runs it inside ``shard_map`` (optionally under
``jax.vmap`` for mesh-composed scenario families).

The engine additionally owns the *convergence-acceleration layer*
(DESIGN.md §15), toggled per mechanism by an :class:`AccelConfig` carried
as a static argument so every driver — single-device, vmapped-batched,
shard_map-sharded and warm-start-chained — gets it for free:

  * **Anderson mixing over phi** — a small (x, f) history window in the
    scan carry, least-squares residual combination, safeguarded by the
    existing projection + cost check (a mixed iterate that leaves the
    flow-conservation simplex or increases cost falls back to the plain
    GP step);
  * **adaptive per-member stepsize** — the fixed 12-rung ladder is
    replaced by a short ladder centered on a carry-resident alpha that
    grows/shrinks with the observed winning rung;
  * **sufficiency-residual stopping** — the residual latch uses the exact
    ``conditions.sufficiency_residual`` form, with a phi-delta fixed-point
    latch as the fallback stop.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import costs
from repro.core import traffic as traffic_mod
from repro.core.marginals import BIG, marginals
from repro.core.network import Instance
from repro.core.traffic import (
    Phi, flows, renormalize, total_cost, traffic_is_valid,
)
from repro.kernels import blocked_sets as blocked_sets_mod
from repro.kernels import ops
from repro.obs.device import (
    COL_ALPHA, COL_ANDERSON, COL_BS_ROUNDS, COL_COST, COL_ITER,
    COL_PHI_DELTA, COL_RESIDUAL, COL_RUNG, TEL_WIDTH, TelemetryConfig,
    empty_ring, resolve_telemetry, ring_record,
)

TIE_EPS = 1e-6      # directions within this of the min-delta receive mass
BLOCK_EPS = 1e-7    # strictness slack for pdt comparisons

# Backtracking multipliers tried each iteration (vmapped inside the jitted
# step).  The paper assumes a "sufficiently small" fixed alpha (Theorem 2 /
# [11]); with congestion-level queue marginals (D' ~ 1e6 near saturation) a
# fixed alpha either diverges or crawls, so we evaluate the same projection
# direction at several stepsizes and keep the best — a monotone-descent
# safeguard that preserves the convergence argument (descent + stationarity
# of condition (6)).  Multiplier 0 is included so the cost never increases.
ALPHA_LADDER = tuple(4.0 ** (1 - k) for k in range(11)) + (0.0,)


class AccelConfig(NamedTuple):
    """Static toggles of the §15 convergence-acceleration layer.

    Hashable (ints/floats/bools only) so it rides as a jit static argument
    and an ``lru_cache`` key for the mesh chunk programs; each distinct
    config compiles its own program, exactly like ``solver=``/``blocked=``.

      anderson_m     history window of the Anderson mixer (0 disables it)
      adaptive_alpha per-member adaptive stepsize replacing the fixed ladder
      residual_stop  exact sufficiency residual + phi-delta fixed-point stop
      phi_tol        phi-delta latch: a committed positive-stepsize move of
                     max|dphi| <= phi_tol means the projection map reached
                     its fixed point (set < 0 to disable)
      anderson_reg   relative Tikhonov regularization of the LS Gram matrix
      alpha_grow / alpha_shrink / alpha_min / alpha_max
                     the short adaptive ladder evaluates multipliers
                     (grow, 1, shrink, 0) on the carry alpha; the winner
                     becomes the next alpha (clipped), a 0-rung win shrinks

    Defaults are tuned on the fig5/fig6 families: Anderson(m=5) + the
    exact-residual/fixed-point stop cut total iterations ~2.4-3.4x at
    matching costs.  ``adaptive_alpha`` defaults OFF: the short 4-rung
    ladder saves 8 candidate evaluations per iteration but on congested
    instances (fig6 r>=1.5) it chases the stepsize instead of line-searching
    the full 12-rung ladder, costing more iterations than it saves — opt in
    per call when per-iteration cost dominates.
    """

    anderson_m: int = 5
    adaptive_alpha: bool = False
    residual_stop: bool = True
    phi_tol: float = 1e-6
    anderson_reg: float = 1e-8
    alpha_grow: float = 2.0
    alpha_shrink: float = 0.25
    alpha_min: float = 1e-6
    alpha_max: float = 64.0


# The tuned default config callers opt into with accel=True/"default".
DEFAULT_ACCEL = AccelConfig()


def resolve_accel(accel) -> Optional[AccelConfig]:
    """None/False -> None (legacy exact path); True/"default"/"on" ->
    :data:`DEFAULT_ACCEL`; an :class:`AccelConfig` passes through."""
    if accel is None or accel is False:
        return None
    if accel is True or accel in ("default", "on"):
        return DEFAULT_ACCEL
    if isinstance(accel, AccelConfig):
        return accel
    raise TypeError(f"accel must be None/bool/'default'/AccelConfig, got {accel!r}")


class GPState(NamedTuple):
    phi: Phi
    cost: jnp.ndarray
    residual: jnp.ndarray    # sufficiency-condition residual (0 => optimal)
    alpha: jnp.ndarray | float = 0.0   # stepsize the winning ladder rung used
    rung: jnp.ndarray | int = 0        # winning ladder-rung index
    bs_rounds: jnp.ndarray | int = -1  # blocked-set sweep rounds (§19; -1 off)


class ScanCarry(NamedTuple):
    """Carry of the chunked GP scan (DESIGN.md §10, accel fields §15,
    telemetry ring §19).

    The accel fields and the telemetry ring are zero-size placeholders
    when the matching mechanism is off (the carry pytree structure is
    fixed per static config, so the scan body simply never touches them):

      alpha    f32 scalar, the member's adaptive stepsize (0 = unseeded —
               the first iteration adopts the driver's ``alpha`` argument)
      ax / af  (m, N) ring buffers of the last m flattened iterates and
               plain-step residuals (newest last); under ``jax.vmap`` these
               gain the member axis, under ``shard_map`` the N axis holds
               the shard-local app slab (opaque, roundtripped per shard)
      ak       int32, #history pairs pushed so far
      tb       (R, TEL_WIDTH) f32 iteration-telemetry ring (§19): one row
               per committed iteration, write index = ``iters`` (both are
               masked by the ``done`` freeze and zeroed together by
               ``reset_carry``), truncating — not wrapping — past R.
               Every column is replicated under ``shard_map`` (values
               derive from the psum-reduced F/G or are pmax-reduced), so
               the ring travels with a replicated spec.
    """

    phi: Phi
    best_cost: jnp.ndarray   # float32, monotone-descent tracker
    stall: jnp.ndarray       # int32, iterations without improvement
    done: jnp.ndarray        # bool, early-stop latch
    iters: jnp.ndarray       # int32, #iterations committed so far
    cost: jnp.ndarray        # float32, last committed cost
    residual: jnp.ndarray    # float32, last committed residual
    alpha: jnp.ndarray       # float32, adaptive stepsize carry (§15)
    ax: jnp.ndarray          # (m, N) Anderson iterate history (§15)
    af: jnp.ndarray          # (m, N) Anderson residual history (§15)
    ak: jnp.ndarray          # int32, Anderson history count (§15)
    tb: jnp.ndarray          # (R, TEL_WIDTH) telemetry ring (§19)


def _pmax(x: jnp.ndarray, axis: Optional[str]) -> jnp.ndarray:
    return x if axis is None else jax.lax.pmax(x, axis)


# ---------------------------------------------------------------------------
# Blocked node sets
# ---------------------------------------------------------------------------

# Node count above which "bitset" auto-upgrades to the padded-neighbor-list
# tagged sweep when the instance carries a sparse topology.  The two are
# bit-equal (the sweep is the same monotone fixed point); the neighbor form
# does O(E) work per round instead of O(V^2), which is what matters at metro
# scale.  Matches traffic.SPARSE_MIN_V in spirit but kept separate — the
# tagged sweep's crossover is independent of the stage-solver crossover.
_NBR_AUTO_MIN_V = 128


def _tagged_nbr_sharded(route: jnp.ndarray, improper: jnp.ndarray,
                        nbr: jnp.ndarray, mask: jnp.ndarray,
                        node_axis: str, node_shards: int, *,
                        with_rounds: bool = False):
    """Node-parallel tagged sweep: each node shard owns a V/n row slab.

    The category-3 fixed point tagged[p] = ∃d: route[p,d] & (improper[p,d]
    | tagged[nbr[p,d]]) reads arbitrary *columns* (successor nodes) but
    writes only its own rows, so under a node-space mesh axis each shard
    sweeps its contiguous row slab (O(E/n) per round) and the slabs are
    re-assembled with one ``all_gather`` of the (A,K1,V) boolean frontier
    per round — the §18 2-D-mesh realization of the paper's node-parallel
    broadcast.  Monotone fixed point ⇒ bit-equal to the dense/replicated
    sweeps; the exact-settle loop exits at the shared fixed point.

    ``with_rounds=True`` also returns the loop's round counter (§19
    telemetry).  The exit test reads the all-gathered full-V frontier, so
    the counter is identical on every node shard by construction.
    """
    V = route.shape[-1]
    rl = V // node_shards
    i0 = jax.lax.axis_index(node_axis) * rl
    route_l = jax.lax.dynamic_slice_in_dim(route, i0, rl, axis=-2)
    imp_l = jax.lax.dynamic_slice_in_dim(improper, i0, rl, axis=-2)
    nbr_l = jax.lax.dynamic_slice_in_dim(nbr, i0, rl, axis=0)
    mask_l = jax.lax.dynamic_slice_in_dim(mask, i0, rl, axis=0)
    idx = jnp.broadcast_to(nbr_l, route_l.shape[:-1] + nbr_l.shape[-1:])
    rv = jnp.take_along_axis(route_l, idx, axis=-1) & mask_l
    iv = jnp.take_along_axis(imp_l, idx, axis=-1)
    seed_l = jnp.any(rv & iv, axis=-1)                       # (A,K1,rl)

    def sweep(t):
        tl = seed_l | jnp.any(rv & t[..., nbr_l], axis=-1)
        return jax.lax.all_gather(tl, node_axis, axis=-1, tiled=True)

    def cond(c):
        i, t, prev = c
        return jnp.any(t != prev) & (i < V + 1)

    def body(c):
        i, t, _ = c
        return i + 1, sweep(t), t

    t0 = jax.lax.all_gather(seed_l, node_axis, axis=-1, tiled=True)
    rounds, t, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(0), t0, jnp.zeros_like(t0) | True))
    if with_rounds:
        return t, rounds
    return t


def blocked_sets(inst: Instance, phi: Phi, pdt: jnp.ndarray,
                 method: str = "bitset", *,
                 node_axis: Optional[str] = None,
                 node_shards: int = 1,
                 with_rounds: bool = False):
    """(A,K1,V,V) bool: j in B_i(a,k).

    j is blocked for i at stage (a,k) if (Section IV "Blocked node set"):
      1) (i,j) not in E, or
      2) dD/dt_j(a,k) > dD/dt_i(a,k), or
      3) j's routing subtree for (a,k) contains an improper link (p,q)
         with dD/dt_q > dD/dt_p.

    Category 3 ("tagged" nodes) is a monotone boolean fixed point along the
    routing DAG.  method="bitset" (default) runs it through the bit-packed
    kernel — uint32-packed successor words, while-loop frontier early exit
    at the DAG diameter (kernels/blocked_sets.py, DESIGN.md §13);
    method="nbr" gathers along the instance's padded out-neighbor lists so
    each round costs O(E) (requires ``inst.has_sparse``; DESIGN.md §18) —
    "bitset" auto-upgrades to it at V >= 128 when the topology is attached,
    since the two are bit-equal; method="scan" keeps the seed's dense
    V-sweep ``lax.scan`` as the differential reference
    (tests/test_blocked_sets.py asserts bit-exact agreement — the early
    exit stops precisely at the shared fixed point).

    Entirely local to an application shard: the routing DAG of stage (a,k)
    never couples applications, so the mesh path calls this unchanged.

    ``with_rounds=True`` additionally returns the tagged sweep's settled
    round count (int32; -1 on paths without a counter — the dense scan and
    the pallas kernel).  The telemetry ring records it as the frontier-depth
    column (DESIGN.md §19); requesting it changes no blocking arithmetic.
    """
    route = phi.e > 0.0                                         # (A,K1,V,V)
    worse = pdt[:, :, None, :] > pdt[:, :, :, None] + BLOCK_EPS  # pdt_q > pdt_p
    improper = route & worse

    rounds = jnp.int32(-1)
    if (method == "bitset" and inst.has_sparse
            and inst.V >= _NBR_AUTO_MIN_V):
        method = "nbr"
    if method == "nbr":
        if (node_axis is not None and node_shards > 1
                and inst.V % node_shards == 0):
            res = _tagged_nbr_sharded(route, improper, inst.out_nbr,
                                      inst.out_mask, node_axis,
                                      node_shards, with_rounds=with_rounds)
            tagged, rounds = res if with_rounds else (res, rounds)
        elif with_rounds:
            tagged, rounds = ops.blocked_tagged_nbr(
                route, improper, inst.out_nbr, inst.out_mask,
                with_rounds=True)
        else:
            tagged = ops.blocked_tagged_nbr(route, improper,
                                            inst.out_nbr, inst.out_mask)
    elif method == "bitset":
        if with_rounds:
            tagged, rounds = ops.blocked_tagged(route, improper,
                                                with_rounds=True)
        else:
            tagged = ops.blocked_tagged(route, improper)
    else:
        tagged = blocked_sets_mod.tagged_scan_dense(route, improper)

    blocked = (~inst.adj[None, None]) | improper | worse | tagged[:, :, None, :]
    if with_rounds:
        return blocked, rounds
    return blocked


# ---------------------------------------------------------------------------
# One GP iteration (eqs. 8-10)
# ---------------------------------------------------------------------------

def _strategy_cost(inst: Instance, phi: Phi, solver: str,
                   axis: Optional[str]) -> jnp.ndarray:
    """Objective of a candidate strategy; inf when its traffic is invalid.

    Shared by the stepsize ladder and the Anderson safeguard: with ``axis``
    set, F/G psum-reduce over the app shards first, so every shard sees the
    identical replicated candidate cost (deterministic tie-breaks).
    """
    fl = flows(inst, phi, solver=solver, axis=axis)
    valid = traffic_is_valid(inst, fl.t, axis=axis)
    c_links = jnp.where(inst.adj, costs.cost(inst.link_kind, fl.F,
                                             inst.link_param), 0.0)
    c_nodes = costs.cost(inst.comp_kind, fl.G, inst.comp_param)
    cost = jnp.sum(c_links) + jnp.sum(c_nodes)
    return jnp.where(valid, cost, jnp.inf)


def gp_step(
    inst: Instance,
    phi: Phi,
    alpha: float,
    allowed_e: Optional[jnp.ndarray] = None,
    allowed_c: Optional[jnp.ndarray] = None,
    scaled: bool = False,
    solver: str = "auto",
    *,
    blocked: str = "bitset",
    axis: Optional[str] = None,
    node_axis: Optional[str] = None,
    node_shards: int = 1,
    accel: Optional[AccelConfig] = None,
    app_mask: Optional[jnp.ndarray] = None,
    telemetry: Optional[TelemetryConfig] = None,
) -> GPState:
    """One fused GP iteration; ``axis`` selects the F/G reduction (above).

    ``node_axis``/``node_shards`` name the second (node-space) mesh axis of
    the 2-D mesh (DESIGN.md §18): when set, the blocked-set tagged sweep
    runs node-parallel over row slabs (``_tagged_nbr_sharded``); all other
    per-iteration compute is replicated across the node shards, so the
    iteration stays bit-equal to the 1-D mesh and single-device paths.

    ``app_mask`` ((A,) bool, optional) freezes applications: where False,
    the committed strategy rows are the *incoming* ``phi`` rows regardless
    of what the projection proposes, and the reported residual ignores the
    frozen applications' directions.  The freeze is applied *inside* each
    ladder candidate before its flows are measured, so the evaluated costs
    are exactly the costs of the committed strategies — frozen applications
    still contribute their (unchanged) flows to the shared F/G measurement,
    which is what makes the restricted solve exact for the active set.
    This is the §16 residual skip gate (``serve/online.py``): applications
    whose sufficiency residual an event left below tolerance are frozen,
    re-checked after the active set converges, and unfrozen only if the
    active set's movement pushed them back above tolerance.
    """
    # One batched LU of every (app, stage) system per iteration: the traffic
    # sweep solves the transposed systems and the marginal recursion the
    # plain ones from the SAME factors (traffic.stage_factors, DESIGN.md
    # §12).  The sparse path is factorization-free — both sweeps run the
    # neighbor-list fixed point directly (§18).  The ladder's candidate
    # evaluations below factor their own (ladder, A, K1)-stacked batch
    # inside the vmap.  "auto" resolves per backend/size/topology at trace
    # time (traffic.resolve_solver).
    solver = traffic_mod.resolve_solver(solver, inst.V, inst)
    fact = traffic_mod.stage_factors(phi.e) if solver == "batched_lu" else None
    fl = flows(inst, phi, fact, solver=solver, axis=axis)
    m = marginals(inst, phi, fl, fact, solver=solver)

    want_rounds = telemetry is not None and telemetry.bs_rounds
    if want_rounds:
        bset, bs_rounds = blocked_sets(
            inst, phi, m.pdt, method=blocked,
            node_axis=node_axis, node_shards=node_shards, with_rounds=True)
        # per-app-shard sweeps may settle at different depths; report the
        # fleet-wide maximum so the ring column is replicated (§19)
        bs_rounds = _pmax(bs_rounds, axis)
    else:
        bset = blocked_sets(inst, phi, m.pdt, method=blocked,
                            node_axis=node_axis, node_shards=node_shards)
        bs_rounds = jnp.int32(-1)
    avail_e = inst.adj[None, None] & ~bset
    if allowed_e is not None:
        avail_e = avail_e & allowed_e
    avail_c = inst.cpu_allowed()[:, :, None]
    if allowed_c is not None:
        avail_c = avail_c & allowed_c

    delta_e = jnp.where(avail_e, m.delta_e, BIG)
    delta_c = jnp.where(avail_c, m.delta_c, BIG)
    min_delta = jnp.minimum(delta_e.min(-1), delta_c)           # (A,K1,V)

    # Fallback guard: if blocking removed every direction at a row that must
    # forward (can happen transiently on congested iterates), fall back to
    # the unblocked-by-topology direction set for that row.
    stuck = min_delta >= BIG / 2
    fb_e = jnp.where(inst.adj[None, None] & (allowed_e if allowed_e is not None else True), m.delta_e, BIG)
    fb_c = jnp.where(inst.cpu_allowed()[:, :, None] & (allowed_c if allowed_c is not None else True), m.delta_c, BIG)
    delta_e = jnp.where(stuck[..., None], fb_e, delta_e)
    delta_c = jnp.where(stuck, fb_c, delta_c)
    min_delta = jnp.minimum(delta_e.min(-1), delta_c)

    e_e = delta_e - min_delta[..., None]                        # e_ij >= 0
    e_c = delta_c - min_delta
    if scaled:
        # quasi-Newton diagonal scaling (the second-order speedup the paper
        # attributes to [5]): normalize the projection step by a curvature
        # surrogate so stepsizes are comparable across congestion levels.
        # D'' of the M/M/1 cost ~ 2 D'/(cap-F) ~ D'^2-scale; we use the
        # per-row marginal magnitude as the diagonal preconditioner.
        scale_row = jnp.maximum(jnp.abs(min_delta), 1e-6)
        e_e = e_e / scale_row[..., None]
        e_c = e_c / scale_row

    is_min_e = (e_e <= TIE_EPS) & (delta_e < BIG / 2)
    is_min_c = (e_c <= TIE_EPS) & (delta_c < BIG / 2)
    N = is_min_e.sum(-1) + is_min_c                             # (A,K1,V)

    # reductions: blocked directions surrender everything; positive-e
    # directions surrender min(phi, alpha * e)   (eq. 9)
    def apply(a):
        red_e = jnp.where(
            delta_e >= BIG / 2, phi.e,
            jnp.where(is_min_e, 0.0, jnp.minimum(phi.e, a * e_e)),
        )
        red_c = jnp.where(
            delta_c >= BIG / 2, phi.c,
            jnp.where(is_min_c, 0.0, jnp.minimum(phi.c, a * e_c)),
        )
        share = (red_e.sum(-1) + red_c) / jnp.maximum(N, 1)     # (A,K1,V)
        cand = renormalize(inst, Phi(
            e=phi.e - red_e + share[..., None] * is_min_e,
            c=phi.c - red_c + share * is_min_c,
        ))
        if app_mask is not None:
            # frozen apps keep their incoming rows; applied BEFORE the flow
            # measurement so the ladder costs what it would actually commit
            cand = Phi(
                e=jnp.where(app_mask[:, None, None, None], cand.e, phi.e),
                c=jnp.where(app_mask[:, None, None], cand.c, phi.c),
            )
        cand_fl = flows(inst, cand, solver=solver, axis=axis)
        valid = traffic_is_valid(inst, cand_fl.t, axis=axis)
        c_links = jnp.where(inst.adj, costs.cost(inst.link_kind, cand_fl.F, inst.link_param), 0.0)
        c_nodes = costs.cost(inst.comp_kind, cand_fl.G, inst.comp_param)
        cost = jnp.sum(c_links) + jnp.sum(c_nodes)
        return cand, jnp.where(valid, cost, jnp.inf)

    if accel is not None and accel.adaptive_alpha:
        # short adaptive ladder centered on the carry alpha (§15): probe one
        # growth rung, the current stepsize, one shrink rung, and 0 (the
        # monotone-descent floor); the caller feeds the winner back in.
        mults = (accel.alpha_grow, 1.0, accel.alpha_shrink, 0.0)
    else:
        mults = ALPHA_LADDER
    ladder = alpha * jnp.asarray(mults, dtype=jnp.float32)
    cands, cand_costs = jax.vmap(apply)(ladder)
    # a too-aggressive candidate can form a routing loop -> divergent traffic
    # fixed point -> inf/NaN cost; such candidates must lose the argmin.
    # cand_costs derive from the psum-reduced F/G, so every shard computes
    # the identical replicated ladder and picks the same argmin.
    cand_costs = jnp.where(jnp.isnan(cand_costs), jnp.inf, cand_costs)
    best = jnp.argmin(cand_costs)
    new_phi = jax.tree_util.tree_map(lambda x: x[best], cands)

    # residual of sufficiency condition (6) at the *new* iterate, computed
    # cheaply from the current marginals (exact residual is recomputed by
    # the caller when it matters)
    if accel is not None and accel.residual_stop:
        # exact conditions.sufficiency_residual form: the minimum is taken
        # over *all* directions, not the blocked-masked set, so the latch
        # agrees with the checker callers use to certify optimality.
        min_margin = jnp.minimum(m.delta_e.min(-1), m.delta_c)
        exc_e = jnp.where(phi.e > 1e-6, m.delta_e - min_margin[..., None], 0.0)
        exc_c = jnp.where(phi.c > 1e-6, m.delta_c - min_margin, 0.0)
    else:
        exc_e = jnp.where(phi.e > 1e-6, m.delta_e - min_delta[..., None], 0.0)
        exc_c = jnp.where(phi.c > 1e-6, m.delta_c - min_delta, 0.0)
    if app_mask is not None:
        # the stop latch must not wait on frozen apps: their drift is
        # re-checked by the caller's outer gate, not by this solve
        exc_e = jnp.where(app_mask[:, None, None, None], exc_e, 0.0)
        exc_c = jnp.where(app_mask[:, None, None], exc_c, 0.0)
    residual = _pmax(jnp.maximum(jnp.max(exc_e), jnp.max(exc_c)), axis)

    return GPState(phi=new_phi, cost=cand_costs[best], residual=residual,
                   alpha=ladder[best], rung=best, bs_rounds=bs_rounds)


# ---------------------------------------------------------------------------
# Anderson mixing helpers (§15)
# ---------------------------------------------------------------------------

def _flat_phi(phi: Phi) -> jnp.ndarray:
    """Flatten a (possibly shard-local) strategy into one f32 vector."""
    return jnp.concatenate(
        [phi.e.reshape(-1), phi.c.reshape(-1)]).astype(jnp.float32)


def _unflat_phi(vec: jnp.ndarray, like: Phi) -> Phi:
    ne = like.e.size
    return Phi(e=vec[:ne].reshape(like.e.shape).astype(like.e.dtype),
               c=vec[ne:].reshape(like.c.shape).astype(like.c.dtype))


def _anderson_mix(ax, af, ak, x_k, f_k, reg: float,
                  axis: Optional[str]) -> jnp.ndarray:
    """Type-II windowed Anderson combination of the fixed-point map g.

    Given the current evaluated pair ``(x_k, f_k)`` (``f = g(x) - x``, the
    plain GP step's displacement) and ring buffers of the last ``m`` pairs,
    solve the regularized least-squares problem

        min_gamma || f_k - sum_j gamma_j (f_k - f_j) ||

    via the (m, m) normal equations and return the mixed iterate

        x_mix = g_k - sum_j gamma_j (g_k - g_j),  g = x + f.

    Slots never written (``j < m - ak``) contribute zero rows; Tikhonov
    regularization keeps the Gram matrix invertible, and their gamma is
    masked to exactly 0.  Under ``axis`` the feature dimension N is the
    shard-local app slab, so the Gram matrix and right-hand side psum over
    the mesh axis — every shard then solves the identical (m, m) system
    and applies the identical gamma to its own slab.
    """
    m = ax.shape[0]
    valid = jnp.arange(m) >= (m - jnp.minimum(ak, m))            # (m,)
    dF = jnp.where(valid[:, None], f_k[None, :] - af, 0.0)       # (m, N)
    gram = dF @ dF.T                                             # (m, m)
    b = dF @ f_k                                                 # (m,)
    if axis is not None:
        gram = jax.lax.psum(gram, axis)
        b = jax.lax.psum(b, axis)
    lam = reg * (jnp.trace(gram) / m) + 1e-12
    gamma = jnp.linalg.solve(gram + lam * jnp.eye(m, dtype=gram.dtype), b)
    gamma = jnp.where(valid, gamma, 0.0)
    g_k = x_k + f_k
    g_hist = ax + af                                             # (m, N)
    return g_k - gamma @ (g_k[None, :] - g_hist)


def _push_history(buf: jnp.ndarray, row: jnp.ndarray) -> jnp.ndarray:
    """Drop the oldest ring-buffer row and append ``row`` (newest last)."""
    return jnp.roll(buf, -1, axis=0).at[-1].set(row)


# ---------------------------------------------------------------------------
# Chunked scan loop body (shared by gp.solve* and distributed.solve_sharded*)
# ---------------------------------------------------------------------------

def init_carry(inst: Instance, phi: Phi, *, solver: str = "auto",
               axis: Optional[str] = None,
               accel: Optional[AccelConfig] = None,
               telemetry: Optional[TelemetryConfig] = None) -> ScanCarry:
    cost0 = jnp.asarray(total_cost(inst, phi, solver=solver, axis=axis),
                        jnp.float32)
    m = accel.anderson_m if accel is not None else 0
    n = (phi.e.size + phi.c.size) if m > 0 else 0
    return ScanCarry(
        phi=phi,
        best_cost=cost0,
        stall=jnp.int32(0),
        done=jnp.asarray(False),
        iters=jnp.int32(0),
        cost=cost0,
        residual=jnp.float32(jnp.inf),
        alpha=jnp.float32(0.0),
        ax=jnp.zeros((m, n), jnp.float32),
        af=jnp.zeros((m, n), jnp.float32),
        ak=jnp.int32(0),
        tb=empty_ring(telemetry),
    )


def reset_carry(inst: Instance, phi: Phi, carry: ScanCarry, *,
                keep_window: bool = False, solver: str = "auto",
                axis: Optional[str] = None) -> ScanCarry:
    """Re-arm a converged carry for a new re-convergence (online events).

    Rebuilds the bookkeeping fields around the (possibly repaired) live
    strategy ``phi`` — fresh cost/best-cost at the *current* instance,
    cleared stall/done/iters latches — while optionally carrying the §15
    acceleration state across the event:

      * ``keep_window=True`` keeps the Anderson ring buffers and the
        adaptive stepsize.  Correct for *small rate deltas*: the stored
        (x, f) pairs were evaluated under the old rates, so the mixer's
        extrapolation is approximate, but the scan body's safeguard
        (projected-feasible AND no-worse-than-the-plain-step, costed under
        the NEW instance) rejects any mix the stale history misleads —
        descent is preserved, and on small deltas the stale window still
        cuts the re-convergence (DESIGN.md §16).
      * ``keep_window=False`` (default) zeroes the window — required after
        topology events (failures, arrivals), where the fixed-point map
        itself changed shape and stale pairs are pure noise.

    The carry's pytree structure (accel slab sizes) is preserved either
    way, so re-armed carries keep hitting the compiled chunk programs.
    """
    cost0 = jnp.asarray(total_cost(inst, phi, solver=solver, axis=axis),
                        jnp.float32)
    keep = jnp.asarray(keep_window)
    return carry._replace(
        phi=phi,
        best_cost=cost0,
        stall=jnp.int32(0),
        done=jnp.asarray(False),
        iters=jnp.int32(0),
        cost=cost0,
        residual=jnp.float32(jnp.inf),
        alpha=jnp.where(keep, carry.alpha, jnp.float32(0.0)),
        ax=jnp.where(keep, carry.ax, jnp.zeros_like(carry.ax)),
        af=jnp.where(keep, carry.af, jnp.zeros_like(carry.af)),
        ak=jnp.where(keep, carry.ak, jnp.int32(0)),
        # the ring restarts with iters: callers drain it *before* resetting
        # (serve/online.py) — the valid prefix is always rows [0, iters)
        tb=jnp.zeros_like(carry.tb),
    )


def scan_chunk(
    inst: Instance,
    carry: ScanCarry,
    alpha, tol, patience, max_iters,
    allowed_e: Optional[jnp.ndarray], allowed_c: Optional[jnp.ndarray],
    *,
    length: int,
    scaled: bool = False,
    solver: str = "auto",
    blocked: str = "bitset",
    axis: Optional[str] = None,
    node_axis: Optional[str] = None,
    node_shards: int = 1,
    accel: Optional[AccelConfig] = None,
    app_mask: Optional[jnp.ndarray] = None,
    telemetry: Optional[TelemetryConfig] = None,
):
    """Advance the solve by up to ``length`` iterations entirely on device.

    Early-stop is a *mask*, not a break: once ``done`` latches (residual
    below tol, ladder-stationary for ``patience`` iterations, the
    ``max_iters`` budget spent, or — with ``accel.residual_stop`` — a
    committed positive-stepsize move below ``accel.phi_tol``) the carry is
    frozen and subsequent steps re-emit the converged (cost, residual),
    keeping history shapes static.

    With ``accel`` set the body additionally runs the §15 layer: the plain
    step seeds an Anderson candidate from the carry's history window, the
    candidate is accepted only if it is projected-feasible and at least as
    cheap as the plain step (otherwise the plain step commits — the
    safeguard that preserves monotone descent), and the adaptive stepsize
    carry adopts the winning rung.

    Not jitted here — the single-device drivers wrap it in ``jax.jit``
    (``gp._scan_chunk``) and the mesh driver traces it inside
    ``shard_map`` (``distributed._chunk_program``), where the ``axis``
    collectives bind to the mesh.
    """
    use_anderson = accel is not None and accel.anderson_m > 0
    use_adaptive = accel is not None and accel.adaptive_alpha
    use_phistop = (accel is not None and accel.residual_stop
                   and accel.phi_tol >= 0)

    def body(c: ScanCarry, _):
        if use_adaptive:
            # carry alpha 0 = unseeded (first iteration / legacy warm
            # start): adopt the driver's alpha argument.
            alpha_eff = jnp.where(c.alpha > 0, c.alpha,
                                  jnp.float32(alpha))
        else:
            alpha_eff = alpha
        state = gp_step(inst, c.phi, alpha_eff, allowed_e, allowed_c, scaled,
                        solver, blocked=blocked, axis=axis,
                        node_axis=node_axis, node_shards=node_shards,
                        accel=accel, app_mask=app_mask, telemetry=telemetry)

        new_phi, new_cost = state.phi, state.cost
        ax, af, ak = c.ax, c.af, c.ak
        if use_anderson:
            x_k = _flat_phi(c.phi)
            f_k = _flat_phi(state.phi) - x_k
            mix = _anderson_mix(ax, af, ak, x_k, f_k,
                                accel.anderson_reg, axis)
            phi_mix = renormalize(inst, _unflat_phi(mix, c.phi))
            if app_mask is not None:
                # the mixer extrapolates over the full flattened phi; frozen
                # apps must stay exactly frozen (applied before costing, so
                # the safeguard evaluates the committed strategy)
                phi_mix = Phi(
                    e=jnp.where(app_mask[:, None, None, None],
                                phi_mix.e, c.phi.e),
                    c=jnp.where(app_mask[:, None, None], phi_mix.c, c.phi.c),
                )
            cost_mix = _strategy_cost(inst, phi_mix, solver, axis)
            cost_mix = jnp.where(jnp.isnan(cost_mix), jnp.inf, cost_mix)
            feas = _pmax(
                traffic_mod.feasibility_violation(inst, phi_mix), axis)
            # safeguard: accept only a feasible, no-worse mixed iterate
            # (rejection falls back to the already-committed plain step)
            accept = (ak >= 1) & (cost_mix <= state.cost) & (feas <= 1e-5)
            new_phi = jax.tree_util.tree_map(
                lambda mx, pl: jnp.where(accept, mx, pl),
                phi_mix, state.phi)
            new_cost = jnp.where(accept, cost_mix, state.cost)
            # history holds genuinely *evaluated* pairs of the plain map
            ax = _push_history(ax, x_k)
            af = _push_history(af, f_k)
            ak = jnp.minimum(ak + 1, jnp.int32(accel.anderson_m))

        frz = c.done
        phi = jax.tree_util.tree_map(
            lambda new, old: jnp.where(frz, old, new), new_phi, c.phi)
        cost = jnp.where(frz, c.cost, new_cost)
        residual = jnp.where(frz, c.residual, state.residual)
        improved = new_cost < c.best_cost * (1 - 1e-6)
        best = jnp.where(frz | ~improved, c.best_cost, new_cost)
        stall = jnp.where(frz, c.stall, jnp.where(improved, 0, c.stall + 1))
        iters = c.iters + jnp.where(frz, 0, 1).astype(jnp.int32)
        done = frz | (residual <= tol) | (stall >= patience) | (iters >= max_iters)

        if use_adaptive:
            chosen = state.alpha
            na = jnp.where(chosen > 0,
                           jnp.clip(chosen, accel.alpha_min, accel.alpha_max),
                           jnp.maximum(alpha_eff * accel.alpha_shrink,
                                       accel.alpha_min))
            new_alpha = jnp.where(frz, c.alpha, jnp.float32(na))
        else:
            new_alpha = c.alpha
        if use_anderson:
            ax = jax.tree_util.tree_map(
                lambda new, old: jnp.where(frz, old, new), ax, c.ax)
            af = jax.tree_util.tree_map(
                lambda new, old: jnp.where(frz, old, new), af, c.af)
            ak = jnp.where(frz, c.ak, ak)
        if use_phistop or telemetry is not None:
            # phi-delta of the committed move; pmax-replicated across app
            # shards.  Shared by the §15 fixed-point latch and the §19
            # telemetry column (computed once when both are on).
            moved = jnp.maximum(jnp.max(jnp.abs(new_phi.e - c.phi.e)),
                                jnp.max(jnp.abs(new_phi.c - c.phi.c)))
            moved = _pmax(moved, axis)
        if use_phistop:
            # phi-delta fixed point: a committed move at positive stepsize
            # that left phi (numerically) unchanged means the projection
            # map is stationary.  Gate on chosen > 0 so a 0-rung win (the
            # ladder rejecting every positive step) doesn't latch early.
            fixed = (state.alpha > 0) & (moved <= accel.phi_tol)
            done = done | (~frz & fixed)

        tb = c.tb
        if telemetry is not None:
            # every operand is already replicated across the mesh (cost,
            # residual, alpha and rung derive from the psum-reduced ladder;
            # bs_rounds and moved were pmax'd above), so the ring rides the
            # carry with a replicated spec and costs no extra collectives.
            if use_anderson:
                anders = jnp.where(accept, 1.0, 0.0).astype(jnp.float32)
            else:
                anders = jnp.float32(-1.0)
            row = jnp.stack([
                c.iters.astype(jnp.float32),           # COL_ITER
                new_cost.astype(jnp.float32),          # COL_COST
                state.residual.astype(jnp.float32),    # COL_RESIDUAL
                state.alpha.astype(jnp.float32),       # COL_ALPHA
                jnp.asarray(state.rung, jnp.float32),  # COL_RUNG
                anders,                                # COL_ANDERSON
                jnp.asarray(state.bs_rounds, jnp.float32),  # COL_BS_ROUNDS
                moved.astype(jnp.float32),             # COL_PHI_DELTA
            ])
            tb = ring_record(tb, c.iters, row, ~frz)

        nc = ScanCarry(phi=phi, best_cost=best, stall=stall, done=done,
                       iters=iters, cost=cost, residual=residual,
                       alpha=new_alpha, ax=ax, af=af, ak=ak, tb=tb)
        return nc, (cost, residual)

    return jax.lax.scan(body, carry, None, length=length)
