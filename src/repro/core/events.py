"""Typed online events for the streaming solver service (DESIGN.md §16).

The paper's Section IV argues the distributed GP algorithm "adapts to
changes in input rates and network topology, and can be implemented as an
online algorithm".  This module gives that claim a concrete event model: a
small algebra of typed events over a *fleet* of padded instances —

  * :class:`RateScale`      — an application's exogenous input rates scale
  * :class:`LinkDown` / :class:`LinkUp`   — a directed link fails / recovers
  * :class:`NodeDown`       — a node fails (all incident links, local rates)
  * :class:`AppArrival` / :class:`AppDeparture` — a service chain joins /
    leaves, using the spare application slots of the padded envelope

— plus :func:`apply_event`, the pure host-side transition
``Instance -> Instance`` that also reports what the event disturbed (an
:class:`EventEffect`), and :func:`random_trace`, a feasibility-preserving
trace generator for benchmarks and tests.

Events reference fleet members by index; each event touches exactly one
member.  ``apply_event`` operates on a single (padded) member instance and
never changes array shapes — topology and application churn happen *within*
the padded envelope, which is what lets ``serve.online.OnlineSolver`` keep
one compiled device program across the whole event stream (§9 padding
invariants do the heavy lifting: a departed app is just a dead app row).

Feasibility discipline of :func:`random_trace`: a link or node failure is
only emitted if afterwards every live node still reaches every live
application's destination (BFS check), so the repaired strategy
(``traffic.repair_phi``) always has a finite-cost route to fall back on;
rate scalings keep each application's cumulative factor inside a bounded
window so the queueing cost families stay in their stable region.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import jax.numpy as jnp
import numpy as np

from repro.core import batch
from repro.core.network import Instance

# ---------------------------------------------------------------------------
# Event types
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RateScale:
    """Scale application ``app``'s input rates by ``factor`` (all apps of
    the member when ``app`` is None)."""

    member: int
    factor: float
    app: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class LinkDown:
    """Directed link (i, j) fails: removed from the graph, capacity zeroed."""

    member: int
    i: int
    j: int


@dataclasses.dataclass(frozen=True)
class LinkUp:
    """Directed link (i, j) (re)appears with the given capacity/coefficient."""

    member: int
    i: int
    j: int
    capacity: float


@dataclasses.dataclass(frozen=True)
class NodeDown:
    """Node fails: every incident link removed, exogenous input at the node
    zeroed; applications destined *to* the node depart."""

    member: int
    node: int


@dataclasses.dataclass(frozen=True)
class AppArrival:
    """A new service chain occupies dead application slot ``app``.

    ``rates`` is a tuple of (source node, rate) pairs.  Packet sizes follow
    the paper's ``L_(a,k) = 10 - 5k`` profile (floored at 0.01, DESIGN.md
    §8) and computation weights are 1 for every computed task.
    """

    member: int
    app: int
    dst: int
    rates: tuple = ()
    n_tasks: int = 2


@dataclasses.dataclass(frozen=True)
class AppDeparture:
    """Application slot ``app`` leaves: its rates and stages are cleared and
    the slot becomes a dead row under the §9 padding invariants."""

    member: int
    app: int


Event = Union[RateScale, LinkDown, LinkUp, NodeDown, AppArrival, AppDeparture]

# Anderson-window carry policy (§16): a rate delta whose factor sits inside
# this window is "small" — the optimum moves continuously, so the solver may
# keep its §15 acceleration history across the event.
SMALL_RATE_WINDOW = (0.5, 2.0)


@dataclasses.dataclass(frozen=True)
class EventEffect:
    """What :func:`apply_event` disturbed, for the solver's skip gates.

    ``topology``   — the direction sets changed (links/nodes/apps appeared
                     or vanished): the strategy needs ``traffic.repair_phi``
                     and the acceleration window must be cleared.
    ``small``      — a rate delta inside :data:`SMALL_RATE_WINDOW`: the
                     Anderson window may be carried across the event.
    ``touched``    — (A,) bool: applications whose *own* problem data
                     changed.  Everything else is only disturbed through
                     shared congestion, which the per-app sufficiency
                     residual gate detects (``conditions.per_app_residual``).
    ``dead_links`` — directed links this event removed; the solver marks
                     applications carrying strategy mass on them as touched
                     (the effect itself cannot, as it never sees phi).
    ``shed``       — application slots this event forcibly departed because
                     their traffic sources can no longer reach their
                     destination (graceful degradation: an isolated
                     destination sheds its chain instead of producing an
                     unroutable — NaN-cost — problem).
    """

    topology: bool
    small: bool
    touched: np.ndarray
    dead_links: tuple = ()
    shed: tuple = ()


# ---------------------------------------------------------------------------
# Event application (pure, host-side)
# ---------------------------------------------------------------------------


def _default_chain(K1: int, n_tasks: int):
    """Packet sizes / weights / stage mask of the builder's default chain."""
    L = np.maximum(10.0 - 5.0 * np.arange(K1), 0.01)
    w = np.where(np.arange(K1) < n_tasks, 1.0, 0.0)
    mask = np.arange(K1) <= n_tasks
    return L, w, mask


def _check_index(v: int, n: int, what: str) -> None:
    """Bounds-check an event index.  jnp's clamped indexing would otherwise
    turn an out-of-range slot/node into a silent write to the LAST one."""
    if not 0 <= v < n:
        raise ValueError(f"{what} {v} out of range [0, {n})")


def _reverse_reach(adj: np.ndarray, d: int) -> np.ndarray:
    """(V,) bool: which nodes have a directed path to ``d`` (reverse BFS)."""
    seen = np.zeros(adj.shape[0], dtype=bool)
    seen[d] = True
    stack = [int(d)]
    while stack:
        v = stack.pop()
        for u in np.flatnonzero(adj[:, v] & ~seen):
            seen[u] = True
            stack.append(int(u))
    return seen


def _shed_unreachable(inst: Instance, touched: np.ndarray):
    """Depart applications whose live sources lost every route to their dst.

    Failures sampled by :func:`random_trace` preserve connectivity so this
    never fires there; hand-written or chaos traces may isolate a
    destination, and an unroutable chain has NO finite-cost strategy — the
    graceful response is to shed the chain (a dead padded row), not to let
    the solver diverge.  Returns (inst, touched, shed_slots).
    """
    adj = np.asarray(inst.adj)
    r = np.asarray(inst.r)
    live = np.asarray(inst.stage_mask).any(axis=1)
    dst = np.asarray(inst.dst)
    shed = []
    for a in np.flatnonzero(live):
        srcs = np.flatnonzero(r[a] > 0)
        if len(srcs) and not _reverse_reach(adj, int(dst[a]))[srcs].all():
            shed.append(int(a))
    if not shed:
        return inst, touched, ()
    gone = np.zeros(inst.A, dtype=bool)
    gone[shed] = True
    inst = dataclasses.replace(
        inst,
        r=jnp.where(gone[:, None], 0.0, inst.r),
        stage_mask=jnp.where(gone[:, None], False, inst.stage_mask),
        n_tasks=jnp.where(gone, 0, inst.n_tasks),
    )
    return inst, touched & ~gone, tuple(shed)


def apply_event(inst: Instance, ev: Event) -> tuple[Instance, EventEffect]:
    """Apply one event to a (padded) member instance.

    Pure: returns a new :class:`Instance` with identical array shapes plus
    an :class:`EventEffect` describing the disturbance.  Raises ValueError
    for structurally invalid events (dead slot arrivals excepted — arriving
    into a live slot, failing a non-existent link, ...), so traces written
    by hand fail loudly instead of silently solving the wrong problem.
    """
    A = inst.A
    touched = np.zeros(A, dtype=bool)

    if isinstance(ev, RateScale):
        if not (np.isfinite(ev.factor) and ev.factor > 0):
            raise ValueError(f"RateScale: factor {ev.factor} must be a "
                             "finite positive number")
        if ev.app is not None:
            _check_index(ev.app, A, "RateScale: app")
            if not bool(inst.stage_mask[ev.app].any()):
                raise ValueError(f"RateScale: slot {ev.app} is dead")
        if ev.app is None:
            r = inst.r * ev.factor
            touched[:] = np.asarray(inst.stage_mask).any(axis=1)
        else:
            r = inst.r.at[ev.app].multiply(ev.factor)
            touched[ev.app] = True
        lo, hi = SMALL_RATE_WINDOW
        small = lo <= ev.factor <= hi
        new = dataclasses.replace(inst, r=r)
        return new, EventEffect(topology=False, small=small, touched=touched)

    if isinstance(ev, LinkDown):
        _check_index(ev.i, inst.V, "LinkDown: node")
        _check_index(ev.j, inst.V, "LinkDown: node")
        if not bool(inst.adj[ev.i, ev.j]):
            raise ValueError(f"LinkDown({ev.i},{ev.j}): link does not exist")
        new = dataclasses.replace(
            inst,
            adj=inst.adj.at[ev.i, ev.j].set(False),
            link_param=inst.link_param.at[ev.i, ev.j].set(0.0),
        )
        new, touched, shed = _shed_unreachable(new, touched)
        return new, EventEffect(topology=True, small=False, touched=touched,
                                dead_links=((ev.i, ev.j),), shed=shed)

    if isinstance(ev, LinkUp):
        _check_index(ev.i, inst.V, "LinkUp: node")
        _check_index(ev.j, inst.V, "LinkUp: node")
        if bool(inst.adj[ev.i, ev.j]):
            raise ValueError(f"LinkUp({ev.i},{ev.j}): link already exists")
        if ev.i == ev.j or not np.isfinite(ev.capacity) or ev.capacity <= 0:
            raise ValueError(f"LinkUp({ev.i},{ev.j}): invalid link")
        new = dataclasses.replace(
            inst,
            adj=inst.adj.at[ev.i, ev.j].set(True),
            link_param=inst.link_param.at[ev.i, ev.j].set(ev.capacity),
        )
        # Nobody's data changed; apps that *should* use the new link are
        # caught by the residual gate (the new direction lowers min_margin).
        return new, EventEffect(topology=True, small=False, touched=touched)

    if isinstance(ev, NodeDown):
        v = ev.node
        _check_index(v, inst.V, "NodeDown: node")
        adj_np = np.asarray(inst.adj)
        if not (adj_np[v].any() or adj_np[:, v].any()):
            raise ValueError(f"NodeDown({v}): node already dead")
        dead = tuple((v, int(j)) for j in np.flatnonzero(adj_np[v])) + \
            tuple((int(i), v) for i in np.flatnonzero(adj_np[:, v]))
        adj = inst.adj.at[v, :].set(False).at[:, v].set(False)
        link_param = inst.link_param.at[v, :].set(0.0).at[:, v].set(0.0)
        r = inst.r.at[:, v].set(0.0)
        touched = np.array(inst.r[:, v] > 0)
        # Applications destined to the failed node depart with it.
        gone = np.asarray(inst.dst == v) & np.asarray(inst.stage_mask).any(1)
        stage_mask = jnp.where(gone[:, None], False, inst.stage_mask)
        r = jnp.where(gone[:, None], 0.0, r)
        touched &= ~gone
        new = dataclasses.replace(inst, adj=adj, link_param=link_param,
                                  r=r, stage_mask=stage_mask)
        new, touched, shed = _shed_unreachable(new, touched)
        return new, EventEffect(topology=True, small=False, touched=touched,
                                dead_links=dead, shed=shed)

    if isinstance(ev, AppArrival):
        a = ev.app
        _check_index(a, A, "AppArrival: slot")
        _check_index(ev.dst, inst.V, "AppArrival: dst")
        if bool(inst.stage_mask[a].any()):
            raise ValueError(f"AppArrival: slot {a} is live")
        if ev.n_tasks + 1 > inst.K1:
            raise ValueError(f"AppArrival: chain needs K1 >= {ev.n_tasks + 1}")
        # Admission control: every source must have a route to the
        # destination under the CURRENT topology, else the chain has no
        # finite-cost strategy and would poison the whole member.
        reach = _reverse_reach(np.asarray(inst.adj), ev.dst)
        L_row, w_row, mask_row = _default_chain(inst.K1, ev.n_tasks)
        r_row = np.zeros(inst.V)
        for node, rate in ev.rates:
            _check_index(node, inst.V, "AppArrival: source")
            if not (np.isfinite(rate) and rate >= 0):
                raise ValueError(f"AppArrival: rate {rate} at node {node} "
                                 "must be finite and non-negative")
            if rate > 0 and not bool(reach[node]):
                raise ValueError(f"AppArrival: source {node} cannot reach "
                                 f"dst {ev.dst} — admission rejected")
            r_row[node] = rate
        new = dataclasses.replace(
            inst,
            L=inst.L.at[a].set(jnp.asarray(L_row, dtype=inst.L.dtype)),
            w=inst.w.at[a].set(jnp.asarray(w_row, dtype=inst.w.dtype)),
            r=inst.r.at[a].set(jnp.asarray(r_row, dtype=inst.r.dtype)),
            dst=inst.dst.at[a].set(ev.dst),
            n_tasks=inst.n_tasks.at[a].set(ev.n_tasks),
            stage_mask=inst.stage_mask.at[a].set(jnp.asarray(mask_row)),
        )
        touched[a] = True
        return new, EventEffect(topology=True, small=False, touched=touched)

    if isinstance(ev, AppDeparture):
        a = ev.app
        _check_index(a, A, "AppDeparture: slot")
        if not bool(inst.stage_mask[a].any()):
            raise ValueError(f"AppDeparture: slot {a} already dead")
        new = dataclasses.replace(
            inst,
            r=inst.r.at[a].set(0.0),
            stage_mask=inst.stage_mask.at[a].set(False),
            n_tasks=inst.n_tasks.at[a].set(0),
        )
        # The departed app needs no solving (its rows become degenerate and
        # renormalize zeroes them); survivors are relieved congestion, which
        # the residual gate picks up.
        return new, EventEffect(topology=True, small=False, touched=touched)

    raise TypeError(f"unknown event type {type(ev).__name__}")


def replay(members: Sequence[Instance], trace: Sequence[Event]):
    """Replay a trace over a member list; yields (event, instance, effect)
    with ``instance`` the event's member *after* the event."""
    members = list(members)
    out = []
    for ev in trace:
        members[ev.member], eff = apply_event(members[ev.member], ev)
        out.append((ev, members[ev.member], eff))
    return out


# ---------------------------------------------------------------------------
# Fleet construction
# ---------------------------------------------------------------------------


def pad_fleet(insts: Sequence[Instance], spare_apps: int = 0) -> list[Instance]:
    """Pad a fleet to its common envelope plus ``spare_apps`` extra dead
    application slots per member (room for :class:`AppArrival` events).

    Members stay separate instances (stack with ``batch.pad_instances`` /
    ``jax.tree_util.tree_map``); shapes are already uniform so event replay
    and the online solver agree on slot indices.
    """
    V, A, K1 = batch.batch_envelope(insts)
    return [batch.pad_instance(i, V, A + spare_apps, K1) for i in insts]


# ---------------------------------------------------------------------------
# Trace generation
# ---------------------------------------------------------------------------


def _reaches_all_dsts(adj: np.ndarray, dsts: Sequence[int]) -> bool:
    """True iff every node with an outgoing link reaches every dst in
    ``dsts`` (reverse BFS from each destination)."""
    live = adj.any(axis=1)
    for d in dsts:
        if not bool(_reverse_reach(adj, int(d))[live].all()):
            return False
    return True


def random_trace(
    members: Sequence[Instance],
    n_events: int = 50,
    seed: int = 0,
    *,
    p_rate: float = 0.5,
    p_topology: float = 0.3,
    p_app: float = 0.2,
    rate_window: tuple = (0.4, 1.6),
) -> list[Event]:
    """Sample a deterministic, feasibility-preserving event trace.

    Works on an already-padded fleet (see :func:`pad_fleet`) so arrival
    events can use the spare application slots.  Guarantees, by replaying
    its own events while sampling:

      * failures keep every live node connected to every live destination
        (so ``traffic.repair_phi`` always has a finite-cost fallback);
      * at least one application stays live per member;
      * per-app cumulative rate factors stay inside ``rate_window`` of the
        member's starting rates (bounded congestion);
      * ``LinkUp`` only restores previously failed links at their original
        capacity; arrivals only fill dead slots.

    Deterministic in ``seed``; infeasible draws fall back to a RateScale.
    """
    rng = np.random.default_rng(seed)
    state = [m for m in members]
    failed: list[list[tuple]] = [[] for _ in members]          # [(i, j, cap)]
    cum = [np.ones(m.A) for m in members]                      # rate factors
    orig_cap = [np.asarray(m.link_param).copy() for m in members]

    def alive_apps(m):
        return np.flatnonzero(np.asarray(state[m].stage_mask).any(axis=1))

    def live_nodes(m):
        return np.flatnonzero(np.asarray(state[m].adj).any(axis=1))

    def live_dsts(m):
        inst = state[m]
        return [int(np.asarray(inst.dst)[a]) for a in alive_apps(m)]

    def commit(ev):
        state[ev.member], _ = apply_event(state[ev.member], ev)
        trace.append(ev)

    def sample_rate(m) -> Event:
        apps = alive_apps(m)
        a = int(rng.choice(apps))
        choices = np.array([0.6, 0.8, 1.25, 1.5, 2.0])
        ok = [f for f in choices
              if rate_window[0] <= cum[m][a] * f <= rate_window[1]]
        factor = float(rng.choice(ok)) if ok else float(1.0 / cum[m][a])
        cum[m][a] *= factor
        return RateScale(member=m, factor=factor, app=a)

    def sample_link_down(m) -> Optional[Event]:
        adj = np.asarray(state[m].adj)
        links = np.argwhere(adj)
        rng.shuffle(links)
        dsts = live_dsts(m)
        for i, j in links[:32]:
            cand = adj.copy()
            cand[i, j] = False
            if _reaches_all_dsts(cand, dsts):
                failed[m].append((int(i), int(j), float(orig_cap[m][i, j])))
                return LinkDown(member=m, i=int(i), j=int(j))
        return None

    def sample_link_up(m) -> Optional[Event]:
        if not failed[m]:
            return None
        i, j, cap = failed[m].pop(int(rng.integers(len(failed[m]))))
        return LinkUp(member=m, i=i, j=j, capacity=cap)

    def sample_node_down(m) -> Optional[Event]:
        inst = state[m]
        adj = np.asarray(inst.adj)
        dst_set = set(live_dsts(m))
        nodes = [v for v in live_nodes(m) if v not in dst_set]
        rng.shuffle(nodes)
        for v in nodes[:16]:
            cand = adj.copy()
            cand[v, :] = False
            cand[:, v] = False
            if _reaches_all_dsts(cand, live_dsts(m)):
                # Incident links of a dead node are not individually
                # restorable — drop them from the LinkUp pool.
                failed[m] = [(i, j, c) for i, j, c in failed[m]
                             if i != v and j != v]
                return NodeDown(member=m, node=int(v))
        return None

    def sample_app(m) -> Optional[Event]:
        inst = state[m]
        mask = np.asarray(inst.stage_mask).any(axis=1)
        dead_slots = np.flatnonzero(~mask)
        apps = alive_apps(m)
        want_arrival = len(dead_slots) > 0 and (
            len(apps) <= 1 or rng.random() < 0.6)
        if want_arrival and len(dead_slots) > 0:
            a = int(dead_slots[0])
            nodes = live_nodes(m)
            if len(nodes) < 2:
                return None
            dst = int(rng.choice(nodes))
            n_src = min(int(rng.integers(2, 4)), len(nodes) - 1)
            srcs = rng.choice([v for v in nodes if v != dst],
                              size=n_src, replace=False)
            rates = tuple((int(s), float(rng.uniform(0.3, 0.8)))
                          for s in srcs)
            cum[m][a] = 1.0
            return AppArrival(member=m, app=a, dst=dst, rates=rates)
        if len(apps) > 1:
            return AppDeparture(member=m, app=int(rng.choice(apps)))
        return None

    trace: list[Event] = []
    kinds = np.array([p_rate, p_topology, p_app]) / (p_rate + p_topology + p_app)
    while len(trace) < n_events:
        m = int(rng.integers(len(members)))
        kind = rng.choice(3, p=kinds)
        ev: Optional[Event] = None
        if kind == 1:
            topo = rng.random()
            if topo < 0.45:
                ev = sample_link_down(m)
            elif topo < 0.75:
                ev = sample_link_up(m)
            else:
                ev = sample_node_down(m)
        elif kind == 2:
            ev = sample_app(m)
        if ev is None:
            ev = sample_rate(m)
        commit(ev)
    return trace
