"""Chaos traces and fault injection for the online service (DESIGN.md §17).

Production online optimizers treat infeasible and transient states as the
common case, not the exception.  This module supplies the adversarial
inputs that exercise `serve.online.OnlineSolver`'s guardrail layer:

  * :func:`chaos_trace` — a seeded generator of *hostile* event streams,
    composing patterns that :func:`events.random_trace` deliberately avoids:
    link flapping (down/up bursts on the same edge), correlated node
    failures biased toward destination in-neighbourhoods (possibly shedding
    chains), rate surges that push links past their modelled capacity (with
    scheduled inverse recoveries so the trace ends in the stable region),
    and event storms hitting many fleet members inside one ``step()`` batch.
    Unlike ``random_trace`` it returns *step batches*
    (``list[list[Event]]``) because the storm pattern is only a storm if
    the events land in one batch.

  * :class:`FaultInjector` — corrupts the *solver state itself* at the
    solve boundary (non-finite carry entries, de-normalized phi rows),
    modelling partial writes / bad device math that no event stream can
    produce.  ``OnlineSolver(fault_injector=...)`` calls
    :meth:`FaultInjector.maybe_corrupt` on the event's member before each
    re-convergence; every injection is recorded so benchmarks can report
    recovery rates against ground truth.

Both are deterministic in their seeds.  Neither touches device state on
its own — the injector transforms a member's ``engine.ScanCarry`` pytree
and hands it back; the trace generator replays its own events through
``events.apply_event`` exactly like ``random_trace`` does.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import engine, events
from repro.core.network import Instance

# ---------------------------------------------------------------------------
# Fault injection at the solve boundary
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Injection:
    """One recorded state corruption (for recovery-rate accounting)."""

    event_index: int
    member: int
    mode: str


class FaultInjector:
    """Seeded corruption of a member's solver carry at the solve boundary.

    Modes:

      * ``"nan_carry"``  — a handful of live ``phi.e`` entries become NaN
        and the carry's cost/best-cost latch is poisoned.  Exercises the
        non-finite recovery path end to end: repair does NOT reseed NaN
        rows (``nan <= min_mass`` is False), so the solver must detect the
        non-finite cost and climb the degradation ladder.
      * ``"denorm_phi"`` — a few live phi rows are scaled by 1.5–4x, so
        the strategy silently violates the simplex invariant while every
        entry stays finite.  Exercises ``verify_fleet`` + quarantine: the
        violation is invisible to the cost/residual bookkeeping alone.

    ``p_inject`` is the per-event corruption probability; draws are made
    once per ``maybe_corrupt`` call, so a trace's injection schedule is a
    pure function of the injector seed.

    ``metrics`` (a ``repro.obs.Metrics``, optional) receives a
    ``faults.injected.<mode>`` counter tick per injection — ground truth
    the §19 report can hold recovery counters against.
    """

    MODES = ("nan_carry", "denorm_phi")

    def __init__(self, seed: int = 0, p_inject: float = 0.2,
                 modes: Sequence[str] = MODES, metrics=None):
        for m in modes:
            if m not in self.MODES:
                raise ValueError(f"unknown fault mode {m!r}")
        self._rng = np.random.default_rng(seed)
        self.p_inject = float(p_inject)
        self.modes = tuple(modes)
        self.metrics = metrics
        self.log: list[Injection] = []

    def maybe_corrupt(self, carry_b: engine.ScanCarry, member: int,
                      event_index: int) -> tuple[engine.ScanCarry, Optional[str]]:
        """Roll the dice for one event; returns (carry, mode or None)."""
        if self._rng.random() >= self.p_inject:
            return carry_b, None
        mode = self.modes[int(self._rng.integers(len(self.modes)))]
        if mode == "nan_carry":
            carry_b = self._nan_carry(carry_b)
        else:
            carry_b = self._denorm_phi(carry_b)
        self.log.append(Injection(event_index=event_index, member=member,
                                  mode=mode))
        if self.metrics is not None:
            self.metrics.counter(f"faults.injected.{mode}")
        return carry_b, mode

    def _nan_carry(self, carry: engine.ScanCarry) -> engine.ScanCarry:
        e = np.asarray(carry.phi.e).astype(np.float32).copy()
        flat = e.reshape(-1)
        live = np.flatnonzero(flat > 1e-6)
        if len(live) == 0:
            return carry
        pick = self._rng.choice(live, size=min(3, len(live)), replace=False)
        flat[pick] = np.nan
        phi = carry.phi._replace(e=jnp.asarray(e))
        return carry._replace(phi=phi, cost=jnp.float32(np.nan),
                              best_cost=jnp.float32(np.nan))

    def _denorm_phi(self, carry: engine.ScanCarry) -> engine.ScanCarry:
        e = np.asarray(carry.phi.e).astype(np.float32).copy()
        c = np.asarray(carry.phi.c).astype(np.float32).copy()
        mass = e.sum(-1) + c                          # (A, K1, V) row sums
        rows = np.argwhere(mass > 0.5)
        if len(rows) == 0:
            return carry
        pick = rows[self._rng.choice(len(rows),
                                     size=min(4, len(rows)), replace=False)]
        for a, k, i in pick:
            f = self._rng.uniform(1.5, 4.0)
            e[a, k, i] *= f
            c[a, k, i] *= f
        phi = carry.phi._replace(e=jnp.asarray(e), c=jnp.asarray(c))
        return carry._replace(phi=phi)


# ---------------------------------------------------------------------------
# Chaos traces
# ---------------------------------------------------------------------------


def chaos_trace(
    members: Sequence[Instance],
    n_events: int = 100,
    seed: int = 0,
    *,
    p_flap: float = 0.30,
    p_node_burst: float = 0.15,
    p_surge: float = 0.35,
    p_storm: float = 0.20,
    surge_window: tuple = (2.5, 6.0),
    max_cum: float = 8.0,
    flap_delay: tuple = (1, 3),
    p_shed: float = 0.3,
) -> list[list[events.Event]]:
    """Sample a deterministic adversarial event trace as step batches.

    ``n_events`` counts individual events across all batches (recoveries
    included).  Guarantees, by replaying its own events while sampling:

      * every member always keeps at least one live application (failures
        that would shed the last chain are never emitted);
      * destination-isolating failures ARE allowed (probability ``p_shed``
        per candidate) — the shed chains depart via
        ``events.apply_event``'s degrade-don't-diverge semantics;
      * every surge schedules its exact inverse recovery, and all
        scheduled recoveries are flushed before the trace ends, so final
        rates sit back inside the stable region (a recovery invalidated by
        later churn — e.g. its app was shed — is silently dropped);
      * cumulative per-app rate factors never exceed ``max_cum``.

    Deterministic in ``seed``.  Unsatisfiable pattern draws fall back to a
    bounded rate scale, so generation always terminates.
    """
    rng = np.random.default_rng(seed)
    state = [m for m in members]
    cum = [np.ones(m.A) for m in members]
    orig_cap = [np.asarray(m.link_param).copy() for m in members]
    due: dict[int, list[tuple]] = {}           # step -> recovery specs
    steps: list[list[events.Event]] = []
    emitted = 0
    t = 0

    def alive_apps(m):
        return np.flatnonzero(np.asarray(state[m].stage_mask).any(axis=1))

    def scheduled():
        return sum(len(v) for v in due.values())

    def room():
        return n_events - emitted - scheduled()

    def commit(batch, ev) -> bool:
        nonlocal emitted
        try:
            new, _ = events.apply_event(state[ev.member], ev)
        except ValueError:
            return False
        state[ev.member] = new
        batch.append(ev)
        emitted += 1
        return True

    def schedule(delay: int, spec: tuple) -> None:
        due.setdefault(t + max(1, delay), []).append(spec)

    def realize(spec) -> Optional[events.Event]:
        """Turn a recovery spec into an event, or None if later churn
        invalidated it (dead slot, revived link, vanished node)."""
        kind, m = spec[0], spec[1]
        if kind == "linkup":
            _, _, i, j, cap = spec
            if bool(np.asarray(state[m].adj)[i, j]):
                return None
            return events.LinkUp(member=m, i=i, j=j, capacity=cap)
        _, _, a, factor = spec                 # "unsurge"
        if not bool(np.asarray(state[m].stage_mask)[a].any()):
            return None
        cum[m][a] *= factor
        return events.RateScale(member=m, factor=factor, app=a)

    def survivable(m, ev) -> Optional[tuple]:
        """Tentatively apply; reject draws that kill the member's last
        chain or that shed chains when shedding wasn't rolled."""
        try:
            new, eff = events.apply_event(state[m], ev)
        except ValueError:
            return None
        if not bool(np.asarray(new.stage_mask).any()):
            return None
        if eff.shed and rng.random() >= p_shed:
            return None
        return (new, eff)

    def flap(batch, m) -> bool:
        adj = np.asarray(state[m].adj)
        links = np.argwhere(adj)
        rng.shuffle(links)
        for i, j in links[:32]:
            ev = events.LinkDown(member=m, i=int(i), j=int(j))
            if survivable(m, ev) is None:
                continue
            cap = float(orig_cap[m][i, j]) or float(np.asarray(
                state[m].link_param)[i, j]) or 1.0
            if not commit(batch, ev):
                continue
            schedule(int(rng.integers(*flap_delay)),
                     ("linkup", m, int(i), int(j), cap))
            return True
        return False

    def node_burst(batch, m) -> bool:
        inst = state[m]
        adj = np.asarray(inst.adj)
        apps = alive_apps(m)
        if len(apps) == 0:
            return False
        d = int(np.asarray(inst.dst)[int(rng.choice(apps))])
        dsts = {int(np.asarray(inst.dst)[a]) for a in apps}
        cand = [int(v) for v in np.flatnonzero(adj[:, d]) if v not in dsts]
        rng.shuffle(cand)
        hit = 0
        for v in cand[: int(rng.integers(1, 3))]:
            ev = events.NodeDown(member=m, node=v)
            if survivable(m, ev) is None:
                continue
            if commit(batch, ev):
                hit += 1
        return hit > 0

    def surge(batch, m) -> bool:
        apps = [a for a in alive_apps(m) if cum[m][a] * 1.1 < max_cum]
        if not apps:
            return False
        a = int(rng.choice(np.asarray(apps)))
        f = float(min(rng.uniform(*surge_window), max_cum / cum[m][a]))
        if f < 1.1:
            return False
        ev = events.RateScale(member=m, factor=f, app=a)
        if not commit(batch, ev):
            return False
        cum[m][a] *= f
        schedule(int(rng.integers(2, 5)), ("unsurge", m, a, 1.0 / f))
        return True

    def small_rate(batch, m) -> bool:
        apps = alive_apps(m)
        if len(apps) == 0:
            return False
        a = int(rng.choice(apps))
        f = 0.5 if cum[m][a] >= max_cum / 2 else float(rng.choice([0.8, 1.25, 1.5]))
        if commit(batch, events.RateScale(member=m, factor=f, app=a)):
            cum[m][a] *= f
            return True
        return False

    def storm(batch) -> bool:
        hit = 0
        targets = rng.permutation(len(members))[: max(2, min(len(members), room()))]
        for m in targets:
            if room() <= 0:
                break
            if small_rate(batch, int(m)):
                hit += 1
        return hit > 1

    probs = np.array([p_flap, p_node_burst, p_surge, p_storm], dtype=float)
    probs = probs / probs.sum()

    while emitted < n_events or scheduled() > 0:
        batch: list[events.Event] = []
        for spec in due.pop(t, []):
            ev = realize(spec)
            if ev is not None:
                commit(batch, ev)
        if room() > 0:
            m = int(rng.integers(len(members)))
            kind = int(rng.choice(4, p=probs))
            ok = False
            if kind == 0 and room() >= 2:
                ok = flap(batch, m)
            elif kind == 1:
                ok = node_burst(batch, m)
            elif kind == 2 and room() >= 2:
                ok = surge(batch, m)
            elif kind == 3:
                ok = storm(batch)
            if not ok and room() > 0:
                small_rate(batch, m)
        if batch:
            steps.append(batch)
        t += 1
    return steps
