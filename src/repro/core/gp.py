"""Algorithm 1: distributed Gradient Projection (GP) for problem (2).

Per iteration (time slot), every node i and stage (a,k):

  1. obtains dD/dt via the marginal-cost broadcast (here: the synchronous
     fixed-point sweep in ``marginals.pdt_recursion``),
  2. computes modified marginals delta_ij(a,k) (eq. 7),
  3. computes the blocked node set B_i(a,k) (loop-freedom),
  4. moves phi mass from blocked/high-delta directions onto the min-delta
     direction(s) with stepsize alpha (eqs. 8-10).

The update is a masked, vectorized computation over the whole (A,K1,V,V(+1))
strategy tensor — jit-compiled, and shard_mappable over stages
(``core/distributed.py``).  ``allowed_e`` / ``allowed_c`` masks restrict the
direction set, which is how the SPOC / LCOF baselines reuse this machinery
(``core/baselines.py``).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import costs
from repro.core.marginals import BIG, Marginals, marginals
from repro.core.network import Instance
from repro.core.traffic import (
    Phi, flows, renormalize, total_cost, traffic_is_valid,
)

_TIE_EPS = 1e-6      # directions within this of the min-delta receive mass
_BLOCK_EPS = 1e-7    # strictness slack for pdt comparisons


class GPState(NamedTuple):
    phi: Phi
    cost: jnp.ndarray
    residual: jnp.ndarray    # sufficiency-condition residual (0 => optimal)


@dataclasses.dataclass
class GPResult:
    phi: Phi
    cost_history: list
    residual_history: list
    iterations: int

    @property
    def final_cost(self) -> float:
        return float(self.cost_history[-1])


# ---------------------------------------------------------------------------
# Blocked node sets
# ---------------------------------------------------------------------------

def blocked_sets(inst: Instance, phi: Phi, pdt: jnp.ndarray) -> jnp.ndarray:
    """(A,K1,V,V) bool: j in B_i(a,k).

    j is blocked for i at stage (a,k) if (Section IV "Blocked node set"):
      1) (i,j) not in E, or
      2) dD/dt_j(a,k) > dD/dt_i(a,k), or
      3) j's routing subtree for (a,k) contains an improper link (p,q)
         with dD/dt_q > dD/dt_p.

    Category 3 ("tagged" nodes) is computed by reverse boolean propagation
    along the routing DAG — at most V sweeps, vectorized over (A,K1).
    """
    route = phi.e > 0.0                                         # (A,K1,V,V)
    worse = pdt[:, :, None, :] > pdt[:, :, :, None] + _BLOCK_EPS  # pdt_q > pdt_p
    improper = route & worse

    def sweep(tagged, _):
        # tagged_p = exists q: route[p,q] and (improper[p,q] or tagged[q])
        hit = improper | (route & tagged[:, :, None, :])
        return jnp.any(hit, axis=-1), None

    tagged0 = jnp.zeros(pdt.shape, dtype=bool)
    tagged, _ = jax.lax.scan(sweep, tagged0, None, length=inst.V)

    blocked = (~inst.adj[None, None]) | improper | worse | tagged[:, :, None, :]
    return blocked


# ---------------------------------------------------------------------------
# One GP iteration (eqs. 8-10)
# ---------------------------------------------------------------------------

# Backtracking multipliers tried each iteration (vmapped inside the jitted
# step).  The paper assumes a "sufficiently small" fixed alpha (Theorem 2 /
# [11]); with congestion-level queue marginals (D' ~ 1e6 near saturation) a
# fixed alpha either diverges or crawls, so we evaluate the same projection
# direction at several stepsizes and keep the best — a monotone-descent
# safeguard that preserves the convergence argument (descent + stationarity
# of condition (6)).  Multiplier 0 is included so the cost never increases.
_ALPHA_LADDER = tuple(4.0 ** (1 - k) for k in range(11)) + (0.0,)


def gp_step(
    inst: Instance,
    phi: Phi,
    alpha: float,
    allowed_e: Optional[jnp.ndarray] = None,
    allowed_c: Optional[jnp.ndarray] = None,
    scaled: bool = False,
) -> GPState:
    fl = flows(inst, phi)
    m = marginals(inst, phi, fl)

    avail_e = inst.adj[None, None] & ~blocked_sets(inst, phi, m.pdt)
    if allowed_e is not None:
        avail_e = avail_e & allowed_e
    avail_c = inst.cpu_allowed()[:, :, None]
    if allowed_c is not None:
        avail_c = avail_c & allowed_c

    delta_e = jnp.where(avail_e, m.delta_e, BIG)
    delta_c = jnp.where(avail_c, m.delta_c, BIG)
    min_delta = jnp.minimum(delta_e.min(-1), delta_c)           # (A,K1,V)

    # Fallback guard: if blocking removed every direction at a row that must
    # forward (can happen transiently on congested iterates), fall back to
    # the unblocked-by-topology direction set for that row.
    stuck = min_delta >= BIG / 2
    fb_e = jnp.where(inst.adj[None, None] & (allowed_e if allowed_e is not None else True), m.delta_e, BIG)
    fb_c = jnp.where(inst.cpu_allowed()[:, :, None] & (allowed_c if allowed_c is not None else True), m.delta_c, BIG)
    delta_e = jnp.where(stuck[..., None], fb_e, delta_e)
    delta_c = jnp.where(stuck, fb_c, delta_c)
    min_delta = jnp.minimum(delta_e.min(-1), delta_c)

    e_e = delta_e - min_delta[..., None]                        # e_ij >= 0
    e_c = delta_c - min_delta
    if scaled:
        # quasi-Newton diagonal scaling (the second-order speedup the paper
        # attributes to [5]): normalize the projection step by a curvature
        # surrogate so stepsizes are comparable across congestion levels.
        # D'' of the M/M/1 cost ~ 2 D'/(cap-F) ~ D'^2-scale; we use the
        # per-row marginal magnitude as the diagonal preconditioner.
        scale_row = jnp.maximum(jnp.abs(min_delta), 1e-6)
        e_e = e_e / scale_row[..., None]
        e_c = e_c / scale_row

    is_min_e = (e_e <= _TIE_EPS) & (delta_e < BIG / 2)
    is_min_c = (e_c <= _TIE_EPS) & (delta_c < BIG / 2)
    N = is_min_e.sum(-1) + is_min_c                             # (A,K1,V)

    # reductions: blocked directions surrender everything; positive-e
    # directions surrender min(phi, alpha * e)   (eq. 9)
    def apply(a):
        red_e = jnp.where(
            delta_e >= BIG / 2, phi.e,
            jnp.where(is_min_e, 0.0, jnp.minimum(phi.e, a * e_e)),
        )
        red_c = jnp.where(
            delta_c >= BIG / 2, phi.c,
            jnp.where(is_min_c, 0.0, jnp.minimum(phi.c, a * e_c)),
        )
        share = (red_e.sum(-1) + red_c) / jnp.maximum(N, 1)     # (A,K1,V)
        cand = renormalize(inst, Phi(
            e=phi.e - red_e + share[..., None] * is_min_e,
            c=phi.c - red_c + share * is_min_c,
        ))
        cand_fl = flows(inst, cand)
        valid = traffic_is_valid(inst, cand_fl.t)
        c_links = jnp.where(inst.adj, costs.cost(inst.link_kind, cand_fl.F, inst.link_param), 0.0)
        c_nodes = costs.cost(inst.comp_kind, cand_fl.G, inst.comp_param)
        cost = jnp.sum(c_links) + jnp.sum(c_nodes)
        return cand, jnp.where(valid, cost, jnp.inf)

    ladder = alpha * jnp.asarray(_ALPHA_LADDER, dtype=jnp.float32)
    cands, cand_costs = jax.vmap(apply)(ladder)
    # a too-aggressive candidate can form a routing loop -> divergent traffic
    # fixed point -> inf/NaN cost; such candidates must lose the argmin
    cand_costs = jnp.where(jnp.isnan(cand_costs), jnp.inf, cand_costs)
    best = jnp.argmin(cand_costs)
    new_phi = jax.tree_util.tree_map(lambda x: x[best], cands)

    # residual of sufficiency condition (6) at the *new* iterate, computed
    # cheaply from the current marginals (exact residual is recomputed by
    # the caller when it matters)
    exc_e = jnp.where(phi.e > 1e-6, m.delta_e - min_delta[..., None], 0.0)
    exc_c = jnp.where(phi.c > 1e-6, m.delta_c - min_delta, 0.0)
    residual = jnp.maximum(jnp.max(exc_e), jnp.max(exc_c))

    return GPState(phi=new_phi, cost=cand_costs[best], residual=residual)


# ---------------------------------------------------------------------------
# Initial strategies (loop-free, finite cost)
# ---------------------------------------------------------------------------

def _zero_flow_weights(inst: Instance) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Link and CPU marginals at zero flow (the 'uncongested' metrics)."""
    Dp0 = jnp.where(
        inst.adj,
        costs.marginal(inst.link_kind, jnp.zeros_like(inst.link_param), inst.link_param),
        jnp.inf,
    )
    Cp0 = costs.marginal(inst.comp_kind, jnp.zeros_like(inst.comp_param), inst.comp_param)
    return Dp0, Cp0


def expanded_shortest_path(inst: Instance) -> tuple[jnp.ndarray, Phi]:
    """Stage-expanded single-destination shortest paths at zero flow.

    Returns (dist, phi) where dist[a,k,i] is the min uncongested cost-to-go
    from (i, stage k) to (d_a, stage K_a), and phi routes integrally along
    the argmin successors.  This is simultaneously:
      * the LPR-SC baseline (joint uncongested routing + offloading), and
      * the default loop-free initialization for GP.
    """
    Dp0, Cp0 = _zero_flow_weights(inst)
    V, K1 = inst.V, inst.K1
    INF = jnp.float32(1e18)

    def per_app(L_a, w_a, dst_a, ntask_a):
        def stage(dist_next, xs):
            k, L_k, w_k = xs
            is_last = k == ntask_a
            # absorbing cost: at the last stage, reaching dst ends the chain
            comp = jnp.where(is_last, INF, w_k * inst.wnode * Cp0 + dist_next)
            at_dst = jnp.arange(V) == dst_a
            base = jnp.where(is_last & at_dst, 0.0, comp)
            # tiny per-hop epsilon: breaks ties toward fewer hops so the
            # argmin successor graph is acyclic even at zero packet size
            wmat = L_k * Dp0 + 1e-5              # (V,V) link weights, inf off-graph

            def relax(dist, _):
                via = jnp.min(wmat + dist[None, :], axis=1)
                return jnp.minimum(dist, via), None

            dist, _ = jax.lax.scan(relax, base, None, length=V)
            return dist, dist

        ks = jnp.arange(K1)
        _, dists = jax.lax.scan(
            stage, jnp.full((V,), INF), (ks, L_a, w_a), reverse=True
        )
        return dists                              # (K1, V)

    dist = jax.vmap(per_app)(inst.L, inst.w, inst.dst, inst.n_tasks)  # (A,K1,V)

    # successor choice: CPU (cost w*C'0 + dist[k+1,i]) vs each link
    dist_next = jnp.concatenate([dist[:, 1:], jnp.full_like(dist[:, :1], 1e18)], axis=1)
    cand_c = jnp.where(
        inst.cpu_allowed()[:, :, None],
        inst.w[:, :, None] * inst.wnode[None, None] * Cp0[None, None] + dist_next,
        INF,
    )
    cand_e = jnp.where(
        inst.adj[None, None],
        inst.L[:, :, None, None] * Dp0[None, None] + 1e-5 + dist[:, :, None, :],
        INF,
    )
    all_cand = jnp.concatenate([cand_c[..., None], cand_e], axis=-1)  # (A,K1,V,1+V)
    best = jnp.argmin(all_cand, axis=-1)
    phi_c = (best == 0).astype(jnp.float32)
    phi_e = jax.nn.one_hot(best - 1, V, dtype=jnp.float32) * (best > 0)[..., None]
    phi = renormalize(inst, Phi(e=phi_e, c=phi_c))
    return dist, phi


def init_phi(inst: Instance) -> Phi:
    """Default loop-free initial strategy with finite cost."""
    _, phi = expanded_shortest_path(inst)
    return phi


# ---------------------------------------------------------------------------
# Solver driver
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("scaled",))
def _jit_step(inst, phi, alpha, allowed_e, allowed_c, scaled=False):
    return gp_step(inst, phi, alpha, allowed_e, allowed_c, scaled)


def solve(
    inst: Instance,
    phi0: Optional[Phi] = None,
    *,
    alpha: float = 0.02,
    max_iters: int = 400,
    tol: float = 1e-4,
    allowed_e: Optional[jnp.ndarray] = None,
    allowed_c: Optional[jnp.ndarray] = None,
    track_every: int = 1,
    patience: int = 40,
    scaled: bool = False,
) -> GPResult:
    """Run Algorithm 1 until the sufficiency residual falls below tol.

    scaled=True enables the quasi-Newton diagonal preconditioner (paper
    Section IV remark on second-order methods)."""
    phi = phi0 if phi0 is not None else init_phi(inst)
    cost_hist = [float(total_cost(inst, phi))]
    res_hist = []
    it = 0
    best_cost, stall = float(cost_hist[0]), 0
    for it in range(1, max_iters + 1):
        state = _jit_step(inst, phi, alpha, allowed_e, allowed_c, scaled)
        phi = state.phi
        c, r = float(state.cost), float(state.residual)
        if it % track_every == 0:
            cost_hist.append(c)
            res_hist.append(r)
        if r <= tol:
            break
        if c < best_cost * (1 - 1e-6):
            best_cost, stall = c, 0
        else:
            stall += 1
            if stall >= patience:
                break   # ladder-stationary: no stepsize makes progress
    return GPResult(phi=phi, cost_history=cost_hist, residual_history=res_hist, iterations=it)
