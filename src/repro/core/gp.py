"""Algorithm 1: distributed Gradient Projection (GP) for problem (2).

Per iteration (time slot), every node i and stage (a,k):

  1. obtains dD/dt via the marginal-cost broadcast (here: the synchronous
     fixed-point sweep in ``marginals.pdt_recursion``),
  2. computes modified marginals delta_ij(a,k) (eq. 7),
  3. computes the blocked node set B_i(a,k) (loop-freedom),
  4. moves phi mass from blocked/high-delta directions onto the min-delta
     direction(s) with stepsize alpha (eqs. 8-10).

The update is a masked, vectorized computation over the whole (A,K1,V,V(+1))
strategy tensor — jit-compiled, and shard_mappable over stages
(``core/distributed.py``).  ``allowed_e`` / ``allowed_c`` masks restrict the
direction set, which is how the SPOC / LCOF baselines reuse this machinery
(``core/baselines.py``).

The iteration itself lives in :mod:`repro.core.engine` (the ONE fused step
core, parameterized over the F/G measurement reduction — DESIGN.md §14);
this module is the single-device driver layer: initial strategies, the
chunked/vmapped solve drivers, and thin ``axis=None`` wrappers that keep
the historical ``gp.gp_step`` / ``gp.blocked_sets`` entry points.  The
mesh drivers (``distributed.solve_sharded*``) consume the same engine
under ``shard_map``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batch as batch_mod
from repro.core import costs
from repro.core import engine
from repro.core.engine import GPState, ScanCarry as _ScanCarry
from repro.core.network import Instance
from repro.core.traffic import Phi, renormalize, total_cost

# Historical spellings, re-exported for call sites and differential tests
# that predate the engine extraction.
_TIE_EPS = engine.TIE_EPS      # directions within this of min-delta get mass
_BLOCK_EPS = engine.BLOCK_EPS  # strictness slack for pdt comparisons
_ALPHA_LADDER = engine.ALPHA_LADDER
blocked_sets = engine.blocked_sets


class GPScan(NamedTuple):
    """On-device result of :func:`solve_scan` (a strict superset of GPState).

    Histories are dense ``(max_iters[+1],)`` arrays: entries past
    ``iterations`` repeat the converged value (the carry is frozen once the
    early-stop predicate fires), so the arrays are safe to consume without
    trimming and stack cleanly under ``jax.vmap``.
    """

    phi: Phi
    cost: jnp.ndarray              # final cost
    residual: jnp.ndarray          # final sufficiency residual
    cost_history: jnp.ndarray      # (max_iters + 1,), [0] = initial cost
    residual_history: jnp.ndarray  # (max_iters,)
    iterations: jnp.ndarray        # int32, #iterations actually committed
    # (R, TEL_WIDTH) per-iteration telemetry ring ((B, R, TEL_WIDTH) for the
    # batched driver) when the solve ran with telemetry on; rows past
    # ``iterations`` (clamped to R) are zero.  Decode with
    # ``repro.obs.ring_valid(telemetry, iterations)`` (DESIGN.md §19).
    telemetry: Optional[jnp.ndarray] = None


@dataclasses.dataclass
class GPResult:
    """Host-side solve summary.

    ``cost_history`` / ``residual_history`` are dense jnp arrays (NOT
    python lists): ``cost_history[0]`` is the initial cost and entry ``i``
    is the cost after iteration ``i``.  Results from :func:`solve` are
    already trimmed; un-trimmed dense results (e.g. assembled from
    :func:`solve_scan`) repeat the converged value past ``iterations`` —
    ``trim()`` cuts them back to the committed prefix.
    """

    phi: Phi
    cost_history: jnp.ndarray
    residual_history: jnp.ndarray
    iterations: int
    # raw (R, TEL_WIDTH) iteration ring when the solve ran with telemetry
    # (``repro.obs.ring_valid`` trims it to the committed prefix); None
    # when telemetry was off.  ``trim()`` preserves it untouched.
    telemetry: Optional[jnp.ndarray] = None

    def __post_init__(self):
        self.cost_history = jnp.asarray(self.cost_history)
        self.residual_history = jnp.asarray(self.residual_history)

    def trim(self) -> "GPResult":
        """Cut dense histories back to the committed iteration prefix.

        ``cost_history`` shrinks to ``(iterations + 1,)`` (entry 0 is the
        initial cost) and ``residual_history`` to ``(iterations,)``.
        Idempotent; host-side only (no device work).

        Example::

            >>> res = gp.GPResult(phi=phi, cost_history=jnp.ones(401),
            ...                   residual_history=jnp.zeros(400),
            ...                   iterations=57)
            >>> res.trim().cost_history.shape
            (58,)
        """
        n = int(self.iterations)
        return dataclasses.replace(
            self,
            cost_history=self.cost_history[: n + 1],
            residual_history=self.residual_history[:n],
        )

    @property
    def final_cost(self) -> float:
        return float(self.cost_history[-1])


# ---------------------------------------------------------------------------
# One GP iteration (eqs. 8-10) — thin wrapper over the shared step engine
# ---------------------------------------------------------------------------

def gp_step(
    inst: Instance,
    phi: Phi,
    alpha: float,
    allowed_e: Optional[jnp.ndarray] = None,
    allowed_c: Optional[jnp.ndarray] = None,
    scaled: bool = False,
    solver: str = "auto",
    blocked: str = "bitset",
    accel=None,
) -> GPState:
    """One fused GP iteration on a single device.

    Delegates to :func:`engine.gp_step` with ``axis=None`` (plain-sum F/G
    measurement).  ``solver`` picks the stage solver (``"auto"`` |
    ``"batched_lu"`` | ``"dense"``, DESIGN.md §12) and ``blocked`` the
    blocked-set method (``"bitset"`` | ``"scan"``, DESIGN.md §13); the mesh
    path (``distributed.solve_sharded``) runs the same engine under
    ``shard_map`` with ``axis`` bound to the app-shard mesh axis.
    ``accel`` toggles the §15 step-level acceleration (adaptive ladder /
    exact residual) — see :func:`engine.resolve_accel`.
    """
    return engine.gp_step(inst, phi, alpha, allowed_e, allowed_c, scaled,
                          solver, blocked=blocked, axis=None,
                          accel=engine.resolve_accel(accel))


# ---------------------------------------------------------------------------
# Initial strategies (loop-free, finite cost)
# ---------------------------------------------------------------------------

def _zero_flow_weights(inst: Instance) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Link and CPU marginals at zero flow (the 'uncongested' metrics)."""
    Dp0 = jnp.where(
        inst.adj,
        costs.marginal(inst.link_kind, jnp.zeros_like(inst.link_param), inst.link_param),
        jnp.inf,
    )
    Cp0 = costs.marginal(inst.comp_kind, jnp.zeros_like(inst.comp_param), inst.comp_param)
    return Dp0, Cp0


def expanded_shortest_path(inst: Instance) -> tuple[jnp.ndarray, Phi]:
    """Stage-expanded single-destination shortest paths at zero flow.

    Returns (dist, phi) where dist[a,k,i] is the min uncongested cost-to-go
    from (i, stage k) to (d_a, stage K_a), and phi routes integrally along
    the argmin successors.  This is simultaneously:
      * the LPR-SC baseline (joint uncongested routing + offloading), and
      * the default loop-free initialization for GP.
    """
    Dp0, Cp0 = _zero_flow_weights(inst)
    V, K1 = inst.V, inst.K1
    INF = jnp.float32(1e18)

    def per_app(L_a, w_a, dst_a, ntask_a):
        def stage(dist_next, xs):
            k, L_k, w_k = xs
            is_last = k == ntask_a
            # absorbing cost: at the last stage, reaching dst ends the chain
            comp = jnp.where(is_last, INF, w_k * inst.wnode * Cp0 + dist_next)
            at_dst = jnp.arange(V) == dst_a
            base = jnp.where(is_last & at_dst, 0.0, comp)
            # tiny per-hop epsilon: breaks ties toward fewer hops so the
            # argmin successor graph is acyclic even at zero packet size
            wmat = L_k * Dp0 + 1e-5              # (V,V) link weights, inf off-graph

            def relax(dist, _):
                via = jnp.min(wmat + dist[None, :], axis=1)
                return jnp.minimum(dist, via), None

            dist, _ = jax.lax.scan(relax, base, None, length=V)
            return dist, dist

        ks = jnp.arange(K1)
        _, dists = jax.lax.scan(
            stage, jnp.full((V,), INF), (ks, L_a, w_a), reverse=True
        )
        return dists                              # (K1, V)

    dist = jax.vmap(per_app)(inst.L, inst.w, inst.dst, inst.n_tasks)  # (A,K1,V)

    # successor choice: CPU (cost w*C'0 + dist[k+1,i]) vs each link
    dist_next = jnp.concatenate([dist[:, 1:], jnp.full_like(dist[:, :1], 1e18)], axis=1)
    cand_c = jnp.where(
        inst.cpu_allowed()[:, :, None],
        inst.w[:, :, None] * inst.wnode[None, None] * Cp0[None, None] + dist_next,
        INF,
    )
    cand_e = jnp.where(
        inst.adj[None, None],
        inst.L[:, :, None, None] * Dp0[None, None] + 1e-5 + dist[:, :, None, :],
        INF,
    )
    all_cand = jnp.concatenate([cand_c[..., None], cand_e], axis=-1)  # (A,K1,V,1+V)
    best = jnp.argmin(all_cand, axis=-1)
    phi_c = (best == 0).astype(jnp.float32)
    phi_e = jax.nn.one_hot(best - 1, V, dtype=jnp.float32) * (best > 0)[..., None]
    phi = renormalize(inst, Phi(e=phi_e, c=phi_c))
    return dist, phi


def init_phi(inst: Instance) -> Phi:
    """Default loop-free initial strategy with finite cost."""
    _, phi = expanded_shortest_path(inst)
    return phi


# ---------------------------------------------------------------------------
# Solver drivers
# ---------------------------------------------------------------------------
#
# Three entry points share one device-resident iteration (DESIGN.md §10):
#
#   * solve_scan  — the whole loop as ONE jitted lax.scan of static length
#                   with on-device early-stop masking; composes with
#                   jax.vmap for batched scenario families (core/batch.py,
#                   core/scenarios.py).
#   * solve       — the user-facing driver: runs the same scan in chunks and
#                   checks the early-stop flag on host once per chunk, so a
#                   run that converges in 50 iterations does not pay for
#                   max_iters=400 worth of frozen device work.
#   * solve_loop  — the original per-iteration host-sync python loop, kept
#                   as the semantic reference (tests/test_batch.py asserts
#                   scan == loop on every Table II scenario).

@functools.partial(jax.jit,
                   static_argnames=("scaled", "solver", "blocked", "accel"))
def _jit_step(inst, phi, alpha, allowed_e, allowed_c, scaled=False,
              solver="auto", blocked="bitset", accel=None):
    return engine.gp_step(inst, phi, alpha, allowed_e, allowed_c, scaled,
                          solver, blocked=blocked, axis=None, accel=accel)


_init_carry = engine.init_carry


@functools.partial(jax.jit,
                   static_argnames=("length", "scaled", "solver", "blocked",
                                    "accel", "telemetry"))
def _scan_chunk(
    inst, carry, alpha, tol, patience, max_iters, allowed_e, allowed_c,
    *, length: int, scaled: bool = False, solver: str = "auto",
    blocked: str = "bitset", accel=None, app_mask=None, telemetry=None,
):
    """Jitted single-device wrapper over :func:`engine.scan_chunk`.

    Early-stop is a *mask*, not a break (see the engine docstring): the
    ``done`` latch freezes the carry and subsequent steps re-emit the
    converged (cost, residual), keeping history shapes static.  ``accel``
    is a resolved :class:`engine.AccelConfig` (or None) riding as a static
    argument — each distinct config compiles its own program.  ``app_mask``
    ((A,) bool or None) freezes applications (the §16 skip gate).
    ``telemetry`` (a resolved :class:`engine.TelemetryConfig` or None) is
    likewise static: with None the carry's ring is (0, TEL_WIDTH) and the
    compiled program is identical to the pre-telemetry one (§19).
    """
    return engine.scan_chunk(
        inst, carry, alpha, tol, patience, max_iters, allowed_e, allowed_c,
        length=length, scaled=scaled, solver=solver, blocked=blocked,
        axis=None, accel=accel, app_mask=app_mask, telemetry=telemetry)


def solve_scan(
    inst: Instance,
    phi0: Optional[Phi] = None,
    *,
    alpha: float = 0.02,
    max_iters: int = 400,
    tol: float = 1e-4,
    allowed_e: Optional[jnp.ndarray] = None,
    allowed_c: Optional[jnp.ndarray] = None,
    patience: int = 40,
    scaled: bool = False,
    solver: str = "auto",
    blocked: str = "bitset",
    accel=None,
    app_mask: Optional[jnp.ndarray] = None,
    telemetry=None,
) -> GPScan:
    """Algorithm 1 as a single device-resident ``lax.scan``.

    No host syncs inside the loop; returns dense histories (see
    :class:`GPScan`).  This is the vmap/jit-composable primitive — batched
    families go through ``jax.vmap(solve_scan)`` (``core/scenarios.py``).

    Shapes: with ``inst`` of extent (V nodes, A apps, K1 = K+1 stages),
    the result carries ``phi.e (A, K1, V, V)``, ``phi.c (A, K1, V)``,
    scalar ``cost``/``residual``/``iterations``, ``cost_history
    (max_iters + 1,)`` and ``residual_history (max_iters,)``.

    Example::

        >>> inst = network.table_ii_instance("abilene", seed=0)
        >>> scan = gp.solve_scan(inst, alpha=0.1, max_iters=200)
        >>> float(scan.cost) <= float(scan.cost_history[0])
        True
        >>> scan.cost_history.shape, int(scan.iterations) <= 200
        ((201,), True)

    solver="batched_lu" runs the shared-factorization stage solver
    (kernels/batched_solve.py); solver="dense" keeps the seed's per-stage
    ``jnp.linalg.solve`` for differential testing; solver="auto" (default)
    picks per backend/size (``traffic.resolve_solver``).

    accel=True (or an :class:`engine.AccelConfig`) enables the §15
    convergence-acceleration layer — Anderson mixing, per-member adaptive
    stepsize, sufficiency-residual stopping; default None keeps the legacy
    exact iteration.
    """
    accel = engine.resolve_accel(accel)
    telemetry = engine.resolve_telemetry(telemetry)
    phi = phi0 if phi0 is not None else init_phi(inst)
    carry0 = _init_carry(inst, phi, accel=accel, telemetry=telemetry)
    carry, (cs, rs) = _scan_chunk(
        inst, carry0, jnp.float32(alpha), jnp.float32(tol),
        jnp.int32(patience), jnp.int32(max_iters), allowed_e, allowed_c,
        length=max_iters, scaled=scaled, solver=solver, blocked=blocked,
        accel=accel, app_mask=app_mask, telemetry=telemetry,
    )
    return GPScan(
        phi=carry.phi, cost=carry.cost, residual=carry.residual,
        cost_history=jnp.concatenate([carry0.cost[None], cs]),
        residual_history=rs, iterations=carry.iters,
        telemetry=carry.tb if telemetry is not None else None,
    )


_SOLVE_CHUNK = 32    # host checks the early-stop latch once per chunk

# Adaptive chunk schedule for batched ensembles (gp.solve_batched): start
# short so early-converging members retire (and the batch compacts) after 8
# iterations, then double up to 64 as the long tail sets in.  All lengths
# stay powers of two — {8, 16, 32, 64} — so the schedule adds no XLA cache
# entries beyond those four per compaction bucket size.
_CHUNK_MIN = 8
_CHUNK_MAX = 64


def _prev_pow2(n: int) -> int:
    """Largest power of two <= n (n >= 1)."""
    return 1 << (n.bit_length() - 1)


def solve(
    inst: Instance,
    phi0: Optional[Phi] = None,
    *,
    alpha: float = 0.02,
    max_iters: int = 400,
    tol: float = 1e-4,
    allowed_e: Optional[jnp.ndarray] = None,
    allowed_c: Optional[jnp.ndarray] = None,
    track_every: int = 1,   # accepted for API compat; histories are dense now
    patience: int = 40,
    scaled: bool = False,
    solver: str = "auto",
    blocked: str = "bitset",
    accel=None,
    app_mask: Optional[jnp.ndarray] = None,
    telemetry=None,
) -> GPResult:
    """Run Algorithm 1 until the sufficiency residual falls below tol.

    Thin chunked driver over the :func:`solve_scan` iteration: the loop body
    never syncs to host — only the ``done`` latch is read back, once every
    ``_SOLVE_CHUNK`` iterations — so converged runs stop early while the
    per-iteration cost stays identical to the fully device-resident scan.

    scaled=True enables the quasi-Newton diagonal preconditioner (paper
    Section IV remark on second-order methods).  accel=True (or an
    :class:`engine.AccelConfig`) enables the §15 acceleration layer.
    ``app_mask`` ((A,) bool) freezes applications (the §16 skip gate):
    frozen apps keep their phi rows and still contribute their flows to
    the shared F/G measurement, and the residual stop ignores them."""
    del track_every
    accel = engine.resolve_accel(accel)
    telemetry = engine.resolve_telemetry(telemetry)
    phi = phi0 if phi0 is not None else init_phi(inst)
    carry = _init_carry(inst, phi, accel=accel, telemetry=telemetry)
    cost0 = carry.cost
    alpha_, tol_ = jnp.float32(alpha), jnp.float32(tol)
    patience_, max_iters_ = jnp.int32(patience), jnp.int32(max_iters)
    cost_chunks, res_chunks = [], []
    steps = 0
    while steps < max_iters:
        carry, (cs, rs) = _scan_chunk(
            inst, carry, alpha_, tol_, patience_, max_iters_,
            allowed_e, allowed_c,
            length=min(_SOLVE_CHUNK, max_iters - steps), scaled=scaled,
            solver=solver, blocked=blocked, accel=accel, app_mask=app_mask,
            telemetry=telemetry,
        )
        cost_chunks.append(cs)
        res_chunks.append(rs)
        steps += len(cs)
        if bool(carry.done):
            break
    return GPResult(
        phi=carry.phi,
        cost_history=jnp.concatenate([cost0[None], *cost_chunks]),
        residual_history=jnp.concatenate(res_chunks) if res_chunks else jnp.zeros((0,)),
        iterations=int(carry.iters),
        telemetry=carry.tb if telemetry is not None else None,
    ).trim()


@functools.partial(jax.jit,
                   static_argnames=("length", "scaled", "solver", "blocked",
                                    "accel", "telemetry"))
def _scan_chunk_batched(
    inst, carry, alpha, tol, patience, max_iters, allowed_e, allowed_c,
    *, length: int, scaled: bool = False, solver: str = "auto",
    blocked: str = "bitset", accel=None, app_mask=None, telemetry=None,
):
    def one(i, c, ae, ac, am):
        return _scan_chunk(i, c, alpha, tol, patience, max_iters, ae, ac,
                           length=length, scaled=scaled, solver=solver,
                           blocked=blocked, accel=accel, app_mask=am,
                           telemetry=telemetry)

    return jax.vmap(one)(inst, carry, allowed_e, allowed_c, app_mask)


def _gather(tree, idx: jnp.ndarray):
    return jax.tree_util.tree_map(lambda x: x[idx], tree)


def solve_batched(
    binst: Instance,
    phi0: Optional[Phi] = None,
    *,
    alpha: float = 0.02,
    max_iters: int = 400,
    tol: float = 1e-4,
    allowed_e: Optional[jnp.ndarray] = None,
    allowed_c: Optional[jnp.ndarray] = None,
    patience: int = 40,
    scaled: bool = False,
    compact: bool = True,
    solver: str = "auto",
    blocked: str = "bitset",
    accel=None,
    telemetry=None,
) -> GPScan:
    """Solve a whole scenario family (a ``batch.pad_instances`` pytree with
    a leading batch axis) in one vmapped device program.

    Semantically ``jax.vmap(solve_scan)`` with two wall-clock refinements
    (DESIGN.md §10):

      * **chunked early stop, adaptive lengths** — the loop body never
        syncs to host; only the batched ``done`` latch is read back at
        chunk boundaries, and the sweep ends when every member has
        converged.  Chunks start at ``_CHUNK_MIN`` = 8 iterations and
        double up to ``_CHUNK_MAX`` = 64, so early-converging members
        retire (and the batch compacts) quickly while long tails amortize
        the host sync — with only pow2 chunk lengths, bounding XLA cache
        entries;
      * **convergence compaction** (``compact=True``) — at chunk boundaries,
        converged members retire and the active set is re-packed into the
        next power-of-two bucket, so a long-tailed ensemble does not keep
        paying for members that finished early.  Bucket sizes are quantized
        to powers of two to bound XLA recompiles (one per bucket size).

    Histories are dense ``(B, max_iters[+1])`` arrays repeating each
    member's converged values past its own stop point; ``iterations``
    reports each member's stop point.

    Shapes: for a batch of B members padded to (V, A, K1), returns
    ``phi.e (B, A, K1, V, V)``, ``phi.c (B, A, K1, V)``, ``cost``/
    ``residual``/``iterations (B,)``, ``cost_history (B, max_iters + 1)``
    and ``residual_history (B, max_iters)``, all indexed by the ORIGINAL
    member order (compaction is internal).  The stage systems of the whole
    batch run through the batched-LU kernel path as one
    ``(B * ladder * A * K1, V, V)`` factorization per chunk iteration
    (vmap over scenarios x batch over stages — DESIGN.md §12).

    Example::

        >>> insts = [network.table_ii_instance("abilene", seed=s)
        ...          for s in range(4)]
        >>> binst = batch.pad_instances(insts)
        >>> scan = gp.solve_batched(binst, alpha=0.1, max_iters=200)
        >>> scan.cost.shape, scan.cost_history.shape
        ((4,), (4, 201))
    """
    B = int(binst.adj.shape[0])
    accel = engine.resolve_accel(accel)
    telemetry = engine.resolve_telemetry(telemetry)
    if phi0 is None:
        phi0 = jax.vmap(init_phi)(binst)
    carry = jax.vmap(
        lambda i, p: _init_carry(i, p, accel=accel, telemetry=telemetry)
    )(binst, phi0)
    alpha_, tol_ = jnp.float32(alpha), jnp.float32(tol)
    patience_, max_iters_ = jnp.int32(patience), jnp.int32(max_iters)

    # host-side result buffers, indexed by original member id
    cost_hist = np.zeros((B, max_iters + 1), np.float32)
    cost_hist[:, 0] = np.asarray(carry.cost)
    res_hist = np.zeros((B, max_iters), np.float32)
    out_phi_e = np.asarray(phi0.e).copy()
    out_phi_c = np.asarray(phi0.c).copy()
    out_cost = np.asarray(carry.cost).copy()
    out_res = np.full((B,), np.inf, np.float32)
    out_iters = np.zeros((B,), np.int32)
    ring = telemetry.ring if telemetry is not None else 0
    out_tb = np.zeros((B, ring, engine.TEL_WIDTH), np.float32)
    written = np.zeros((B,), np.int64)     # history filled up to this step

    ids = np.arange(B)                      # lane -> original member (-1: pad)
    inst_p, ae_p, ac_p = binst, allowed_e, allowed_c
    # align the initial batch to a power-of-two bucket so every chunk
    # program in this solve (and any other solve over same-shaped members)
    # hits the same XLA cache entries as the compaction buckets
    bucket0 = batch_mod.next_pow2(B)
    if compact and bucket0 > B:
        sel = np.concatenate([np.arange(B), np.zeros(bucket0 - B, np.int64)])
        sel_j = jnp.asarray(sel)
        inst_p = _gather(inst_p, sel_j)
        carry = _gather(carry, sel_j)
        if ae_p is not None:
            ae_p = ae_p[sel_j]
        if ac_p is not None:
            ac_p = ac_p[sel_j]
        pad0 = jnp.arange(bucket0) >= B
        carry = carry._replace(done=carry.done | pad0)
        ids = np.concatenate([ids, np.full(bucket0 - B, -1)])
    steps = 0
    chunk = _CHUNK_MIN
    while steps < max_iters:
        # pow2 lengths only (min with the largest pow2 <= the remaining
        # budget), so the whole schedule draws from {8, 16, 32, 64} plus
        # the pow2 ladder of any sub-8 tail
        length = min(chunk, _prev_pow2(max_iters - steps))
        chunk = min(chunk * 2, _CHUNK_MAX)
        carry, (cs, rs) = _scan_chunk_batched(
            inst_p, carry, alpha_, tol_, patience_, max_iters_, ae_p, ac_p,
            length=length, scaled=scaled, solver=solver, blocked=blocked,
            accel=accel, telemetry=telemetry,
        )
        valid = ids >= 0
        vids = ids[valid]
        cost_hist[vids, steps + 1: steps + 1 + length] = np.asarray(cs)[valid]
        res_hist[vids, steps: steps + length] = np.asarray(rs)[valid]
        steps += length
        written[vids] = steps

        done = np.asarray(carry.done)
        # snapshot finals only for lanes retiring this chunk (done, or the
        # iteration budget just ran out) — phi is the expensive transfer,
        # (B, A, K1, V, V), and active lanes would overwrite it anyway
        retiring = valid & (done | (steps >= max_iters))
        if retiring.any():
            rids = ids[retiring]
            out_phi_e[rids] = np.asarray(carry.phi.e)[retiring]
            out_phi_c[rids] = np.asarray(carry.phi.c)[retiring]
            out_cost[rids] = np.asarray(carry.cost)[retiring]
            out_res[rids] = np.asarray(carry.residual)[retiring]
            out_iters[rids] = np.asarray(carry.iters)[retiring]
            if telemetry is not None:
                # rings snapshot at retirement only (same rationale as phi:
                # active lanes would overwrite, and compaction re-packs
                # lanes — original-id indexing happens here, once)
                out_tb[rids] = np.asarray(carry.tb)[retiring]

        active = valid & ~done
        n_act = int(active.sum())
        if n_act == 0:
            break
        bucket = batch_mod.next_pow2(n_act)
        if compact and bucket < len(ids):
            keep = np.flatnonzero(active)
            sel = np.concatenate(
                [keep, np.full(bucket - n_act, keep[0], np.int64)])
            sel_j = jnp.asarray(sel)
            inst_p = _gather(inst_p, sel_j)
            carry = _gather(carry, sel_j)
            if ae_p is not None:
                ae_p = ae_p[sel_j]
            if ac_p is not None:
                ac_p = ac_p[sel_j]
            # pad lanes duplicate a live member but start frozen
            pad = jnp.arange(bucket) >= n_act
            carry = carry._replace(done=carry.done | pad)
            ids = np.where(np.arange(bucket) < n_act, ids[sel], -1)

    # dense-history contract: repeat converged values past each member's
    # retirement chunk
    for m in range(B):
        w = int(written[m])
        cost_hist[m, w + 1:] = cost_hist[m, w]
        if w > 0:
            res_hist[m, w:] = res_hist[m, w - 1]

    return GPScan(
        phi=Phi(e=jnp.asarray(out_phi_e), c=jnp.asarray(out_phi_c)),
        cost=jnp.asarray(out_cost), residual=jnp.asarray(out_res),
        cost_history=jnp.asarray(cost_hist),
        residual_history=jnp.asarray(res_hist),
        iterations=jnp.asarray(out_iters),
        telemetry=jnp.asarray(out_tb) if telemetry is not None else None,
    )


def solve_loop(
    inst: Instance,
    phi0: Optional[Phi] = None,
    *,
    alpha: float = 0.02,
    max_iters: int = 400,
    tol: float = 1e-4,
    allowed_e: Optional[jnp.ndarray] = None,
    allowed_c: Optional[jnp.ndarray] = None,
    patience: int = 40,
    scaled: bool = False,
    solver: str = "auto",
    blocked: str = "bitset",
) -> GPResult:
    """Reference driver: the original per-iteration host-sync python loop.

    Semantically equivalent to :func:`solve` / :func:`solve_scan` (asserted
    by tests/test_batch.py); kept for differential testing and debugging —
    use :func:`solve` everywhere else."""
    phi = phi0 if phi0 is not None else init_phi(inst)
    cost0 = jnp.asarray(total_cost(inst, phi), jnp.float32)
    cost_hist = [float(cost0)]
    res_hist = []
    it = 0
    # bookkeeping stays in float32 so the stop iteration is bit-identical
    # to the device-resident scan (which cannot use python float64)
    best_cost, stall = cost0, 0
    shrink = jnp.float32(1 - 1e-6)
    tol32 = jnp.float32(tol)
    for it in range(1, max_iters + 1):
        state = _jit_step(inst, phi, alpha, allowed_e, allowed_c, scaled,
                          solver, blocked)
        phi = state.phi
        cost_hist.append(float(state.cost))
        res_hist.append(float(state.residual))
        if bool(state.residual <= tol32):
            break
        if bool(state.cost < best_cost * shrink):
            best_cost, stall = state.cost, 0
        else:
            stall += 1
            if stall >= patience:
                break   # ladder-stationary: no stepsize makes progress
    return GPResult(phi=phi, cost_history=cost_hist, residual_history=res_hist, iterations=it)
