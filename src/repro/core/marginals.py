"""Closed-form marginal costs and modified marginals (eqs. (3), (4), (7)).

``pdt[a,k,i] = dD/dt_i(a,k)`` satisfies the backward recursion (4):

    pdt_k(i) = sum_j phi_ij(k) (L_k D'_ij + pdt_k(j))
             + phi_i0(k) (w(a,k) C'_i + pdt_{k+1}(i))

with pdt_{K}(d_a) = 0 at the destination.  For each stage this is a linear
system in ``pdt_k`` whose matrix is ``I - Phi_k`` (NOT transposed — the
recursion runs along outgoing links), solved exactly; the chain coupling is
a *reverse* ``lax.scan`` over k.  This realizes the paper's distributed
marginal-cost broadcast protocol as a synchronous fixed-point computation:
identical limit, synchronous schedule (DESIGN.md §4).

The modified marginals (7) drop the ``t_i(a,k)`` prefactor of (3):

    delta_ij(a,k) = L_k D'_ij + pdt[a,k,j]                     (j != 0)
    delta_i0(a,k) = w(a,k) C'_i + pdt[a,k+1,i]                 (j == 0)

and are the quantities both the sufficiency condition (6) and the GP update
(9) operate on.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.network import Instance
from repro.core.traffic import (
    Flows, Phi, comp_marginals, flows, link_marginals, resolve_solver,
    stage_factors,
)
from repro.kernels import ops

# Marginal assigned to non-existent directions ((i,j) not in E, or CPU at the
# final stage) — the paper's "infinity" (footnote 4).
BIG = jnp.float32(1e9)


class Marginals(NamedTuple):
    pdt: jnp.ndarray       # (A, K1, V)     dD/dt_i(a,k)
    delta_e: jnp.ndarray   # (A, K1, V, V)  delta_ij(a,k); BIG on non-links
    delta_c: jnp.ndarray   # (A, K1, V)     delta_i0(a,k); BIG when k == K_a
    Dp: jnp.ndarray        # (V, V)         D'_ij(F_ij)
    Cp: jnp.ndarray        # (V,)           C'_i(G_i)


def pdt_recursion(
    inst: Instance,
    phi: Phi,
    Dp: jnp.ndarray,
    Cp: jnp.ndarray,
    fact: Optional[ops.BatchedLU] = None,
    *,
    solver: str = "auto",
) -> jnp.ndarray:
    """Solve recursion (4) for all stages: reverse scan over k, vmap over a.

    Each stage's matrix ``I - Phi_k`` is independent of the chain coupling
    (only the RHS carries pdt_{k+1}), so the default path factors all
    (a, k) systems in ONE batched LU (``traffic.stage_factors`` — shareable
    with the traffic sweep, which solves the transposed system) and runs
    the whole reverse recursion as ONE fused chain-substitution call
    (``ops.fused_chain_solve``, DESIGN.md §13).
    """
    solver = resolve_solver(solver, phi.e.shape[-1], inst)
    if solver not in ("batched_lu", "sparse"):
        return jax.vmap(
            lambda pe, pc, L_a, w_a: _per_app_dense(inst, Dp, Cp, pe, pc, L_a, w_a)
        )(phi.e, phi.c, inst.L, inst.w)

    # One fused call consumes the whole (A, K1, V, V) stage stack, walking
    # k in reverse: pdt_k = (I - Phi_k)^-1 (base_k + phi_c_k * pdt_{k+1})
    # with base_k = [link term] + phi_c_k * w_k * wnode * C' and the
    # nonnegativity clamp applied inside the fused sweep.
    link_term = jnp.einsum(
        "akij,akij->aki", phi.e, inst.L[:, :, None, None] * Dp[None, None]
    )  # (A, K1, V): sum_j phi_ij L_k D'_ij
    base = link_term + phi.c * (
        inst.w[:, :, None] * inst.wnode[None, None] * Cp[None, None])
    if solver == "sparse":
        return ops.sparse_chain_solve(
            ops.sparse_topo(inst), phi.e, base, phi.c, trans=0,
            reverse=True, clamp=True)
    if fact is None:
        fact = stage_factors(phi.e)
    return ops.fused_chain_solve(fact, base, phi.c, trans=0, reverse=True,
                                 clamp=True)


def _per_app_dense(inst, Dp, Cp, phi_e_a, phi_c_a, L_a, w_a):
    """Seed-path per-app recursion (dense per-stage solves) — the
    differential reference for solver="batched_lu"."""
    link_term = jnp.einsum(
        "kij,kij->ki", phi_e_a, L_a[:, None, None] * Dp[None]
    )

    def step(pdt_next, xs):
        phi_e_k, phi_c_k, lt_k, w_k = xs
        b = lt_k + phi_c_k * (w_k * inst.wnode * Cp + pdt_next)
        V = phi_e_k.shape[0]
        pdt_k = jnp.linalg.solve(jnp.eye(V, dtype=b.dtype) - phi_e_k, b)
        pdt_k = jnp.maximum(pdt_k, 0.0)
        return pdt_k, pdt_k

    zero = jnp.zeros(inst.V, dtype=phi_e_a.dtype)
    _, pdt_a = jax.lax.scan(
        step, zero, (phi_e_a, phi_c_a, link_term, w_a), reverse=True
    )
    return pdt_a


def marginals(
    inst: Instance,
    phi: Phi,
    fl: Flows | None = None,
    fact: Optional[ops.BatchedLU] = None,
    *,
    solver: str = "auto",
    axis: Optional[str] = None,
) -> Marginals:
    """All marginal quantities for strategy phi.

    The marginal recursion itself is local to an application shard (stage
    coupling never crosses applications); only the measured ``F``/``G``
    inside ``fl`` are network-wide.  Callers under ``shard_map`` either
    pass a pre-reduced ``fl`` (``core/engine.py`` does) or set ``axis`` so
    the internally computed flows psum-reduce over the app shards.
    """
    if fl is None:
        fl = flows(inst, phi, fact, solver=solver, axis=axis)
    Dp = link_marginals(inst, fl.F)
    Cp = comp_marginals(inst, fl.G)
    pdt = pdt_recursion(inst, phi, Dp, Cp, fact, solver=solver)

    # delta_ij (7), j != 0
    delta_e = inst.L[:, :, None, None] * Dp[None, None] + pdt[:, :, None, :]
    delta_e = jnp.where(inst.adj[None, None], delta_e, BIG)

    # delta_i0 (7): needs pdt at stage k+1 (zero beyond the last stage)
    pdt_next = jnp.concatenate(
        [pdt[:, 1:, :], jnp.zeros_like(pdt[:, :1, :])], axis=1
    )
    delta_c = inst.w[:, :, None] * inst.wnode[None, None] * Cp[None, None] + pdt_next
    delta_c = jnp.where(inst.cpu_allowed()[:, :, None], delta_c, BIG)

    return Marginals(pdt=pdt, delta_e=delta_e, delta_c=delta_c, Dp=Dp, Cp=Cp)


def dD_dphi(inst: Instance, phi: Phi) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Closed-form dD/dphi_ij(a,k) of eq. (3): t_i(a,k) * delta_ij(a,k).

    Returns (grad_e (A,K1,V,V), grad_c (A,K1,V)).  Cross-validated against
    jax.grad in tests/test_marginals.py.
    """
    fl = flows(inst, phi)
    m = marginals(inst, phi, fl)
    grad_e = fl.t[..., None] * jnp.where(inst.adj[None, None], m.delta_e, 0.0)
    grad_c = fl.t * jnp.where(inst.cpu_allowed()[:, :, None], m.delta_c, 0.0)
    return grad_e, grad_c
