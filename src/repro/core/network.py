"""Network model for collaborative edge computing (CEC).

Implements the directed-graph network model of Section II of
"Delay-Optimal Service Chain Forwarding and Offloading in Collaborative
Edge Computing" (Zhang & Yeh, 2023), plus the seven evaluation topologies
of Table II.

A :class:`Instance` bundles everything problem (2) needs:
  * the directed graph (adjacency mask),
  * per-link cost parameters (capacity / linear coefficient),
  * per-node computation cost parameters,
  * the application set: chains, packet sizes ``L_(a,k)``, computation
    weights ``w(a,k)``, input rates ``r_i(a)`` and destinations ``d_a``.

Everything is stored as dense JAX arrays so the optimization core can be
jitted / vmapped / shard_mapped.  Networks in the paper are small
(|V| <= 100), so dense (V,V) representations are the right trade-off there.

Metro-scale instances (V >= several hundred) additionally carry a *sparse
topology* (DESIGN.md §18): padded per-node in/out neighbor lists (real
topologies have O(V) edges, so max-degree padding wastes little), a BFS
graph partition into routing blocks, and the block-level neighbor lists of
the blocked (BSR-style) stage systems.  ``with_sparse`` attaches these as
optional pytree fields; every dense code path ignores them, and the sparse
stage solver (``kernels/sparse_solve.py``), the neighbor-list blocked-set
sweep and the 2-D mesh driver consume them when present.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import networkx as nx
import numpy as np

from repro.core import costs

# Cost-family identifiers (match repro.core.costs).
LINEAR = costs.LINEAR
QUEUE = costs.QUEUE


@dataclasses.dataclass(frozen=True)
class Instance:
    """A complete CEC service-chain forwarding/offloading problem instance.

    Registered as a JAX pytree (cost-family kinds are static metadata), so
    instances can flow through jit/vmap/shard_map directly.

    Shapes: V = #nodes, A = #applications, K1 = max(|T_a|) + 1 stages.
    """

    # --- graph ---
    adj: jnp.ndarray            # (V, V) bool, adj[i, j] == (i, j) in E
    link_param: jnp.ndarray     # (V, V) float, capacity (QUEUE) or coeff (LINEAR)
    link_kind: int              # costs.LINEAR or costs.QUEUE
    comp_param: jnp.ndarray     # (V,) float, CPU capacity (QUEUE) or coeff
    comp_kind: int
    # --- applications ---
    L: jnp.ndarray              # (A, K1) packet size of stage (a, k) [bits]
    w: jnp.ndarray              # (A, K1) computation weight of task k+1 on a
    #     w[a, k] is the workload for computing task k+1 on one stage-k
    #     packet; w[a, K_a] is unused (final results are not computed).
    wnode: jnp.ndarray          # (V,) per-node workload multiplier (heterogeneity)
    r: jnp.ndarray              # (A, V) exogenous input rate of application a at i
    dst: jnp.ndarray            # (A,) int destination node d_a
    n_tasks: jnp.ndarray        # (A,) int |T_a|
    stage_mask: jnp.ndarray     # (A, K1) bool, valid stages k <= |T_a|
    # --- sparse topology (optional, attached by ``with_sparse``; §18) ---
    # Padded neighbor lists: row i lists its out-/in-neighbors in columns
    # 0..deg-1; masked columns point at i itself (a safe gather target).
    out_nbr: Optional[jnp.ndarray] = None    # (V, D) int32
    out_mask: Optional[jnp.ndarray] = None   # (V, D) bool
    in_nbr: Optional[jnp.ndarray] = None     # (V, D) int32
    in_mask: Optional[jnp.ndarray] = None    # (V, D) bool
    node_part: Optional[jnp.ndarray] = None  # (V,) int32 BFS routing-block id
    # Block-level neighbor lists of the SPARSE_BLOCK x SPARSE_BLOCK blocked
    # stage systems (symmetrized, so one structure serves Phi and Phi^T).
    blk_nbr: Optional[jnp.ndarray] = None    # (NB, BD) int32
    blk_mask: Optional[jnp.ndarray] = None   # (NB, BD) bool

    @property
    def V(self) -> int:
        return int(self.adj.shape[0])

    @property
    def A(self) -> int:
        return int(self.L.shape[0])

    @property
    def K1(self) -> int:
        return int(self.L.shape[1])

    @property
    def has_sparse(self) -> bool:
        """Whether the sparse-topology fields are attached (``with_sparse``)."""
        return self.out_nbr is not None

    @property
    def max_degree(self) -> int:
        """Neighbor-list pad width D (0 when no sparse topology attached)."""
        return int(self.out_nbr.shape[-1]) if self.has_sparse else 0

    def degenerate_mask(self) -> jnp.ndarray:
        """(A, K1, V) bool — True where phi must sum to 0 (eq. (1) lower branch).

        Stage K_a at the destination node is the exit of the network.  A
        final-stage row at a node with no outgoing links is degenerate too:
        it has an empty direction set (no CPU option at k = K_a), which only
        occurs for the masked dead nodes of the batch layer (DESIGN.md §9) —
        real Table II topologies are connected.
        """
        A, K1, V = self.A, self.K1, self.V
        karr = jnp.arange(K1)[None, :, None]             # (1, K1, 1)
        is_last = karr == self.n_tasks[:, None, None]     # (A, K1, 1)
        is_dst = (jnp.arange(V)[None, None, :] == self.dst[:, None, None])
        no_out = ~self.adj.any(axis=1)                    # (V,)
        return ((is_last & is_dst) | (is_last & no_out[None, None, :])
                | ~self.stage_mask[:, :, None])

    def cpu_allowed(self) -> jnp.ndarray:
        """(A, K1) bool — whether phi_{i0}(a,k) may be nonzero (k < |T_a|)."""
        karr = jnp.arange(self.K1)[None, :]
        return (karr < self.n_tasks[:, None]) & self.stage_mask


jax.tree_util.register_dataclass(
    Instance,
    data_fields=[
        "adj", "link_param", "comp_param", "L", "w", "wnode", "r", "dst",
        "n_tasks", "stage_mask",
        # optional sparse topology (None = absent; None is an empty pytree
        # subtree, so dense-only instances keep their historical structure)
        "out_nbr", "out_mask", "in_nbr", "in_mask", "node_part",
        "blk_nbr", "blk_mask",
    ],
    meta_fields=["link_kind", "comp_kind"],
)


# ---------------------------------------------------------------------------
# Topologies (Table II)
# ---------------------------------------------------------------------------

def _to_directed_arrays(g: nx.Graph) -> np.ndarray:
    """Undirected graph -> dense bool adjacency with both directions."""
    n = g.number_of_nodes()
    g = nx.convert_node_labels_to_integers(g)
    adj = np.zeros((n, n), dtype=bool)
    for u, v in g.edges():
        adj[u, v] = True
        adj[v, u] = True
    return adj


def connected_er(n: int = 20, m: int = 40, seed: int = 0) -> np.ndarray:
    """Connectivity-guaranteed Erdos-Renyi graph with n nodes and m edges."""
    rng = np.random.default_rng(seed)
    for trial in range(10_000):
        g = nx.gnm_random_graph(n, m, seed=int(rng.integers(1 << 31)))
        if nx.is_connected(g):
            return _to_directed_arrays(g)
    raise RuntimeError("could not sample a connected ER graph")


def balanced_tree(r: int = 2, h: int = 3) -> np.ndarray:
    """Complete binary tree: r=2, h=3 -> 15 nodes / 14 edges (Table II)."""
    return _to_directed_arrays(nx.balanced_tree(r, h))


def fog(seed: int = 0) -> np.ndarray:
    """A 3-tier fog-computing sample topology, 19 nodes / 30 edges.

    Tier 0: cloud (node 0). Tier 1: 6 edge servers (1..6) in a ring, each
    linked to the cloud. Tier 2: 12 devices (7..18), each linked to one
    server; 6 extra device-device D2D links. 6+6+12+6 = 30 edges.
    """
    g = nx.Graph()
    g.add_nodes_from(range(19))
    for s in range(1, 7):
        g.add_edge(0, s)                        # cloud <-> server (6)
    for s in range(1, 7):
        g.add_edge(s, 1 + (s % 6))              # server ring (6)
    for d in range(7, 19):
        g.add_edge(d, 1 + (d - 7) % 6)          # device -> server (12)
    for d in range(7, 19, 2):
        g.add_edge(d, 7 + (d - 7 + 3) % 12)     # D2D links (6)
    assert g.number_of_nodes() == 19 and g.number_of_edges() == 30
    return _to_directed_arrays(g)


def abilene() -> np.ndarray:
    """Abilene (Internet2 predecessor): 11 nodes / 14 edges."""
    edges = [
        (0, 1), (0, 2), (1, 2), (1, 3), (2, 4), (3, 4), (3, 5), (4, 6),
        (5, 6), (5, 7), (6, 8), (7, 9), (8, 9), (9, 10),
    ]
    g = nx.Graph(edges)
    assert g.number_of_nodes() == 11 and g.number_of_edges() == 14
    return _to_directed_arrays(g)


def lhc(seed: int = 7) -> np.ndarray:
    """LHC computing-grid-like topology, 16 nodes / 31 edges.

    The paper does not give the edge list; we use a deterministic
    tier-0/tier-1/tier-2 grid-like construction with the same |V|, |E|.
    """
    g = nx.Graph()
    g.add_nodes_from(range(16))
    # tier-0 hub (CERN-like): node 0 fully linked to tier-1 (1..5)
    for t1 in range(1, 6):
        g.add_edge(0, t1)                       # 5
    for t1 in range(1, 6):
        g.add_edge(t1, 1 + (t1 % 5))            # tier-1 ring, 5
    # tier-2 sites 6..15, each dual-homed to two tier-1 sites
    for t2 in range(6, 16):
        g.add_edge(t2, 1 + (t2 - 6) % 5)        # 10
        g.add_edge(t2, 1 + (t2 - 6 + 2) % 5)    # 10
    # one transatlantic-style shortcut
    g.add_edge(6, 11)
    assert g.number_of_nodes() == 16 and g.number_of_edges() == 31
    return _to_directed_arrays(g)


def geant(seed: int = 11) -> np.ndarray:
    """GEANT-like pan-European topology, 22 nodes / 33 edges.

    Paper cites GEANT with |V|=22, |E|=33; exact edge list is not given, so
    we use a deterministic ring + chords construction matching the counts.
    """
    g = nx.Graph()
    n = 22
    g.add_nodes_from(range(n))
    for i in range(n):
        g.add_edge(i, (i + 1) % n)              # backbone ring, 22
    chords = [(0, 5), (2, 9), (4, 13), (6, 17), (8, 15), (10, 19),
              (12, 21), (1, 14), (3, 18), (7, 20), (11, 16)]
    for u, v in chords:                          # 11 chords -> 33 edges
        g.add_edge(u, v)
    assert g.number_of_nodes() == 22 and g.number_of_edges() == 33
    return _to_directed_arrays(g)


def small_world(n: int = 100, seed: int = 3,
                n_long: Optional[int] = None) -> np.ndarray:
    """SW: ring-like graph with short- and long-range edges.

    At the Table II defaults (n=100, seed=3) this is exactly the paper's
    100-node / 320-edge topology.  Other ``n`` give the same construction
    scaled — ring + i+2/i+3 short-range chords + ``n_long`` (default n/5)
    random long-range chords — which is the metro-scale "small-world" family
    of the ``gp_scaling`` V >= 300 leg.  Node labels follow the ring, so
    contiguous index blocks are graph-local (the §18 partition relies on
    this).
    """
    g = nx.Graph()
    g.add_nodes_from(range(n))
    for i in range(n):
        g.add_edge(i, (i + 1) % n)              # ring
        g.add_edge(i, (i + 2) % n)              # short-range
        g.add_edge(i, (i + 3) % n)              # short-range
    if n_long is None:
        n_long = n // 5
    rng = np.random.default_rng(seed)
    added = 0
    while added < n_long:                        # long-range chords
        u, v = rng.integers(0, n, size=2)
        if u != v and not g.has_edge(u, v):
            g.add_edge(u, v)
            added += 1
    if n == 100 and n_long == 20:
        assert g.number_of_nodes() == 100 and g.number_of_edges() == 320
    return _to_directed_arrays(g)


def metro_geant(n: int = 300, seed: int = 11) -> np.ndarray:
    """GEANT-like ring + chords construction scaled to metro node counts.

    Same shape as :func:`geant` (backbone ring + n/2 chords, average degree
    3) at arbitrary ``n``; deterministic for a given seed.  Ring labeling
    keeps contiguous index blocks graph-local, like :func:`small_world`.
    """
    g = nx.Graph()
    g.add_nodes_from(range(n))
    for i in range(n):
        g.add_edge(i, (i + 1) % n)              # backbone ring, n
    rng = np.random.default_rng(seed)
    added = 0
    while added < n // 2:                        # chords, n/2
        u, v = rng.integers(0, n, size=2)
        if u != v and not g.has_edge(u, v):
            g.add_edge(u, v)
            added += 1
    return _to_directed_arrays(g)


TOPOLOGIES = {
    "connected-er": lambda: connected_er(20, 40, seed=0),
    "balanced-tree": lambda: balanced_tree(2, 3),
    "fog": fog,
    "abilene": abilene,
    "lhc": lhc,
    "geant": geant,
    "sw": small_world,
}


# ---------------------------------------------------------------------------
# Sparse topology (padded neighbor lists + graph partition — DESIGN.md §18)
# ---------------------------------------------------------------------------

# Edge length of the blocked (BSR-style) stage-system blocks: nodes are
# grouped into ceil(V / SPARSE_BLOCK) contiguous index blocks, and the
# blocked kernels iterate only block pairs with at least one edge.  The
# value lives in kernels/sparse_solve.py (the kernel and the block-gather
# must agree); re-exported here for the topology builders.
from repro.kernels.sparse_solve import SPARSE_BLOCK  # noqa: E402


def sparse_neighbors(adj: np.ndarray
                     ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Padded neighbor lists of a dense adjacency.

    Returns ``(out_nbr, out_mask, in_nbr, in_mask)``, each ``(V, D)`` with
    ``D = max(1, max total degree)``: row ``i`` lists its out-(in-)neighbors
    in the leading columns; masked columns point at ``i`` itself so gathers
    through them are always in-bounds (and zeroed by the mask).
    """
    adj = np.asarray(adj, dtype=bool)
    V = adj.shape[0]
    D = max(1, int(max(adj.sum(1).max(initial=0), adj.sum(0).max(initial=0))))
    out_nbr = np.tile(np.arange(V, dtype=np.int32)[:, None], (1, D))
    in_nbr = out_nbr.copy()
    out_mask = np.zeros((V, D), dtype=bool)
    in_mask = np.zeros((V, D), dtype=bool)
    for i in range(V):
        js = np.nonzero(adj[i])[0]
        out_nbr[i, : len(js)] = js
        out_mask[i, : len(js)] = True
        js = np.nonzero(adj[:, i])[0]
        in_nbr[i, : len(js)] = js
        in_mask[i, : len(js)] = True
    return out_nbr, out_mask, in_nbr, in_mask


def graph_partition(adj: np.ndarray, block: int = SPARSE_BLOCK) -> np.ndarray:
    """(V,) int32 routing-block labels: BFS order packed into size-``block``
    groups.

    BFS discovery order keeps each block a connected neighborhood, so for
    the ring-labeled metro builders the labels coincide with contiguous
    index blocks ``i // block`` — the layout the blocked kernels and the
    node-space mesh axis shard along.  The labels are diagnostic metadata
    (roofline accounting, partition-quality checks); the kernels themselves
    block over contiguous index ranges.
    """
    adj = np.asarray(adj, dtype=bool)
    V = adj.shape[0]
    seen = np.zeros(V, dtype=bool)
    order = []
    for s in range(V):
        if seen[s]:
            continue
        seen[s] = True
        queue = [s]
        while queue:
            u = queue.pop(0)
            order.append(u)
            for v in np.nonzero(adj[u])[0]:
                if not seen[v]:
                    seen[v] = True
                    queue.append(int(v))
    part = np.empty(V, dtype=np.int32)
    part[np.asarray(order)] = np.arange(V, dtype=np.int32) // block
    return part


def block_neighbors(adj: np.ndarray, block: int = SPARSE_BLOCK
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Block-level neighbor lists of the partition-blocked stage systems.

    Nodes are grouped into ``NB = ceil(V / block)`` contiguous index blocks;
    block pair (I, J) is a neighbor iff any edge (in either direction —
    symmetrized so one structure serves both ``Phi`` and ``Phi^T``) touches
    the (I, J) submatrix.  Returns ``(blk_nbr, blk_mask)``, each ``(NB, BD)``
    with ``BD = max block degree``; masked columns point at ``I`` itself.
    """
    adj = np.asarray(adj, dtype=bool)
    V = adj.shape[0]
    NB = -(-V // block)
    Vp = NB * block
    ap = np.zeros((Vp, Vp), dtype=bool)
    ap[:V, :V] = adj
    bad = ap.reshape(NB, block, NB, block).any(axis=(1, 3))
    bad = bad | bad.T
    BD = max(1, int(bad.sum(1).max(initial=0)))
    blk_nbr = np.tile(np.arange(NB, dtype=np.int32)[:, None], (1, BD))
    blk_mask = np.zeros((NB, BD), dtype=bool)
    for i in range(NB):
        js = np.nonzero(bad[i])[0]
        blk_nbr[i, : len(js)] = js
        blk_mask[i, : len(js)] = True
    return blk_nbr, blk_mask


def with_sparse(inst: Instance, *, block: int = SPARSE_BLOCK) -> Instance:
    """Attach the sparse topology fields to an instance (host-side, numpy).

    The returned instance is numerically identical to ``inst`` everywhere —
    the dense arrays are untouched; the sparse fields ride along as extra
    pytree leaves that the sparse stage solver, the neighbor-list blocked-set
    sweep and the 2-D mesh driver pick up (DESIGN.md §18).  Must be called
    outside jit (the neighbor extraction is data-dependent).
    """
    adj = np.asarray(inst.adj)
    out_nbr, out_mask, in_nbr, in_mask = sparse_neighbors(adj)
    part = graph_partition(adj, block=block)
    blk_nbr, blk_mask = block_neighbors(adj, block=block)
    return dataclasses.replace(
        inst,
        out_nbr=jnp.asarray(out_nbr), out_mask=jnp.asarray(out_mask),
        in_nbr=jnp.asarray(in_nbr), in_mask=jnp.asarray(in_mask),
        node_part=jnp.asarray(part),
        blk_nbr=jnp.asarray(blk_nbr), blk_mask=jnp.asarray(blk_mask),
    )


def without_sparse(inst: Instance) -> Instance:
    """Strip the sparse topology fields (the explicit dense fallback)."""
    return dataclasses.replace(
        inst, out_nbr=None, out_mask=None, in_nbr=None, in_mask=None,
        node_part=None, blk_nbr=None, blk_mask=None,
    )


def n_edges(inst: Instance) -> int:
    """Directed edge count |E| (host-side)."""
    return int(np.asarray(inst.adj).sum())


# ---------------------------------------------------------------------------
# Instance builders
# ---------------------------------------------------------------------------

def build_instance(
    adj: np.ndarray,
    *,
    n_apps: int,
    n_tasks: int = 2,
    n_sources: int = 3,
    link_kind: int = QUEUE,
    comp_kind: int = QUEUE,
    link_mean: float = 10.0,
    comp_mean: float = 12.0,
    rate_lo: float = 0.5,
    rate_hi: float = 1.5,
    packet_sizes: Optional[np.ndarray] = None,   # (K1,) default 10 - 5k
    comp_weight: float = 1.0,
    seed: int = 0,
    heterogeneity: float = 0.3,
) -> Instance:
    """Build a random instance in the style of Table II.

    Link/CPU parameters are u.a.r. in [1-h, 1+h] * mean; application input
    rates u.a.r. in [rate_lo, rate_hi] at ``n_sources`` random source nodes.
    Packet sizes default to the paper's ``L_(a,k) = 10 - 5k``.
    """
    rng = np.random.default_rng(seed)
    V = adj.shape[0]
    K1 = n_tasks + 1

    link_param = np.where(
        adj,
        link_mean * rng.uniform(1 - heterogeneity, 1 + heterogeneity, (V, V)),
        0.0,
    )
    comp_param = comp_mean * rng.uniform(1 - heterogeneity, 1 + heterogeneity, V)

    if packet_sizes is None:
        # Paper: L_(a,k) = 10 - 5k.  For |T_a| = 2 this makes the final
        # result size exactly 0, which admits zero-cost routing loops (any
        # strategy is tied).  We floor packet sizes at 0.01 — cost impact
        # is O(eps), but it removes the degeneracy (DESIGN.md §8).
        packet_sizes = np.array([10.0 - 5.0 * k for k in range(K1)])
    packet_sizes = np.maximum(np.asarray(packet_sizes, dtype=np.float64), 0.01)
    L = np.tile(np.asarray(packet_sizes, dtype=np.float64)[None, :], (n_apps, 1))

    w = np.full((n_apps, K1), comp_weight, dtype=np.float64)
    w[:, -1] = 0.0                                # final stage is never computed

    r = np.zeros((n_apps, V))
    dst = np.zeros(n_apps, dtype=np.int64)
    for a in range(n_apps):
        dst[a] = rng.integers(V)
        srcs = rng.choice(V, size=min(n_sources, V), replace=False)
        r[a, srcs] = rng.uniform(rate_lo, rate_hi, size=len(srcs))

    return Instance(
        adj=jnp.asarray(adj),
        link_param=jnp.asarray(link_param, dtype=jnp.float32),
        link_kind=link_kind,
        comp_param=jnp.asarray(comp_param, dtype=jnp.float32),
        comp_kind=comp_kind,
        L=jnp.asarray(L, dtype=jnp.float32),
        w=jnp.asarray(w, dtype=jnp.float32),
        wnode=jnp.ones(V, dtype=jnp.float32),
        r=jnp.asarray(r, dtype=jnp.float32),
        dst=jnp.asarray(dst),
        n_tasks=jnp.full((n_apps,), n_tasks),
        stage_mask=jnp.ones((n_apps, K1), dtype=bool),
    )


# Table II scenario parameters: (topology, |A|, R, link_kind, d_mean,
#                                comp_kind, s_mean)
TABLE_II = {
    "connected-er": ("connected-er", 5, 3, QUEUE, 10.0, QUEUE, 12.0),
    "balanced-tree": ("balanced-tree", 5, 3, QUEUE, 20.0, QUEUE, 15.0),
    "fog": ("fog", 5, 3, QUEUE, 20.0, QUEUE, 17.0),
    "abilene": ("abilene", 3, 3, QUEUE, 15.0, QUEUE, 10.0),
    "lhc": ("lhc", 8, 3, QUEUE, 15.0, QUEUE, 15.0),
    "geant": ("geant", 10, 5, QUEUE, 20.0, QUEUE, 20.0),
    "sw-queue": ("sw", 30, 8, QUEUE, 20.0, QUEUE, 20.0),
    "sw-linear": ("sw", 30, 8, LINEAR, 20.0, LINEAR, 20.0),
}


def metro_instance(topo: str, V: int, *, n_apps: int = 3, seed: int = 0,
                   sparse: bool = True) -> Instance:
    """A metro-scale instance on a V-node sparse graph (DESIGN.md §18).

    ``topo`` is ``"sw"`` (scaled :func:`small_world`) or ``"geant"``
    (scaled :func:`metro_geant`).  Parameters follow the Table II sw-queue
    scenario; ``sparse=True`` (default) attaches the sparse topology, which
    is the only viable solve path at V >= several hundred.
    """
    if topo == "sw":
        adj = small_world(V, seed=3)
    elif topo == "geant":
        adj = metro_geant(V, seed=11)
    else:
        raise ValueError(f"unknown metro topology {topo!r} (want 'sw'/'geant')")
    inst = build_instance(
        adj, n_apps=n_apps, n_tasks=2, n_sources=3,
        link_mean=20.0, comp_mean=20.0, seed=seed,
    )
    return with_sparse(inst) if sparse else inst


def table_ii_instance(name: str, seed: int = 0, rate_scale: float = 1.0) -> Instance:
    """Instantiate one of the paper's Table II simulation scenarios."""
    topo, n_apps, R, lk, dmean, ck, smean = TABLE_II[name]
    adj = TOPOLOGIES[topo]()
    inst = build_instance(
        adj,
        n_apps=n_apps,
        n_tasks=2,
        n_sources=R,
        link_kind=lk,
        comp_kind=ck,
        link_mean=dmean,
        comp_mean=smean,
        rate_lo=0.5 * rate_scale,
        rate_hi=1.5 * rate_scale,
        seed=seed,
    )
    return inst
