"""Named scenario sweeps: the paper's figures as batched scenario families.

Fig. 5-7 of the paper are statements about *families* of problem instances —
Table II topologies x input-rate scalings x random seeds.  This module is
the registry that expands a named sweep into a list of :class:`Scenario`
(label + Instance + provenance metadata) and runs whole families through the
device-resident batched solver (``batch.pad_instances`` +
``gp.solve_batched``), grouping members by cost family first because the
cost kinds are static pytree metadata (DESIGN.md §9).

Built-in sweeps:

  * ``fig5``            — the 8 Table II scenarios at their congested-regime
                          rate scalings (GP vs baselines, Fig. 5)
  * ``fig6-congestion`` — Abilene across input-rate scalings (Fig. 6)
  * ``fig7-packetsize`` — Abilene across input packet sizes L_(a,0) (Fig. 7)
  * ``seed-ensemble``   — one topology, many random seeds (confidence bands)
  * ``mixed-topology``  — heterogeneous Table II topologies in ONE padded
                          batch (exercises the V/A padding invariants)

``run_sweep(name)`` solves a family batched (``mesh=`` additionally shards
each member's application axis over a device mesh — vmap-of-shard_map,
DESIGN.md §14); ``run_sweep_serial(name)`` solves it one instance at a time
through ``gp.solve`` — the pair is how the benchmark drivers measure the
batched-vs-serial speedup.  ``run_sweep_chained(name)`` solves an
incremental family sequentially, warm-starting each member from its
predecessor's strategy (the fig6 rate-ladder shortcut).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence

import jax

from repro.core import batch, gp, network
from repro.core.traffic import Phi

# Input-rate scaling per Table II scenario so the networks operate in the
# congested regime the paper targets (its absolute rates depend on
# unpublished simulator units; the *relative* algorithm ordering is the
# claim).  fog's capacities (Table II: s=17, d=20) leave it lightly loaded
# at 2x — every algorithm already sits at the uncongested optimum — so fog
# runs at 3.5x to reach the congested regime Fig. 5 depicts.
FIG5_RATE = {
    "connected-er": 2.0, "balanced-tree": 2.0, "fog": 3.5, "abilene": 2.0,
    "lhc": 2.0, "geant": 2.0, "sw-linear": 1.5, "sw-queue": 1.5,
}

FIG6_SCALES = (0.5, 1.0, 1.5, 2.0, 2.5, 3.0)
FIG7_L0 = (2.0, 5.0, 10.0, 20.0, 40.0)

# Table II members small enough to batch comfortably on one host device
# (excludes the V=100 small-world pair).
SMALL_TABLE_II = ("connected-er", "balanced-tree", "fog", "abilene", "lhc", "geant")


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One member of a sweep: a labeled Instance plus provenance."""

    label: str
    instance: network.Instance
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def kinds(self) -> tuple[int, int]:
        return (self.instance.link_kind, self.instance.comp_kind)


def _fig5(**kw) -> list[Scenario]:
    seed = kw.get("seed", 0)
    return [
        Scenario(
            label=name,
            instance=network.table_ii_instance(name, seed=seed, rate_scale=rate),
            meta={"table_ii": name, "seed": seed, "rate_scale": rate},
        )
        for name, rate in FIG5_RATE.items()
    ]


def _fig6_congestion(**kw) -> list[Scenario]:
    name = kw.get("scenario", "abilene")
    seed = kw.get("seed", 0)
    scales = kw.get("scales", FIG6_SCALES)
    return [
        Scenario(
            label=f"{name}@r{scale:g}",
            instance=network.table_ii_instance(name, seed=seed, rate_scale=scale),
            meta={"table_ii": name, "seed": seed, "rate_scale": scale},
        )
        for scale in scales
    ]


def _fig7_packetsize(**kw) -> list[Scenario]:
    import numpy as np

    seed = kw.get("seed", 0)
    l0s = kw.get("l0_values", FIG7_L0)
    out = []
    for l0 in l0s:
        inst = network.build_instance(
            network.TOPOLOGIES["abilene"](), n_apps=3, n_tasks=2, n_sources=3,
            link_mean=15.0, comp_mean=10.0, seed=seed,
            packet_sizes=np.array([l0, l0 / 2, 0.01]),
        )
        out.append(Scenario(
            label=f"abilene@L0={l0:g}", instance=inst,
            meta={"topology": "abilene", "seed": seed, "L0": l0},
        ))
    return out


def _seed_ensemble(**kw) -> list[Scenario]:
    name = kw.get("scenario", "abilene")
    n_seeds = kw.get("n_seeds", 32)
    rate = kw.get("rate_scale", 2.0)
    return [
        Scenario(
            label=f"{name}#s{s}",
            instance=network.table_ii_instance(name, seed=s, rate_scale=rate),
            meta={"table_ii": name, "seed": s, "rate_scale": rate},
        )
        for s in range(n_seeds)
    ]


def _mixed_topology(**kw) -> list[Scenario]:
    names = kw.get("scenarios", SMALL_TABLE_II)
    seeds = kw.get("seeds", (0, 1))
    rate = kw.get("rate_scale", 1.5)
    return [
        Scenario(
            label=f"{name}#s{s}",
            instance=network.table_ii_instance(name, seed=s, rate_scale=rate),
            meta={"table_ii": name, "seed": s, "rate_scale": rate},
        )
        for name in names
        for s in seeds
    ]


def _online_trace(**kw) -> list[Scenario]:
    """Event-trace replay family: the fig6 fleet after each event of an
    online trace (DESIGN.md §16).

    Expands to one Scenario per event — the *post-event* instance of the
    event's member — so cold-solving this family with :func:`run_sweep`
    yields the per-event cold baseline the online solver
    (``serve.online.OnlineSolver``) is benchmarked against.  Every member
    is padded to the fleet envelope (plus ``spare_apps`` dead application
    slots for arrivals), so the family always batches into one group.

    kwargs: ``scenario`` (Table II name), ``scales`` (fleet rate ladder),
    ``seed``, ``n_events``, ``spare_apps``, and optionally an explicit
    ``trace`` (list of ``events.Event``) to replay instead of sampling.
    """
    from repro.core import events

    name = kw.get("scenario", "abilene")
    scales = kw.get("scales", FIG6_SCALES)
    seed = kw.get("seed", 0)
    n_events = kw.get("n_events", 50)
    spare = kw.get("spare_apps", 2)
    insts = [network.table_ii_instance(name, seed=seed, rate_scale=s)
             for s in scales]
    members = events.pad_fleet(insts, spare_apps=spare)
    trace = kw.get("trace")
    if trace is None:
        trace = events.random_trace(members, n_events=n_events, seed=seed)
    out = []
    for t, (ev, inst, _eff) in enumerate(events.replay(members, trace)):
        out.append(Scenario(
            label=f"{name}-ev{t:02d}-m{ev.member}",
            instance=inst,
            meta={"event": type(ev).__name__, "member": ev.member, "t": t,
                  "table_ii": name, "seed": seed,
                  "base_scale": scales[ev.member]},
        ))
    return out


SWEEPS: dict[str, Callable[..., list[Scenario]]] = {
    "fig5": _fig5,
    "fig6-congestion": _fig6_congestion,
    "fig7-packetsize": _fig7_packetsize,
    "seed-ensemble": _seed_ensemble,
    "mixed-topology": _mixed_topology,
    "online-trace": _online_trace,
}


def register(name: str, build: Callable[..., list[Scenario]]) -> None:
    """Add a sweep to the registry (used by downstream experiment scripts)."""
    if name in SWEEPS:
        raise ValueError(f"sweep {name!r} already registered")
    SWEEPS[name] = build


def expand(name: str, **kw) -> list[Scenario]:
    """Expand a named sweep into its scenario list."""
    try:
        build = SWEEPS[name]
    except KeyError:
        raise KeyError(f"unknown sweep {name!r}; have {sorted(SWEEPS)}") from None
    return build(**kw)


# ---------------------------------------------------------------------------
# Batched execution
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SweepResult:
    scenarios: list[Scenario]
    results: list[gp.GPResult]      # aligned with scenarios, phi un-padded
    seconds: float                  # wall clock for the solve(s)
    n_batches: int                  # #kind-groups the family was split into

    def by_label(self) -> dict[str, gp.GPResult]:
        return {s.label: r for s, r in zip(self.scenarios, self.results)}


def solve_family(
    insts: Sequence[network.Instance],
    phi0s: Optional[Sequence[Phi]] = None,
    *,
    masks_fn: Optional[Callable] = None,
    mesh=None,
    mesh_axis: str = "stage",
    **gp_kwargs,
) -> list[gp.GPResult]:
    """Solve same-cost-family instances as ONE padded, vmapped batch.

    ``masks_fn`` (e.g. ``baselines.spoc_masks``) maps an Instance to
    (allowed_e, allowed_c, phi0); it is vmapped over the padded batch so
    restricted solvers — the SPOC/LCOF baselines — run through the same
    batched device program as unrestricted GP.  An explicit ``phi0s``
    overrides the masks' initial strategies.

    With ``mesh`` set (a ``jax.sharding.Mesh``), the family runs through
    ``distributed.solve_sharded_batched``: each member's application axis
    is sharded over ``mesh_axis`` and the member axis is vmapped inside
    each shard (vmap-of-shard_map, DESIGN.md §14), so large ensembles
    spread across devices while solving the identical problems.

    Returns per-instance trimmed GPResults with padding stripped from phi
    and histories taken from the batched dense scan outputs.
    """
    binst = batch.pad_instances(insts)
    phi0 = batch.pad_phis(phi0s, insts) if phi0s is not None else None
    if masks_fn is not None:
        allowed_e, allowed_c, mask_phi0 = jax.vmap(masks_fn)(binst)
        gp_kwargs.setdefault("allowed_e", allowed_e)
        gp_kwargs.setdefault("allowed_c", allowed_c)
        if phi0 is None:
            phi0 = mask_phi0
    if mesh is not None:
        from repro.core import distributed

        scan = distributed.solve_sharded_batched(
            binst, mesh, axis=mesh_axis, phi0=phi0, **gp_kwargs)
    else:
        scan = gp.solve_batched(binst, phi0, **gp_kwargs)
    out = []
    for b, inst in enumerate(insts):
        member = jax.tree_util.tree_map(lambda x: x[b], scan)
        out.append(gp.GPResult(
            phi=batch.unpad_phi(member.phi, inst),
            cost_history=member.cost_history,
            residual_history=member.residual_history,
            iterations=int(member.iterations),
        ).trim())
    return out


def run_sweep(name_or_scenarios, *, sweep_kwargs: Optional[dict] = None,
              masks_fn: Optional[Callable] = None,
              mesh=None, mesh_axis: str = "stage",
              **gp_kwargs) -> SweepResult:
    """Expand a sweep and solve it batched.

    ``name_or_scenarios`` is a registry name (``"fig5"``,
    ``"fig6-congestion"``, ``"fig7-packetsize"``, ``"seed-ensemble"``,
    ``"mixed-topology"`` — expanded with ``sweep_kwargs``) or an explicit
    ``list[Scenario]``; remaining kwargs go to ``gp.solve_batched`` —
    including ``accel=`` (the §15 convergence-acceleration layer), which
    therefore applies uniformly to every member of the family on both the
    single-device and ``mesh=`` paths.
    ``masks_fn`` restricts the direction set per member (the SPOC/LCOF
    baselines — ``baselines.BASELINE_MASKS``); it is evaluated under
    ``jax.vmap`` on each padded group (see :func:`solve_family`).
    ``mesh`` composes the family with a device mesh: each padded group is
    solved by ``distributed.solve_sharded_batched`` with the app axis
    sharded over ``mesh_axis`` and members vmapped inside each shard.
    Returns a :class:`SweepResult` whose ``results`` align 1:1 with
    ``scenarios`` (trimmed GPResults, phi un-padded back to each member's
    true (A, K1, V, V)).

    Members are grouped by cost family (static metadata, must match within a
    batch) AND by node-count size class (next power of two): padding a
    V=11 Abilene member to a V=100 small-world envelope would multiply its
    per-iteration work ~80x, wiping out the batching win, so differently
    sized members go into separate device programs instead.

    Example::

        >>> sweep = scenarios.run_sweep(
        ...     "seed-ensemble",
        ...     sweep_kwargs={"scenario": "abilene", "n_seeds": 32},
        ...     alpha=0.1, max_iters=250)
        >>> len(sweep.results), sweep.n_batches
        (32, 1)
        >>> sweep.by_label()["abilene#s0"].final_cost  # doctest: +SKIP
        15.19
    """
    if isinstance(name_or_scenarios, str):
        scenarios = expand(name_or_scenarios, **(sweep_kwargs or {}))
    else:
        scenarios = list(name_or_scenarios)
    groups: dict[tuple, list[int]] = {}
    for idx, sc in enumerate(scenarios):
        key = sc.kinds + (batch.next_pow2(sc.instance.V),)
        groups.setdefault(key, []).append(idx)

    results: list[Optional[gp.GPResult]] = [None] * len(scenarios)
    t0 = time.perf_counter()
    for idxs in groups.values():
        group_res = solve_family([scenarios[i].instance for i in idxs],
                                 masks_fn=masks_fn, mesh=mesh,
                                 mesh_axis=mesh_axis, **gp_kwargs)
        for i, r in zip(idxs, group_res):
            results[i] = r
    seconds = time.perf_counter() - t0
    return SweepResult(scenarios=scenarios, results=results, seconds=seconds,
                       n_batches=len(groups))


def run_sweep_serial(name_or_scenarios, *, sweep_kwargs: Optional[dict] = None,
                     masks_fn: Optional[Callable] = None,
                     **gp_kwargs) -> SweepResult:
    """The serial reference: one ``gp.solve`` per scenario (for speedup
    comparisons against :func:`run_sweep`).

    ``masks_fn`` mirrors :func:`run_sweep`'s: per-scenario
    (allowed_e, allowed_c, phi0) direction restrictions are computed on
    each (unpadded) instance and forwarded to ``gp.solve``, so the
    serial-vs-batched baseline comparison is apples-to-apples — both
    paths solve exactly the same restricted problems.
    """
    if isinstance(name_or_scenarios, str):
        scenarios = expand(name_or_scenarios, **(sweep_kwargs or {}))
    else:
        scenarios = list(name_or_scenarios)
    t0 = time.perf_counter()
    results = []
    for sc in scenarios:
        kw = dict(gp_kwargs)
        phi0 = None
        if masks_fn is not None:
            allowed_e, allowed_c, phi0 = masks_fn(sc.instance)
            kw.setdefault("allowed_e", allowed_e)
            kw.setdefault("allowed_c", allowed_c)
        results.append(gp.solve(sc.instance, phi0, **kw))
    seconds = time.perf_counter() - t0
    return SweepResult(scenarios=scenarios, results=results, seconds=seconds,
                       n_batches=len(scenarios))


def run_sweep_chained(name_or_scenarios, *,
                      sweep_kwargs: Optional[dict] = None,
                      masks_fn: Optional[Callable] = None,
                      **gp_kwargs) -> SweepResult:
    """Sequential sweep with warm-start chaining: member k starts from
    member k-1's converged strategy.

    The intended use is *incremental* families — e.g. the fig6 input-rate
    ladder, where rate ``r_k``'s optimum is a small perturbation of
    ``r_{k-1}``'s — so order the scenario list from least to most congested.
    Chaining is inherently sequential (each member needs its predecessor's
    phi), so this trades the batched device program for a much shorter
    iteration count per member; ``benchmarks/fig6_congestion.py`` and the
    fig5 V=100 warm-start report the measured cut.

    A member that cannot legally inherit its predecessor's strategy —
    different graph, destinations or chain structure, not just a different
    array shape (two random topologies can share (A, K1, V, V) while
    disagreeing on which edges exist, and phi mass on a non-edge poisons
    the traffic fixed point) — falls back to a cold start.  ``masks_fn``
    restrictions still apply per member; the chained phi only replaces the
    *initial* strategy.  With ``accel=`` each member's solve builds a fresh
    carry, so the Anderson history and adaptive stepsize never leak across
    chain members (only the warm-started phi does).
    """
    import numpy as np

    if isinstance(name_or_scenarios, str):
        scenarios = expand(name_or_scenarios, **(sweep_kwargs or {}))
    else:
        scenarios = list(name_or_scenarios)
    t0 = time.perf_counter()
    results: list[gp.GPResult] = []
    phi_prev: Optional[Phi] = None
    inst_prev: Optional[network.Instance] = None
    for sc in scenarios:
        inst = sc.instance
        kw = dict(gp_kwargs)
        phi0 = None
        if masks_fn is not None:
            allowed_e, allowed_c, phi0 = masks_fn(inst)
            kw.setdefault("allowed_e", allowed_e)
            kw.setdefault("allowed_c", allowed_c)
        inheritable = (
            phi_prev is not None
            and tuple(phi_prev.e.shape) == (inst.A, inst.K1, inst.V, inst.V)
            and np.array_equal(np.asarray(inst.adj), np.asarray(inst_prev.adj))
            and np.array_equal(np.asarray(inst.dst), np.asarray(inst_prev.dst))
            and np.array_equal(np.asarray(inst.n_tasks),
                               np.asarray(inst_prev.n_tasks))
        )
        if inheritable:
            phi0 = phi_prev
        res = gp.solve(inst, phi0, **kw)
        phi_prev, inst_prev = res.phi, inst
        results.append(res)
    seconds = time.perf_counter() - t0
    return SweepResult(scenarios=scenarios, results=results, seconds=seconds,
                       n_batches=len(scenarios))
