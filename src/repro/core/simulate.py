"""Packet-level discrete-event validation of the flow model.

The paper's premise (Section II): when D_ij and C_i are M/M/1 queue
lengths, the aggregate cost D(phi) equals the expected number of packets
in the system, so by Little's law

    mean packet system delay  =  D(phi) / (total input rate).

The optimizer itself never simulates packets (it is flow-level, like the
paper's own simulator [14]); this module provides the ground-truth check:
a discrete-event simulation with Poisson arrivals, random dispatching by
phi (footnote 2), exponential service with mean L_(a,k)/d_ij on links and
w(a,k)*wnode_i/s_i on CPUs, FIFO queues.  ``simulate`` measures the mean
end-to-end delay; tests/test_simulate.py asserts it matches Little's-law
prediction from the analytic cost.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools

import numpy as np

from repro.core.network import Instance
from repro.core.traffic import Phi


@dataclasses.dataclass
class SimResult:
    mean_delay: float          # mean packet system time (injection -> exit)
    n_delivered: int
    predicted_delay: float     # D(phi) / total rate (Little's law)
    mean_queue_occupancy: float


def simulate(inst: Instance, phi: Phi, *, horizon: float = 2_000.0,
             warmup: float = 200.0, seed: int = 0,
             max_events: int = 2_000_000) -> SimResult:
    rng = np.random.default_rng(seed)
    V, A = inst.V, inst.A
    adj = np.asarray(inst.adj)
    lp = np.asarray(inst.link_param)
    cp = np.asarray(inst.comp_param)
    L = np.asarray(inst.L)
    w = np.asarray(inst.w)
    wnode = np.asarray(inst.wnode)
    r = np.asarray(inst.r)
    dst = np.asarray(inst.dst)
    ntask = np.asarray(inst.n_tasks)
    phi_e = np.asarray(phi.e)
    phi_c = np.asarray(phi.c)

    # server state: one FIFO per link and per CPU
    link_busy_until = np.zeros((V, V))
    cpu_busy_until = np.zeros(V)

    counter = itertools.count()
    events: list = []          # (time, tiebreak, kind, payload)

    # schedule Poisson arrivals per (a, source)
    for a in range(A):
        for i in range(V):
            if r[a, i] > 0:
                t = rng.exponential(1.0 / r[a, i])
                heapq.heappush(events, (t, next(counter), "arr", (a, i)))

    delays = []
    occupancy_area = 0.0
    in_system = 0
    last_t = warmup
    delivered = 0

    def advance(t):
        nonlocal occupancy_area, last_t
        if t > last_t:
            occupancy_area += in_system * (t - last_t)
            last_t = t

    n_events = 0
    while events and n_events < max_events:
        t, _, kind, payload = heapq.heappop(events)
        if t > horizon:
            break
        n_events += 1
        if kind == "arr":
            a, i = payload
            # next arrival of this stream
            heapq.heappush(events, (t + rng.exponential(1.0 / r[a, i]),
                                    next(counter), "arr", (a, i)))
            if t >= warmup:
                advance(t)
                in_system += 1
            heapq.heappush(events, (t, next(counter), "hop", (a, 0, i, t)))
        else:
            a, k, i, t0 = payload
            # exit?
            if k == ntask[a] and i == dst[a]:
                if t0 >= warmup:
                    advance(t)
                    in_system -= 1
                    delays.append(t - t0)
                    delivered += 1
                continue
            # choose direction by phi (random dispatch, footnote 2)
            pe = phi_e[a, k, i].copy()
            pc = phi_c[a, k, i] if k < ntask[a] else 0.0
            tot = pe.sum() + pc
            if tot <= 1e-12:
                continue                     # dead end (zero-traffic row)
            u = rng.random() * tot
            if u < pc:
                # CPU: exponential service, mean w/(s_i) per packet
                svc = rng.exponential(w[a, k] * wnode[i] / cp[i])
                start = max(t, cpu_busy_until[i])
                done = start + svc
                cpu_busy_until[i] = done
                heapq.heappush(events, (done, next(counter), "hop",
                                        (a, k + 1, i, t0)))
            else:
                c = u - pc
                j = int(np.searchsorted(np.cumsum(pe), c))
                j = min(j, V - 1)
                svc = rng.exponential(L[a, k] / lp[i, j]) if lp[i, j] > 0 else 0.0
                start = max(t, link_busy_until[i, j])
                done = start + svc
                link_busy_until[i, j] = done
                heapq.heappush(events, (done, next(counter), "hop",
                                        (a, k, j, t0)))

    from repro.core.traffic import total_cost

    D = float(total_cost(inst, phi))
    lam = float(r.sum())
    span = max(last_t - warmup, 1e-9)
    return SimResult(
        mean_delay=float(np.mean(delays)) if delays else float("nan"),
        n_delivered=delivered,
        predicted_delay=D / lam,
        mean_queue_occupancy=occupancy_area / span,
    )
