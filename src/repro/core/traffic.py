"""Stage traffic, link flows and computation workloads (Section II).

Given a forwarding/offloading strategy ``phi`` the stage traffics
``t_i(a,k)`` satisfy the linear fixed points

    t(a,0) = Phi_0^T t(a,0) + r(a)
    t(a,k) = Phi_k^T t(a,k) + g(a,k-1),       g(a,k) = t(a,k) * phi_c(a,k)

(one next-stage packet per computed packet).  For loop-free strategies
``I - Phi^T`` is nonsingular (spectral radius < 1), so each stage is a dense
linear solve; the chain coupling is a ``lax.scan`` over k, and applications
are vmapped.  This is the synchronous, vectorized equivalent of the paper's
per-packet flow propagation.

The default solver path batches all (app, stage) factorizations into ONE
``(A*K1, V, V)`` LU (``stage_factors`` -> ``kernels.ops.batched_factor``)
and then consumes the whole factor stack in ONE fused chain-substitution
call (``ops.fused_chain_solve`` — per-stage padding/transpose/permutation
costs hoisted out of the scan, DESIGN.md §13).  The same factors serve the
marginal recursion (``core/marginals.py``) because its matrix
``I - Phi_k`` is this one un-transposed — one factorization per GP step
covers both sweeps (DESIGN.md §12).  ``solver="dense"`` keeps the seed's
per-stage ``jnp.linalg.solve`` as the differential reference.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import costs
from repro.core.network import Instance
from repro.kernels import ops


class Phi(NamedTuple):
    """Forwarding/offloading strategy (the optimization variable).

    e: (A, K1, V, V)  phi_{ij}(a,k) link-forwarding fractions
    c: (A, K1, V)     phi_{i0}(a,k) local-CPU offloading fractions
    """

    e: jnp.ndarray
    c: jnp.ndarray


class Flows(NamedTuple):
    t: jnp.ndarray   # (A, K1, V)    stage traffic t_i(a,k)
    g: jnp.ndarray   # (A, K1, V)    CPU rates g_i(a,k)
    f: jnp.ndarray   # (A, K1, V, V) link rates f_ij(a,k)
    F: jnp.ndarray   # (V, V)        total link bit-rates
    G: jnp.ndarray   # (V,)          total computation workloads


def _solve_stage(phi_e_k: jnp.ndarray, inject: jnp.ndarray) -> jnp.ndarray:
    """Solve t = Phi_k^T t + inject for one (application, stage)."""
    V = phi_e_k.shape[0]
    mat = jnp.eye(V, dtype=phi_e_k.dtype) - phi_e_k.T
    return jnp.linalg.solve(mat, inject)


# Below this node count the CPU fallback's batched factor+substitution is
# dispatch-bound and loses to the per-stage dense solve.  On TPU the Pallas
# kernel path is always preferred.  The historical hand-measured value;
# used whenever BENCH_gp.json rows are unavailable.
_AUTO_MIN_V_FALLBACK = 48


def _derive_auto_min_v(rows: Optional[list] = None,
                       backend: Optional[str] = None) -> int:
    """Dense-vs-batched crossover V, derived from committed bench rows.

    Reads the repo's BENCH_gp.json ``gp_scaling``/``batched_lu`` rows
    (each carries the measured batched-over-dense ``speedup`` at one V)
    and linearly interpolates the V where the speedup crosses 1.0.  The
    committed measurements put the crossover well below the old hardcoded
    48 (0.95x already at V=22), so deriving it here fixes the small-V
    dispatch regression without baking in another magic constant.

    The crossover is *per backend*: rows carry a ``backend`` key (rows
    recorded before the key existed count as ``"cpu"``), and only rows
    measured on the current backend (default ``jax.default_backend()``)
    enter the interpolation — a CPU-measured crossover says nothing about
    GPU/TPU dispatch.  Any failure — file missing (installed package), no
    rows for this backend, no crossing bracketed — falls back to
    :data:`_AUTO_MIN_V_FALLBACK`.  ``rows`` injects a row list directly
    (tests); default None reads the file.
    """
    import json
    import os

    if backend is None:
        backend = jax.default_backend()
    if rows is None:
        path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "..", "..", "..", "BENCH_gp.json")
        try:
            with open(path) as fh:
                rows = json.load(fh)["rows"]
        except (OSError, ValueError, KeyError):
            return _AUTO_MIN_V_FALLBACK
    pts = sorted(
        {int(r["V"]): float(r["speedup"])
         for r in rows
         if r.get("bench") == "gp_scaling"
         and r.get("solver") == "batched_lu"
         and r.get("backend", "cpu") == backend
         and "V" in r and "speedup" in r}.items())
    if len(pts) < 2:
        return _AUTO_MIN_V_FALLBACK
    if pts[0][1] >= 1.0:
        return pts[0][0]          # batched wins from the smallest measured V
    for (v1, s1), (v2, s2) in zip(pts, pts[1:]):
        if s1 < 1.0 <= s2:
            frac = (1.0 - s1) / (s2 - s1)
            return max(2, int(-(-(v1 + frac * (v2 - v1)) // 1)))
    return _AUTO_MIN_V_FALLBACK   # never crosses in the measured range


AUTO_MIN_V = _derive_auto_min_v()

# Minimum node count for "auto" to prefer the sparse fixed-point solver when
# an instance carries a sparse topology (network.with_sparse).  Below this
# the dense paths win — the sweeps are dispatch-bound and the V^3/V^2 work
# they avoid is small; at metro scale (V >= several hundred at O(V) edges)
# the sparse path is the only viable one (DESIGN.md §18).  Parity tests
# force solver="sparse" explicitly, so the threshold only steers "auto".
SPARSE_MIN_V = 128


def resolve_solver(solver: str, V: int, inst: Optional[Instance] = None
                   ) -> str:
    """Resolve the "auto" stage-solver policy to a concrete method.

    V is a static (shape-derived) quantity — and whether ``inst`` carries a
    sparse topology is static pytree structure — so the choice is made at
    trace time and each jitted program contains exactly one solver path.
    "auto" resolves to "sparse" when the instance carries a sparse topology
    and V >= :data:`SPARSE_MIN_V`; otherwise to "batched_lu"/"dense" by the
    per-backend bench-derived crossover :data:`AUTO_MIN_V`.
    """
    if solver != "auto":
        return solver
    if inst is not None and inst.has_sparse and V >= SPARSE_MIN_V:
        return "sparse"
    return "batched_lu" if (not ops.INTERPRET or V >= AUTO_MIN_V) else "dense"


def stage_factors(phi_e: jnp.ndarray) -> ops.BatchedLU:
    """Batched LU of every stage system ``I - Phi_k`` in one device call.

    phi_e (A, K1, V, V) -> BatchedLU with leading dims (A, K1).  The factors
    serve BOTH linear sweeps of a GP iteration: the traffic fixed point
    solves the transposed system (``trans=1``) and the marginal recursion
    the plain one (``trans=0``), so ``gp.gp_step`` factors once and shares
    (DESIGN.md §12).  Per-member condition flags live in ``.ok``; singular
    members (loopy candidates) yield non-finite solves that
    ``traffic_is_valid`` rejects, exactly like the dense path.
    """
    V = phi_e.shape[-1]
    mats = jnp.eye(V, dtype=phi_e.dtype) - phi_e
    return ops.batched_factor(mats)


def stage_traffic(
    inst: Instance,
    phi: Phi,
    fact: Optional[ops.BatchedLU] = None,
    *,
    solver: str = "auto",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Compute t (A,K1,V) and g (A,K1,V) by scanning the chain.

    solver="batched_lu" consumes ``fact`` (or factors all stages in one
    batched LU) and runs O(V^2) triangular solves per scan step;
    solver="sparse" runs the factorization-free neighbor-list fixed-point
    sweeps (requires ``inst.has_sparse``; O(E) per sweep, DESIGN.md §18);
    solver="dense" is the seed's per-stage ``jnp.linalg.solve`` reference;
    solver="auto" (default) picks per backend/size (``resolve_solver``).
    """
    solver = resolve_solver(solver, phi.e.shape[-1], inst)
    if solver in ("batched_lu", "sparse"):
        # One fused call consumes the whole (A, K1, V, V) stage stack:
        # t_k = (I - Phi_k)^-T (base_k + mult_k * t_{k-1}) with base_0 = r,
        # base_{k>0} = 0 and mult_k = phi_c_{k-1} (each computed packet of
        # stage k-1 injects one next-stage packet).  NOTE: no clamping — the
        # map phi -> t must stay exactly linear so closed-form marginals
        # (3)-(4) match autodiff and finite differences
        # (tests/test_marginals.py); divergent solutions from loopy
        # candidate strategies are rejected by ``traffic_is_valid`` instead.
        base = jnp.concatenate(
            [inst.r[:, None, :], jnp.zeros_like(phi.c[:, 1:])], axis=1)
        mult = jnp.concatenate(
            [jnp.zeros_like(phi.c[:, :1]), phi.c[:, :-1]], axis=1)
        if solver == "sparse":
            t = ops.sparse_chain_solve(
                ops.sparse_topo(inst), phi.e, base, mult, trans=1)
        else:
            if fact is None:
                fact = stage_factors(phi.e)
            t = ops.fused_chain_solve(fact, base, mult, trans=1)
        return t, t * phi.c

    def per_app(phi_e_a, phi_c_a, r_a):
        def step(inject, xs):
            phi_e_k, phi_c_k = xs
            t_k = _solve_stage(phi_e_k, inject)
            g_k = t_k * phi_c_k
            return g_k, (t_k, g_k)

        _, (t_a, g_a) = jax.lax.scan(step, r_a, (phi_e_a, phi_c_a))
        return t_a, g_a

    return jax.vmap(per_app)(phi.e, phi.c, inst.r)


def flows(
    inst: Instance,
    phi: Phi,
    fact: Optional[ops.BatchedLU] = None,
    *,
    solver: str = "auto",
    axis: Optional[str] = None,
) -> Flows:
    """All flow quantities induced by strategy phi (Table I).

    ``axis`` parameterizes the ONE network-wide measurement of the model:
    total link flows ``F_ij`` and workloads ``G_i`` are sums over *all*
    applications, so when the application axis is sharded over a mesh axis
    (core/distributed.py) the local partial sums are all-reduced with
    ``lax.psum(_, axis)`` — the paper's implicit all-reduce of locally
    measured flows.  ``axis=None`` (default, single device) keeps the
    plain einsum sums.  Per-application quantities ``t``/``g``/``f`` stay
    local to the shard either way.
    """
    t, g = stage_traffic(inst, phi, fact, solver=solver)
    f = t[..., None] * phi.e                                  # (A,K1,V,V)
    F = jnp.einsum("ak,akij->ij", inst.L, f)
    G = jnp.einsum("ak,aki->i", inst.w, g) * inst.wnode
    if axis is not None:
        F = jax.lax.psum(F, axis)
        G = jax.lax.psum(G, axis)
    return Flows(t=t, g=g, f=f, F=F, G=G)


def traffic_is_valid(inst: Instance, t: jnp.ndarray, *,
                     axis: Optional[str] = None) -> jnp.ndarray:
    """Scalar bool: t is a physical (loop-free) traffic solution.

    For a loop-free strategy, flow conservation bounds every stage traffic
    by the application's total injected rate; a routing loop makes the
    Neumann series diverge and the linear solve returns values far outside
    that bound (or non-finite).

    Under app sharding (``axis`` names the mesh axis) the bound uses the
    globally maximal injected rate (``pmax``) and the verdict is the
    all-shard AND, so the sharded vote matches the single-device check on
    the full application set.
    """
    rmax = jnp.max(jnp.sum(inst.r, axis=1))
    if axis is not None:
        rmax = jax.lax.pmax(rmax, axis)
    bound = 4.0 * rmax + 1.0
    finite = jnp.all(jnp.isfinite(t))
    ok = finite & jnp.all(t > -1e-3) & jnp.all(t < bound)
    if axis is not None:
        ok = jax.lax.pmax(jnp.where(ok, 0, 1), axis) == 0
    return ok


def total_cost(inst: Instance, phi: Phi, *, solver: str = "auto",
               axis: Optional[str] = None) -> jnp.ndarray:
    """Objective of problem (2): D(phi) = sum D_ij(F_ij) + sum C_i(G_i).

    With ``axis`` set, F/G are psum-reduced over the app shards first, so
    every shard returns the identical replicated global objective.
    """
    fl = flows(inst, phi, solver=solver, axis=axis)
    D_links = jnp.where(inst.adj, costs.cost(inst.link_kind, fl.F, inst.link_param), 0.0)
    C_nodes = costs.cost(inst.comp_kind, fl.G, inst.comp_param)
    return jnp.sum(D_links) + jnp.sum(C_nodes)


def link_marginals(inst: Instance, F: jnp.ndarray) -> jnp.ndarray:
    """D'_ij(F_ij), zero on non-links."""
    m = costs.marginal(inst.link_kind, F, inst.link_param)
    return jnp.where(inst.adj, m, 0.0)


def comp_marginals(inst: Instance, G: jnp.ndarray) -> jnp.ndarray:
    """C'_i(G_i)."""
    return costs.marginal(inst.comp_kind, G, inst.comp_param)


def renormalize(inst: Instance, phi: Phi) -> Phi:
    """Project phi back onto the simplex constraints (1), fixing drift.

    Non-negative clip then rescale each (a,k,i) row to sum 1, except
    degenerate rows (stage K_a at the destination / invalid stages) which
    are forced to zero; CPU fractions at the final stage are forced to zero.
    """
    e = jnp.where(inst.adj[None, None], jnp.maximum(phi.e, 0.0), 0.0)
    c = jnp.maximum(phi.c, 0.0) * inst.cpu_allowed()[:, :, None]
    tot = e.sum(-1) + c                                       # (A,K1,V)
    degen = inst.degenerate_mask()
    scale = jnp.where(degen | (tot <= 0), 0.0, 1.0 / jnp.maximum(tot, 1e-30))
    return Phi(e=e * scale[..., None], c=c * scale)


def repair_phi(inst: Instance, phi: Phi, seed_phi: Optional[Phi] = None,
               *, min_mass: float = 1e-3) -> Phi:
    """Project a live strategy onto a (possibly changed) instance.

    The online repair primitive (Section IV adaptivity; DESIGN.md §16):
    after a topology event — link/node failure, application
    arrival/departure — a previously feasible ``phi`` may carry mass on
    directions that no longer exist.  ``repair_phi``

      1. zeroes mass on dead links (``~adj``) and disallowed CPU rows,
      2. reseeds rows that lost (almost) all their mass — total remaining
         mass ``<= min_mass`` on a non-degenerate row — from ``seed_phi``,
      3. renormalizes back onto the simplex constraints (1).

    ``seed_phi`` should be a loop-free strategy valid for the NEW instance
    (callers use ``gp.init_phi(new_inst)``, the uncongested shortest-path
    strategy).  Without one, the fallback seeds full local offloading where
    the CPU direction exists and a uniform spread over the surviving
    out-links at final stages; the fallback keeps the output on the simplex
    but — unlike a shortest-path seed — cannot guarantee loop-freedom of
    the seeded rows, so prefer passing ``seed_phi``.

    The threshold matters: a row that kept only a sliver of mass (say
    ``1e-4`` on one surviving link) would be rescaled to route *everything*
    there, which is feasible but can be a terrible (even invalid-traffic)
    starting point; reseeding such rows instead costs nothing and keeps the
    warm start loop-free.  Rows above the threshold rescale as usual —
    that is exactly the renormalize repair the paper's adaptivity argument
    relies on.

    Invariants (property-tested in tests/test_online_properties.py): the
    output satisfies constraint (1) exactly (``feasibility_violation`` ~ 0),
    carries zero mass on non-links, and zero CPU mass where offloading is
    not allowed.
    """
    e = jnp.where(inst.adj[None, None], jnp.maximum(phi.e, 0.0), 0.0)
    c = jnp.maximum(phi.c, 0.0) * inst.cpu_allowed()[:, :, None]
    tot = e.sum(-1) + c
    empty = (tot <= min_mass) & ~inst.degenerate_mask()        # (A,K1,V)
    if seed_phi is None:
        cpu_ok = inst.cpu_allowed()[:, :, None]                # (A,K1,1)
        out_deg = jnp.maximum(inst.adj.sum(-1, keepdims=True), 1)
        uniform = inst.adj.astype(e.dtype) / out_deg           # (V,V)
        seed_e = jnp.where(cpu_ok[..., None], 0.0,
                           jnp.broadcast_to(uniform[None, None], e.shape))
        seed_c = jnp.broadcast_to(cpu_ok.astype(c.dtype), c.shape)
        seed_phi = Phi(e=seed_e, c=seed_c)
    e = jnp.where(empty[..., None], seed_phi.e, e)
    c = jnp.where(empty, seed_phi.c, c)
    return renormalize(inst, Phi(e=e, c=c))


def feasibility_violation(inst: Instance, phi: Phi) -> jnp.ndarray:
    """Max violation of constraint (1) — for tests and invariant checks."""
    tot = phi.e.sum(-1) + phi.c
    degen = inst.degenerate_mask()
    want = jnp.where(degen, 0.0, 1.0)
    return jnp.max(jnp.abs(tot - want))


class StrategyViolations(NamedTuple):
    """Per-invariant maxima of a live strategy (the §17 guardrail checks).

    Every field is a scalar; an exactly-feasible strategy reports 0 (or
    ``False``) everywhere.  ``nonfinite`` is the hard-corruption flag: any
    nan/inf entry in phi poisons every downstream flow measurement, so it
    is reported separately from the magnitude checks (whose comparisons a
    nan would silently pass).
    """

    simplex: jnp.ndarray         # max |row sum - expected| over (a,k,i)
    dead_link_mass: jnp.ndarray  # max phi.e mass on (i,j) not in E
    dead_app_mass: jnp.ndarray   # max mass on rows of dead/padded apps
    cpu_mass: jnp.ndarray        # max phi.c where offloading is disallowed
    nonfinite: jnp.ndarray       # bool: any non-finite entry in phi


def strategy_violations(inst: Instance, phi: Phi) -> StrategyViolations:
    """Measure every runtime strategy invariant in one jittable call.

    The numeric core of ``serve.online.OnlineSolver.verify_fleet``
    (DESIGN.md §17): simplex rows (constraint (1)), zero mass on dead
    links, zero mass on dead/padded application rows, zero CPU mass where
    offloading is disallowed, and finiteness of every entry.  Pure and
    vmappable, so fleet-wide checks batch into one device program.
    """
    live_app = inst.stage_mask.any(axis=1)                   # (A,)
    dead_e = jnp.where(inst.adj[None, None], 0.0, jnp.abs(phi.e))
    dead_rows = jnp.where(live_app[:, None, None], 0.0,
                          jnp.abs(phi.e).sum(-1) + jnp.abs(phi.c))
    bad_c = jnp.where(inst.cpu_allowed()[:, :, None], 0.0, jnp.abs(phi.c))
    finite = jnp.all(jnp.isfinite(phi.e)) & jnp.all(jnp.isfinite(phi.c))
    return StrategyViolations(
        simplex=feasibility_violation(inst, phi),
        dead_link_mass=jnp.max(dead_e),
        dead_app_mass=jnp.max(dead_rows),
        cpu_mass=jnp.max(bad_c),
        nonfinite=~finite,
    )


def capacity_slack(inst: Instance, F: jnp.ndarray) -> jnp.ndarray:
    """Min over links of ``theta * capacity - F`` (the M/M/1 headroom).

    Negative slack means some link operates beyond the modelled queueing
    region (``costs.saturated``) — the strategy is still *feasible* (the
    quadratic cost extension keeps costs finite) but the served delay no
    longer tracks the M/M/1 model, which is the "capacity slack" guardrail
    of DESIGN.md §17.  LINEAR links have no capacity; instances whose link
    family is LINEAR report ``+inf``.
    """
    if inst.link_kind == costs.LINEAR:
        return jnp.asarray(jnp.inf, dtype=F.dtype)
    slack = jnp.where(inst.adj, costs._THETA * inst.link_param - F, jnp.inf)
    return jnp.min(slack)
