from repro.data.pipeline import SyntheticTokens, batch_for, eval_inputs  # noqa: F401
