"""Synthetic data pipeline: deterministic, seeded token/embedding streams.

Produces per-architecture batches of the right modality:
  * LM archs:    {tokens, targets}         (targets = next-token shift)
  * audio:       {embeds, targets, mask}   (masked cluster prediction)
  * vlm:         {patches, tokens, targets}

A Markov-chain token source gives the model non-trivial structure to learn
(loss decreases measurably within a few hundred steps for ~100M models),
which the end-to-end example uses as its convergence check.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class SyntheticTokens:
    """Order-1 Markov token stream with a planted low-rank transition."""

    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    rank: int = 8

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v, r = self.vocab, self.rank
        left = rng.dirichlet(np.ones(r) * 0.3, size=v)        # (v, r)
        right = rng.dirichlet(np.ones(v) * 0.5, size=r)       # (r, v)
        self.trans = (left @ right).astype(np.float64)
        self.trans /= self.trans.sum(1, keepdims=True)
        self.cum = np.cumsum(self.trans, axis=1)

    def __iter__(self) -> Iterator[dict]:
        rng = np.random.default_rng(self.seed + 1)
        while True:
            toks = np.empty((self.batch, self.seq_len + 1), np.int32)
            toks[:, 0] = rng.integers(self.vocab, size=self.batch)
            u = rng.random((self.batch, self.seq_len))
            for t in range(self.seq_len):
                toks[:, t + 1] = np.array(
                    [np.searchsorted(self.cum[toks[b, t]], u[b, t])
                     for b in range(self.batch)], np.int32)
            yield {
                "tokens": jnp.asarray(toks[:, :-1]),
                "targets": jnp.asarray(toks[:, 1:]),
            }


def batch_for(cfg: ModelConfig, batch: int, seq_len: int, seed: int = 0) -> dict:
    """One deterministic batch of the right modality for cfg."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.frontend == "audio":
        embeds = 0.1 * jax.random.normal(k1, (batch, seq_len, cfg.d_model))
        targets = jax.random.randint(k2, (batch, seq_len), 0, cfg.vocab)
        # HuBERT-style span masking: ~8% mask starts, span 10
        starts = jax.random.bernoulli(k3, 0.08, (batch, seq_len))
        mask = starts
        for _ in range(9):
            mask = mask | jnp.roll(mask, 1, axis=1)
        return {"embeds": embeds, "targets": targets, "mask": mask}
    if cfg.frontend == "vision":
        n_text = max(seq_len - cfg.n_patches, 16)
        patches = 0.1 * jax.random.normal(k1, (batch, min(cfg.n_patches, seq_len - 16), cfg.d_model))
        tokens = jax.random.randint(k2, (batch, n_text), 0, cfg.vocab)
        targets = jnp.roll(tokens, -1, axis=1)
        return {"patches": patches, "tokens": tokens, "targets": targets}
    tokens = jax.random.randint(k1, (batch, seq_len + 1), 0, cfg.vocab)
    return {"tokens": tokens[:, :-1], "targets": tokens[:, 1:]}


def eval_inputs(cfg: ModelConfig, batch: int, seq_len: int):
    """Shapes-only stand-ins (ShapeDtypeStruct) — see launch/dryrun.py."""
    from repro.launch.specs import input_specs

    return input_specs(cfg, batch, seq_len, mode="train")
