"""Blocked batched-LU Pallas kernel for the GP hot loop's stage systems.

Every GP iteration solves O(ladder x apps x stages) small dense systems

    (I - Phi_k)   pdt = b      (marginal recursion (4), row form)
    (I - Phi_k)^T t   = inject (traffic fixed point, Section II)

whose matrices differ only by a transpose.  This module provides the batched
factorization + triangular-solve pair that turns that pile of tiny solves
into ONE ``(B, V, V)`` device program:

  * :func:`lu_factor` — unpivoted blocked LU, one batch member per grid
    step.  Loop-free strategies make ``I - Phi`` a nonsingular M-matrix
    (unit diagonal, row-diagonally dominant), for which LU without pivoting
    exists and is stable; near-singular members (loopy candidate
    strategies) produce ~0 pivots whose non-finite quotients are surfaced
    through per-member ``ok`` flags rather than exceptions — the contract
    DESIGN.md §2 and §12 rely on to keep divergence detectable under vmap.
  * :func:`lu_solve` — the companion two-sweep triangular solve, with
    ``trans=1`` reusing the same factors for the transposed system.
  * :func:`ref_factor` / :func:`ref_solve` — the ``jax.lax.linalg`` (LAPACK
    partial-pivoting) reference path; also the CPU dispatch target of
    ``kernels.ops`` since interpret-mode Pallas cannot beat native LAPACK.

Blocking scheme (§12): the (Vp, Vp) matrix is resident in VMEM; a static
python loop walks column panels of width ``NB``.  Within a panel, columns
are eliminated by masked rank-1 updates (VPU); the panel's trailing block
row is recovered by a Neumann sweep of the nilpotent strictly-lower panel
(``U12 = A12 - L11s @ U12`` iterated NB times, MXU matmuls); the trailing
submatrix update ``A22 -= L21 @ U12`` is a single MXU matmul — the O(V^3)
bulk of the factorization.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128       # lane-dim alignment on real TPU
SUBLANE = 8      # cheaper alignment used under interpret mode (tests/CPU)
DEFAULT_NB = 32  # column-panel width of the blocked factorization

# |U_ii| below this is treated as a structurally singular member.
PIVOT_TINY = 1e-30


# ---------------------------------------------------------------------------
# Reference path (jax.lax.linalg factorization + block substitution)
# ---------------------------------------------------------------------------
#
# The factorization is LAPACK's batched partial-pivoting getrf
# (``jax.lax.linalg.lu``).  The SOLVE phase deliberately avoids XLA's
# ``triangular_solve``: on CPU its batched lowering is orders of magnitude
# slower than the O(B V^2) flop count (measured ~50ms for 90 single-rhs
# V=100 solves).  Instead, factor time precomputes the inverses of the
# nb x nb diagonal blocks of L and U — a log-depth Neumann product over
# ONE (B * nblk, nb, nb) matmul stack, valid because the strict triangle
# of a triangular block is nilpotent — and each solve is then a short
# static chain of batched matvecs (one per block row), which XLA:CPU maps
# to well-optimized batched GEMV.  This is the same blocking scheme the
# Pallas kernel uses on TPU, expressed at the XLA level (DESIGN.md §12).

# Substitution block width.  The diag-block inverse prework costs
# O(log(nb) * V * nb^2) flops per member and the solve sweeps O(V/nb)
# dispatches — nb=16 balances the two on CPU (nb=32 triples factor-time
# flops for one fewer solve dispatch per sweep).
REF_NB = 16


def _pad_square(a: jnp.ndarray, Vp: int) -> jnp.ndarray:
    """Pad (B, V, V) to (B, Vp, Vp) with an identity tail block."""
    V = a.shape[-1]
    if Vp == V:
        return a
    a = jnp.pad(a, ((0, 0), (0, Vp - V), (0, Vp - V)))
    tail = (jnp.arange(Vp) >= V).astype(a.dtype)
    return a + jnp.diag(tail)[None]


def _diag_blocks(a: jnp.ndarray, nb: int) -> jnp.ndarray:
    """(B, Vp, Vp) -> (B, nblk, nb, nb) diagonal blocks."""
    B, Vp, _ = a.shape
    nblk = Vp // nb
    d = jnp.diagonal(a.reshape(B, nblk, nb, nblk, nb), axis1=1, axis2=3)
    return jnp.moveaxis(d, -1, 1)


def _nilpotent_inv(X: jnp.ndarray) -> jnp.ndarray:
    """inv(I - X) for strictly-triangular (nilpotent) X, any leading dims.

    Uses the log-depth product identity sum_{k<2^m} X^k =
    prod_j (I + X^(2^j)) — ceil(log2 nb) batched matmul rounds instead of
    nb substitution steps.
    """
    nb = X.shape[-1]
    eye = jnp.eye(nb, dtype=X.dtype)
    acc = eye + X
    span = 2
    while span < nb:
        X = jnp.einsum("...ij,...jk->...ik", X, X)
        acc = jnp.einsum("...ij,...jk->...ik", acc, eye + X)
        span *= 2
    return acc


def block_inverses(lu: jnp.ndarray, nb: int = REF_NB
                   ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Inverses of the diagonal nb-blocks of packed factors.

    lu (B, V, V) -> (linv, uinv), each (B, nblk, nb, nb), where
    linv[b, i] = inv(L_ii) (unit lower) and uinv[b, i] = inv(U_ii).
    Padding blocks are identity, so padded solves are exact.
    """
    V = lu.shape[-1]
    Vp = -(-V // nb) * nb
    lup = _pad_square(lu.astype(jnp.float32), Vp)
    tri = jnp.tril(jnp.ones((nb, nb), jnp.float32), -1)
    Lb = _diag_blocks(lup, nb) * tri                     # strict lower
    linv = _nilpotent_inv(-Lb)
    Ub = _diag_blocks(lup, nb) * (1.0 - tri)             # upper incl diag
    d = jnp.diagonal(Ub, axis1=-2, axis2=-1)             # (B, nblk, nb)
    dinv = 1.0 / d
    Nu = dinv[..., :, None] * Ub * tri.T                 # row-scaled strict upper
    uinv = _nilpotent_inv(-Nu) * dinv[..., None, :]
    return linv, uinv


def ref_factor(mats: jnp.ndarray
               ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Batched LAPACK LU + substitution prework.

    mats (B, V, V) -> (lu, perm (B, V) int32 row permutation with
    ``mats[perm] = L @ U``, linv, uinv).
    """
    lu, _, perm = jax.lax.linalg.lu(mats.astype(jnp.float32))
    linv, uinv = block_inverses(lu)
    return lu, perm, linv, uinv


def _block_subst(mat: jnp.ndarray, dinv: jnp.ndarray, b: jnp.ndarray,
                 nb: int, *, lower: bool) -> jnp.ndarray:
    """Solve T x = b for block-triangular T given diag-block inverses.

    mat (B, Vp, Vp) carries T in its lower (or upper) triangle; coupling
    to already-solved blocks is a masked batched matvec per block row —
    the intra-block triangle is folded into ``dinv``.
    """
    B, Vp = b.shape
    nblk = Vp // nb
    cols = jnp.arange(Vp)
    x = jnp.zeros_like(b)
    order = range(nblk) if lower else range(nblk - 1, -1, -1)
    for i in order:
        sl = slice(i * nb, (i + 1) * nb)
        panel = mat[:, sl, :]
        mask = (cols < i * nb) if lower else (cols >= (i + 1) * nb)
        s = jnp.einsum("brv,bv->br", panel * mask, x)
        x_i = jnp.einsum("brc,bc->br", dinv[:, i], b[:, sl] - s)
        x = x.at[:, sl].set(x_i)
    return x


def _subst_single(mat: jnp.ndarray, dinv: jnp.ndarray, b: jnp.ndarray,
                  nb: int, *, lower: bool) -> jnp.ndarray:
    """Single-system variant of :func:`_block_subst`: mat (Vp, Vp),
    dinv (nblk, nb, nb), b (Vp,) -> x (Vp,).  Batches by jax.vmap.

    The block-row loop is static, so the coupling to already-solved blocks
    is a *statically sliced* matvec (``mat[sl, :i*nb] @ x[:i*nb]``) rather
    than the masked full-row product of ``_block_subst`` — half the flops
    and no mask materialization, which matters inside the fused chain scan.
    """
    Vp = b.shape[0]
    nblk = Vp // nb
    x = jnp.zeros_like(b)
    order = range(nblk) if lower else range(nblk - 1, -1, -1)
    for i in order:
        sl = slice(i * nb, (i + 1) * nb)
        done = slice(0, i * nb) if lower else slice((i + 1) * nb, Vp)
        s = mat[sl, done] @ x[done] if done.stop != done.start else 0.0
        x = x.at[sl].set(dinv[i] @ (b[sl] - s))
    return x


def ref_chain_solve(lu: jnp.ndarray, perm: jnp.ndarray,
                    linv: jnp.ndarray, uinv: jnp.ndarray,
                    base: jnp.ndarray, mult: jnp.ndarray,
                    *, trans: int = 0, reverse: bool = False,
                    clamp: bool = False, nb: int = REF_NB) -> jnp.ndarray:
    """Fused sequential solve over a whole factor stack (one chain).

    Solves, along the stage axis k (forward, or backward with
    ``reverse=True``),

        x_k = A_k^{-1(T)} (base_k + mult_k * x_prev),     x_prev(start) = 0

    for lu (K, V, V), perm (K, V), linv/uinv (K, nblk, nb, nb) and
    base/mult (K, V), returning x (K, V) — the shared recurrence shape of
    the traffic fixed point (trans=1, forward, mult = shifted phi_c) and
    the marginal recursion (trans=0, reverse, mult = phi_c, clamp >= 0).

    Compared with calling :func:`ref_solve` once per stage inside a scan,
    every per-stage fixed cost — factor padding, the trans transpose, the
    permutation (arg)sort, dtype casts — is hoisted out of the loop and
    paid ONCE for the whole (K, V, V) stack; the scan body is only the two
    block-substitution sweeps plus the O(V) affine RHS.  This is what
    moves the CPU dense-vs-batched crossover (traffic.AUTO_MIN_V) down
    (DESIGN.md §13).
    """
    K, V = base.shape
    Vp = linv.shape[-3] * nb
    lup = _pad_square(lu.astype(jnp.float32), Vp)
    basep = jnp.pad(base.astype(jnp.float32), ((0, 0), (0, Vp - V)))
    multp = jnp.pad(mult.astype(jnp.float32), ((0, 0), (0, Vp - V)))
    tail = jnp.broadcast_to(jnp.arange(V, Vp, dtype=perm.dtype), (K, Vp - V))
    permp = jnp.concatenate([perm, tail], axis=1).astype(jnp.int32)
    if trans == 0:
        mats, d1, d2 = lup, linv, uinv
        pre, post = permp, None
    else:
        # A^T = U^T L^T P: sweep the transposed pack, un-permute the result
        mats = lup.transpose(0, 2, 1)
        d1 = uinv.transpose(0, 1, 3, 2)
        d2 = linv.transpose(0, 1, 3, 2)
        pre, post = None, jnp.argsort(permp, axis=1)

    def step(carry, xs):
        mat_k, d1_k, d2_k, base_k, mult_k, pre_k, post_k = xs
        b = base_k + mult_k * carry
        if pre is not None:
            b = b[pre_k]
        y = _subst_single(mat_k, d1_k, b, nb, lower=True)
        x = _subst_single(mat_k, d2_k, y, nb, lower=False)
        if post is not None:
            x = x[post_k]
        if clamp:
            x = jnp.maximum(x, 0.0)
        return x, x

    zeros_i = jnp.zeros((K, 1), jnp.int32)  # placeholder for the unused perm
    xs = (mats, d1, d2, basep, multp,
          pre if pre is not None else zeros_i,
          post if post is not None else zeros_i)
    _, x = jax.lax.scan(step, jnp.zeros((Vp,), jnp.float32), xs,
                        reverse=reverse)
    return x[:, :V]


def ref_solve(lu: jnp.ndarray, perm: jnp.ndarray,
              linv: jnp.ndarray, uinv: jnp.ndarray, rhs: jnp.ndarray,
              *, trans: int = 0, nb: int = REF_NB) -> jnp.ndarray:
    """Solve A x = rhs (trans=0) or A^T x = rhs (trans=1) from ref_factor."""
    B, V = rhs.shape
    Vp = linv.shape[1] * nb
    lup = _pad_square(lu.astype(jnp.float32), Vp)
    b = rhs.astype(jnp.float32)
    if trans == 0:
        # A = P^T L U:  L U x = b[perm]
        bp = jnp.take_along_axis(b, perm.astype(jnp.int32), axis=1)
        bp = jnp.pad(bp, ((0, 0), (0, Vp - V)))
        y = _block_subst(lup, linv, bp, nb, lower=True)
        x = _block_subst(lup, uinv, y, nb, lower=False)
        return x[:, :V]
    # A^T = U^T L^T P:  solve U^T y = b, L^T z = y, then undo the row perm
    lupT = lup.transpose(0, 2, 1)
    uinvT = uinv.transpose(0, 1, 3, 2)
    linvT = linv.transpose(0, 1, 3, 2)
    bp = jnp.pad(b, ((0, 0), (0, Vp - V)))
    y = _block_subst(lupT, uinvT, bp, nb, lower=True)
    z = _block_subst(lupT, linvT, y, nb, lower=False)[:, :V]
    inv_perm = jnp.argsort(perm, axis=1)
    return jnp.take_along_axis(z, inv_perm, axis=1)


# ---------------------------------------------------------------------------
# Pallas kernels
# ---------------------------------------------------------------------------

def _pad_dim(V: int, interpret: bool) -> int:
    mult = SUBLANE if interpret else LANE
    return -(-V // mult) * mult


def _lu_kernel(a_ref, lu_ref, *, nb: int):
    """Unpivoted blocked LU of one (Vp, Vp) matrix, in-register."""
    a = a_ref[0].astype(jnp.float32)
    Vp = a.shape[0]
    row = jax.lax.broadcasted_iota(jnp.int32, (Vp, Vp), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (Vp, Vp), 1)
    vidx = jax.lax.broadcasted_iota(jnp.int32, (Vp,), 0)

    for p0 in range(0, Vp, nb):
        p1 = min(p0 + nb, Vp)

        def col_step(k, a):
            # Masked rank-1 elimination of column k, update restricted to
            # the panel's columns (the trailing block is updated once per
            # panel by the MXU matmul below).
            piv = jnp.sum(jnp.where((row == k) & (col == k), a, 0.0))
            colk = jnp.sum(jnp.where(col == k, a, 0.0), axis=1)      # (Vp,)
            l = jnp.where(vidx > k, colk / piv, 0.0)
            rowk = jnp.sum(jnp.where(row == k, a, 0.0), axis=0)      # (Vp,)
            u = jnp.where((vidx > k) & (vidx < p1), rowk, 0.0)
            a = a - l[:, None] * u[None, :]
            # store the multipliers below the diagonal of column k
            return jnp.where((col == k) & (row > k), l[:, None], a)

        a = jax.lax.fori_loop(p0, p1, col_step, a)

        if p1 < Vp:
            nb_p = p1 - p0
            L11 = a[p0:p1, p0:p1]
            rloc = jax.lax.broadcasted_iota(jnp.int32, (nb_p, nb_p), 0)
            cloc = jax.lax.broadcasted_iota(jnp.int32, (nb_p, nb_p), 1)
            L11s = jnp.where(rloc > cloc, L11, 0.0)   # strictly lower, nilpotent
            A12 = a[p0:p1, p1:]
            # U12 = (I + L11s)^{-1} A12 via the finite Neumann fixed point
            # (exact after nb_p sweeps since L11s^nb_p = 0) — MXU matmuls.
            U12 = A12
            for _ in range(nb_p):
                U12 = A12 - jax.lax.dot(L11s, U12)
            L21 = a[p1:, p0:p1]
            a = a.at[p0:p1, p1:].set(U12)
            a = a.at[p1:, p1:].add(-jax.lax.dot(L21, U12))

    lu_ref[0, ...] = a.astype(lu_ref.dtype)


def _two_sweep(luw: jnp.ndarray, b: jnp.ndarray, *, trans: int) -> jnp.ndarray:
    """In-kernel two-sweep substitution on a packed factor.

    ``luw`` is the packed L\\U (already transposed by the caller when
    trans=1); solves L U x = b (trans=0) or (L U)^T x = b (trans=1) — in
    both cases a forward then a backward row sweep of ``luw``.
    """
    Vp = luw.shape[0]
    vidx = jax.lax.broadcasted_iota(jnp.int32, (Vp,), 0)

    def row_of(m, i):
        return jax.lax.dynamic_slice(m, (i, 0), (1, Vp))[0]

    def diag_of(m, i):
        return jnp.sum(jnp.where(vidx == i, row_of(m, i), 0.0))

    # forward sweep: unit-lower L (trans=0) / lower-with-diag U^T (trans=1)
    def fwd(i, y):
        s = jnp.sum(jnp.where(vidx < i, row_of(luw, i), 0.0) * y)
        d = diag_of(luw, i) if trans else 1.0
        return jnp.where(vidx == i, (y - s) / d, y)

    y = jax.lax.fori_loop(0, Vp, fwd, b)

    # backward sweep: upper-with-diag U (trans=0) / unit-upper L^T (trans=1)
    def bwd(j, x):
        i = Vp - 1 - j
        s = jnp.sum(jnp.where(vidx > i, row_of(luw, i), 0.0) * x)
        d = 1.0 if trans else diag_of(luw, i)
        return jnp.where(vidx == i, (x - s) / d, x)

    return jax.lax.fori_loop(0, Vp, bwd, y)


def _solve_kernel(lu_ref, b_ref, x_ref, *, trans: int):
    """Two-sweep substitution for one packed-LU system.

    trans=0 solves L U x = b; trans=1 solves (L U)^T x = b, i.e. first the
    lower-triangular U^T then the unit-upper L^T — both become row sweeps of
    the transposed packed factor, so one upfront transpose unifies the code.
    """
    lu = lu_ref[0].astype(jnp.float32)
    b = b_ref[0, 0].astype(jnp.float32)                          # (Vp,)
    luw = lu.T if trans else lu
    x = _two_sweep(luw, b, trans=trans)
    x_ref[0, 0, ...] = x.astype(x_ref.dtype)


def _chain_solve_kernel(lu_ref, base_ref, mult_ref, x_ref, *, trans: int,
                        reverse: bool, clamp: bool, K: int):
    """Fused chain of substitutions over one (K, Vp, Vp) factor stack.

    One batch member (= one app's whole stage chain) per grid step; the
    factor stack stays VMEM-resident and a single ``fori_loop`` walks the
    stages, so the sequential chain never leaves the core:

        x_k = A_k^{-1(T)} (base_k + mult_k * x_prev)

    Assumes identity row permutation (the unpivoted Pallas factors of
    :func:`lu_factor`); LAPACK-pivoted reference factors must go through
    :func:`ref_chain_solve` instead (kernels/ops.py dispatches).
    """
    Vp = lu_ref.shape[-1]

    zero = jnp.int32(0)

    def body(j, carry):
        k = (K - 1 - j) if reverse else j
        lu = pl.load(lu_ref, (zero, k, slice(None), slice(None))).astype(jnp.float32)
        base_k = pl.load(base_ref, (zero, k, slice(None))).astype(jnp.float32)
        mult_k = pl.load(mult_ref, (zero, k, slice(None))).astype(jnp.float32)
        b = base_k + mult_k * carry
        luw = lu.T if trans else lu
        x = _two_sweep(luw, b, trans=trans)
        if clamp:
            x = jnp.maximum(x, 0.0)
        pl.store(x_ref, (zero, k, slice(None)), x.astype(x_ref.dtype))
        return x

    jax.lax.fori_loop(0, K, body, jnp.zeros((Vp,), jnp.float32))


# ---------------------------------------------------------------------------
# Wrappers (padding + pallas_call plumbing)
# ---------------------------------------------------------------------------

def lu_factor(mats: jnp.ndarray, *, nb: int = DEFAULT_NB,
              interpret: bool = False) -> jnp.ndarray:
    """Unpivoted blocked LU of a (B, V, V) batch -> packed (B, V, V) factors.

    The pad region is an identity block, whose LU is itself, so padding and
    slicing commute with the factorization.
    """
    B, V, _ = mats.shape
    Vp = _pad_dim(V, interpret)
    a = _pad_square(mats.astype(jnp.float32), Vp)

    out = pl.pallas_call(
        functools.partial(_lu_kernel, nb=min(nb, Vp)),
        grid=(B,),
        in_specs=[pl.BlockSpec((1, Vp, Vp), lambda b: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, Vp, Vp), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Vp, Vp), jnp.float32),
        interpret=interpret,
    )(a)
    return out[:, :V, :V]


def lu_solve(lu: jnp.ndarray, rhs: jnp.ndarray, *, trans: int = 0,
             interpret: bool = False) -> jnp.ndarray:
    """Solve packed-LU systems: lu (B, V, V), rhs (B, V) -> (B, V)."""
    B, V, _ = lu.shape
    Vp = _pad_dim(V, interpret)
    a = _pad_square(lu.astype(jnp.float32), Vp)
    b = jnp.pad(rhs.astype(jnp.float32), ((0, 0), (0, Vp - V)))

    out = pl.pallas_call(
        functools.partial(_solve_kernel, trans=trans),
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, Vp, Vp), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1, Vp), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Vp), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1, Vp), jnp.float32),
        interpret=interpret,
    )(a, b[:, None, :])
    return out[:, 0, :V]


def chain_solve(lu: jnp.ndarray, base: jnp.ndarray, mult: jnp.ndarray,
                *, trans: int = 0, reverse: bool = False, clamp: bool = False,
                interpret: bool = False) -> jnp.ndarray:
    """Fused chain solve: lu (B, K, V, V), base/mult (B, K, V) -> (B, K, V).

    Each grid step runs one member's whole stage chain inside the kernel
    (see :func:`_chain_solve_kernel`); identity row permutation assumed.
    """
    B, K, V, _ = lu.shape
    Vp = _pad_dim(V, interpret)
    a = _pad_square(lu.reshape(B * K, V, V).astype(jnp.float32), Vp)
    a = a.reshape(B, K, Vp, Vp)
    pad = ((0, 0), (0, 0), (0, Vp - V))
    basep = jnp.pad(base.astype(jnp.float32), pad)
    multp = jnp.pad(mult.astype(jnp.float32), pad)

    out = pl.pallas_call(
        functools.partial(_chain_solve_kernel, trans=trans, reverse=reverse,
                          clamp=clamp, K=K),
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, K, Vp, Vp), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((1, K, Vp), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, K, Vp), lambda b: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, K, Vp), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, Vp), jnp.float32),
        interpret=interpret,
    )(a, basep, multp)
    return out[:, :, :V]


def factor_ok(lu: jnp.ndarray) -> jnp.ndarray:
    """(B,) bool condition flags from packed factors (either pivot scheme).

    A member is flagged not-ok when its factors contain non-finite entries
    or a ~zero U pivot — the batched analogue of LAPACK's ``info`` return,
    evaluated without host sync so flagged members cannot poison the batch
    (their lanes simply carry inf/nan forward to ``traffic_is_valid``).
    """
    diag = jnp.diagonal(lu, axis1=-2, axis2=-1)
    finite = jnp.all(jnp.isfinite(lu), axis=(-2, -1))
    return finite & (jnp.min(jnp.abs(diag), axis=-1) > PIVOT_TINY)


def residuals(mats: jnp.ndarray, x: jnp.ndarray, rhs: jnp.ndarray,
              *, trans: int = 0) -> jnp.ndarray:
    """(B,) relative residuals ``|A x - b|_inf / (|b|_inf + 1)``.

    Non-finite solutions report ``inf`` — the per-member divergence signal
    the GP loop consumes instead of per-solve exceptions (DESIGN.md §12).
    """
    op = jnp.einsum("bji,bj->bi" if trans else "bij,bj->bi",
                    mats.astype(jnp.float32), x.astype(jnp.float32))
    r = jnp.max(jnp.abs(op - rhs), axis=-1) / (jnp.max(jnp.abs(rhs), axis=-1) + 1.0)
    return jnp.where(jnp.isfinite(r), r, jnp.inf)
