"""Bit-packed blocked-set ("tagged node") propagation kernel.

Algorithm 1 needs, per (app, stage), the blocked node sets B_i(a,k):
category 3 of Section IV tags every node whose routing subtree contains an
improper link (p, q) with dD/dt_q > dD/dt_p.  The seed computed this with a
dense boolean sweep — ``lax.scan`` of V rounds over the full (A, K1, V, V)
``route``/``improper`` tensors:

    tagged'[p] = exists q: route[p, q] and (improper[p, q] or tagged[q])

i.e. O(V) rounds of O(V^2) bool traffic per (a, k), always, even though the
propagation stabilizes after the routing-DAG diameter (a handful of hops on
Table II topologies).  After PR 2 batched the linear solves this sweep was
the co-dominant per-iteration cost at V = 100 (ROADMAP).

This module packs the successor axis into uint32 lanes:

  * ``route``/``improper`` (B, V, V) bool  ->  (B, Vp, W) uint32 with
    W = ceil(V / 32) — one word ANDs/ORs 32 successor bits at once;
  * ``tagged`` lives as a (B, W) node bitset, re-packed from the per-row
    ``any`` reduction each round;
  * rounds run under a ``lax.while_loop`` that exits as soon as the bitset
    stops changing — the fixed point is reached after (diameter + 1)
    rounds, not V.  The map is monotone (tagged only grows), so the early
    exit is *exact*: the result equals the V-round dense scan bit for bit.

Two executable paths, dispatched by ``kernels.ops.blocked_tagged`` exactly
like the batched-LU solver (DESIGN.md §13):

  * :func:`tagged_packed`  — packed jnp, the CPU/GPU path;
  * :func:`tagged_pallas`  — one batch member per grid step, the (Vp, W)
    bit matrices VMEM-resident, the while-loop sweep in-kernel (Mosaic on
    TPU, interpret mode for tests).
  * :func:`tagged_scan_dense` — the seed's dense V-round sweep, kept as
    the differential reference for parity tests and benchmarks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

WORD = 32  # bits per packed lane word


def padded_nodes(V: int) -> tuple[int, int]:
    """(Vp, W): node count padded to a word multiple, and the word count."""
    W = -(-V // WORD)
    return W * WORD, W


def pack_bits(x: jnp.ndarray) -> jnp.ndarray:
    """Pack a bool array along its last axis: (..., V) -> (..., W) uint32.

    Bit ``q % 32`` of word ``q // 32`` is ``x[..., q]``; the pad tail is 0.
    """
    V = x.shape[-1]
    Vp, W = padded_nodes(V)
    if Vp != V:
        widths = [(0, 0)] * (x.ndim - 1) + [(0, Vp - V)]
        x = jnp.pad(x, widths)
    xw = x.reshape(x.shape[:-1] + (W, WORD)).astype(jnp.uint32)
    weights = jnp.left_shift(jnp.uint32(1), jnp.arange(WORD, dtype=jnp.uint32))
    return jnp.sum(xw * weights, axis=-1, dtype=jnp.uint32)


def unpack_bits(w: jnp.ndarray, V: int) -> jnp.ndarray:
    """Inverse of :func:`pack_bits`: (..., W) uint32 -> (..., V) bool."""
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = jnp.bitwise_and(
        jnp.right_shift(w[..., None], shifts), jnp.uint32(1))
    return bits.reshape(w.shape[:-1] + (w.shape[-1] * WORD,))[..., :V] != 0


# ---------------------------------------------------------------------------
# Reference: the seed's dense V-round boolean sweep
# ---------------------------------------------------------------------------

def tagged_scan_dense(route: jnp.ndarray, improper: jnp.ndarray
                      ) -> jnp.ndarray:
    """Dense fixed point by V unconditional sweeps: (..., V, V) -> (..., V).

    This is the seed implementation of ``gp.blocked_sets``'s category-3
    propagation, kept verbatim as the parity reference for the packed
    kernels (tests/test_blocked_sets.py, kernel_bench).
    """
    V = route.shape[-1]

    def sweep(tagged, _):
        hit = improper | (route & tagged[..., None, :])
        return jnp.any(hit, axis=-1), None

    tagged0 = jnp.zeros(route.shape[:-1], dtype=bool)
    tagged, _ = jax.lax.scan(sweep, tagged0, None, length=V)
    return tagged


# ---------------------------------------------------------------------------
# Packed jnp path (CPU/GPU)
# ---------------------------------------------------------------------------

def tagged_packed(route_bits: jnp.ndarray, improper_bits: jnp.ndarray,
                  V: int, *, with_rounds: bool = False):
    """Packed frontier propagation: (B, Vp, W) uint32 x2 -> (B, V) bool.

    Runs word-wise OR-AND rounds under a ``while_loop`` that stops when the
    tagged bitset reaches its (monotone) fixed point — after at most
    ``diameter + 1`` rounds of the routing DAG instead of always V.  The
    round cap V + 1 is unreachable for any input (each round before the
    fixed point tags >= 1 new node) but bounds the loop for the compiler.

    ``with_rounds=True`` additionally returns the loop's round counter —
    the number of sweeps until the whole batch settled (the frontier-depth
    telemetry column, DESIGN.md §19).  The counter already drives the
    early exit; returning it changes no propagation arithmetic.
    """
    B, Vp, W = route_bits.shape

    def round_(tagged_bits):
        # hit[p] = exists word w: improper[p,w] | (route[p,w] & tagged[w])
        hit = improper_bits | (route_bits & tagged_bits[:, None, :])
        return pack_bits(jnp.any(hit != 0, axis=-1))

    def cond(carry):
        tb, prev, i = carry
        return jnp.any(tb != prev) & (i < Vp + 1)

    def body(carry):
        tb, _, i = carry
        return round_(tb), tb, i + 1

    tb0 = jnp.zeros((B, W), jnp.uint32)
    sentinel = jnp.full((B, W), jnp.uint32(0xFFFFFFFF))
    tb, _, rounds = jax.lax.while_loop(
        cond, body, (tb0, sentinel, jnp.int32(0)))
    tagged = unpack_bits(tb, V)
    if with_rounds:
        return tagged, rounds
    return tagged


# ---------------------------------------------------------------------------
# Pallas kernel
# ---------------------------------------------------------------------------

def _tagged_kernel(route_ref, imp_ref, out_ref):
    """One batch member per grid step; bit matrices VMEM-resident.

    The layout keeps nodes on the sublane axis and packed successor words
    on the lane axis — sized for large V (the lane dim fills at V >= 4096);
    below that the packed-jnp path is preferred even on TPU, which
    ``kernels.ops.blocked_tagged`` encodes (DESIGN.md §13).
    """
    route = route_ref[0]          # (Vp, W) uint32
    imp = imp_ref[0]
    Vp, W = route.shape
    weights = jnp.left_shift(jnp.uint32(1), jnp.arange(WORD, dtype=jnp.uint32))

    def round_(tb):
        hit = imp | (route & tb[None, :])
        tagged = jnp.any(hit != 0, axis=-1)                     # (Vp,)
        tw = tagged.reshape(W, WORD).astype(jnp.uint32)
        return jnp.sum(tw * weights, axis=-1, dtype=jnp.uint32)

    def cond(carry):
        tb, prev, i = carry
        return jnp.any(tb != prev) & (i < Vp + 1)

    def body(carry):
        tb, _, i = carry
        return round_(tb), tb, i + 1

    tb0 = jnp.zeros((W,), jnp.uint32)
    sentinel = jnp.full((W,), jnp.uint32(0xFFFFFFFF))
    tb, _, _ = jax.lax.while_loop(cond, body, (tb0, sentinel, jnp.int32(0)))
    out_ref[0, ...] = tb[None, :]


def tagged_pallas(route_bits: jnp.ndarray, improper_bits: jnp.ndarray,
                  V: int, *, interpret: bool = False) -> jnp.ndarray:
    """Pallas path: (B, Vp, W) uint32 x2 -> (B, V) bool tagged flags."""
    B, Vp, W = route_bits.shape
    out = pl.pallas_call(
        _tagged_kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, Vp, W), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, Vp, W), lambda b: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, W), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1, W), jnp.uint32),
        interpret=interpret,
    )(route_bits, improper_bits)
    return unpack_bits(out[:, 0, :], V)
