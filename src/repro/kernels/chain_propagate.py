"""Pallas TPU kernel for the paper's hot loop: batched stage propagation.

One Neumann-series step of the traffic / marginal fixed points, batched over
all (application, stage) pairs:

    out[s, :] = t[s, :] @ M[s, :, :] + src[s, :]

  * traffic sweep:   M = Phi (forward along links),  src = injections
  * marginal sweep:  M = Phi^T                        src = local marginals

Loop-free routing makes the series exact after <= |V| sweeps, so the online
GP iteration is a chain of these kernels.  TPU adaptation: stages are the
major grid axis (one stage's (V, V) routing matrix per VMEM residency),
node blocks are 128-aligned for the MXU matvec; the wrapper zero-pads V to
a lane multiple.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def _kernel(t_ref, m_ref, src_ref, out_ref):
    t = t_ref[0].astype(jnp.float32)                 # (1, Vp) row vector
    m = m_ref[0].astype(jnp.float32)                 # (Vp, Vp)
    src = src_ref[0].astype(jnp.float32)             # (1, Vp)
    out = jax.lax.dot(t, m) + src                    # MXU (1,Vp)x(Vp,Vp)
    out_ref[0, ...] = out.astype(out_ref.dtype)


def propagate_step(t, M, src, *, interpret=False):
    """t, src: (S, V); M: (S, V, V) -> (S, V). One sweep for all stages."""
    S, V = t.shape
    Vp = -(-V // LANE) * LANE
    pad = Vp - V
    if pad:
        t = jnp.pad(t, ((0, 0), (0, pad)))
        src = jnp.pad(src, ((0, 0), (0, pad)))
        M = jnp.pad(M, ((0, 0), (0, pad), (0, pad)))

    out = pl.pallas_call(
        _kernel,
        grid=(S,),
        in_specs=[
            pl.BlockSpec((1, 1, Vp), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, Vp, Vp), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, 1, Vp), lambda s: (s, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Vp), lambda s: (s, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((S, 1, Vp), jnp.float32),
        interpret=interpret,
    )(t[:, None, :], M, src[:, None, :])
    return out[:, 0, :V]


def solve_fixed_point(M, src, *, sweeps: int, interpret=False):
    """Iterate out <- out @ M + src from zero; exact for nilpotent M (loop-
    free routing) once sweeps >= longest path length."""
    t = jnp.zeros_like(src)
    step = functools.partial(propagate_step, interpret=interpret)
    for _ in range(sweeps):
        t = step(t, M, src)
    return t
