"""Pallas TPU flash attention (blockwise online-softmax), causal + SWA.

TPU adaptation notes (vs. the CUDA flash-attention algorithm):
  * tiling targets VMEM: one (BQ, hd) query tile and one (BK, hd) KV tile
    resident per grid step; BQ/BK default 128 = MXU-aligned.
  * the KV loop is the *minor grid axis* — TPU grids execute sequentially,
    so the running max / denominator / accumulator live in VMEM scratch and
    persist across KV steps for a fixed (batch, head, q-block), replacing
    the CUDA shared-memory reduction.
  * GQA is expressed in the BlockSpec index map (kv head = h // rep), so
    no materialized repeat of K/V ever reaches VMEM.

Layouts: q (B, H, S, hd), k/v (B, KV, S, hd) — the ``ops`` wrapper handles
(B, S, H, hd) transposition and padding to multiples of the block size.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window, bq: int, bk: int, nk: int,
            seq_len: int):
    i = pl.program_id(2)          # q block
    j = pl.program_id(3)          # kv block (minor: sequential on TPU)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)                  # (bk, hd)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))   # (bq, bk)

    q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < seq_len                                # padding
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                   # (bq,)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    p = jnp.where(mask, p, 0.0)                           # kill -inf rows
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot(p, v)
    m_scr[...] = m_cur

    @pl.when(j == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0, ...] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal=True, window=None,
                        bq=DEFAULT_BQ, bk=DEFAULT_BK, interpret=False):
    """q: (B,H,S,hd), k/v: (B,KV,S,hd) -> (B,H,S,hd).  S padded by caller."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    rep = H // KV
    bq, bk = min(bq, S), min(bk, S)
    nq, nk = S // bq, S // bk
    scale = hd ** -0.5

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, nk=nk, seq_len=S,
    )
    grid = (B, H, nq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),        # running max
            pltpu.VMEM((bq,), jnp.float32),        # denominator
            pltpu.VMEM((bq, hd), jnp.float32),     # accumulator
        ],
        interpret=interpret,
    )(q, k, v)
