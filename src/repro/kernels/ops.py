"""jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute with interpret=True; on a real
TPU the same call sites compile to Mosaic.  ``INTERPRET`` flips automatically
from the backend.

Shard-map contract (relied on by ``core/engine.py``, DESIGN.md §14): every
wrapper here is *collective-free and per-member* — leading batch dims are
flattened into the kernel grid and no wrapper ever reduces across them —
so the GP step engine may call them unchanged inside ``shard_map`` (each
app shard runs the kernels on its local slab) and under ``jax.vmap`` of a
shard (mesh-composed scenario families).  Keep new wrappers collective-free
too; network-wide reductions belong to the engine's ``axis`` plumbing.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import batched_solve as _bs
from repro.kernels import blocked_sets as _bset
from repro.kernels import chain_propagate as _cp
from repro.kernels import flash_attention as _fa
from repro.kernels import sparse_solve as _ss
from repro.kernels import ssd_chunk as _sc

INTERPRET = jax.default_backend() == "cpu"


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention(q, k, v, *, causal=True, window=None):
    """(B,S,H,hd) layout public API (matches models.attention.sdpa)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    qt, S = _pad_to(qt, 2, _fa.DEFAULT_BQ)
    kt, _ = _pad_to(kt, 2, _fa.DEFAULT_BK)
    vt, _ = _pad_to(vt, 2, _fa.DEFAULT_BK)
    out = _fa.flash_attention_fwd(qt, kt, vt, causal=causal, window=window,
                                  interpret=INTERPRET)
    return out[:, :, :S].transpose(0, 2, 1, 3)


@jax.jit
def propagate_step(t, M, src):
    return _cp.propagate_step(t, M, src, interpret=INTERPRET)


@functools.partial(jax.jit, static_argnames=("sweeps",))
def solve_fixed_point(M, src, *, sweeps: int):
    return _cp.solve_fixed_point(M, src, sweeps=sweeps, interpret=INTERPRET)


@jax.jit
def ssd_chunk(xh, dt, dtA, cum, BH, CH):
    """Adapter matching models.ssm.ssd_chunked's kernel call signature."""
    return _sc.ssd_chunk_fwd(xh, dt, cum, BH, CH, interpret=INTERPRET)


# ---------------------------------------------------------------------------
# Batched LU solve (the GP stage-system hot path — DESIGN.md §12)
# ---------------------------------------------------------------------------
#
# Dispatch: on TPU the blocked Pallas kernels compile to Mosaic; on CPU the
# "interpret-mode fallback" is engaged only when explicitly requested
# (``use_pallas=True`` — tests and kernel parity sweeps), because the
# default CPU path should hit native batched LAPACK (``jax.lax.linalg.lu``)
# rather than the Pallas interpreter.  Both paths share the packed-LU
# (B, V, V) layout and the per-member ``ok`` flag contract.

class BatchedLU(NamedTuple):
    """Packed LU factors of a batch of stage systems.

    lu:   (..., V, V) packed L\\U (unit diagonal of L implicit)
    perm: (..., V) int32 row permutation (``mats[perm] = L @ U``; identity
          for the Pallas path, which factors without pivoting — valid for
          the M-matrices ``I - Phi`` of loop-free strategies)
    linv: (..., nblk, nb, nb) inverses of L's diagonal blocks
    uinv: (..., nblk, nb, nb) inverses of U's diagonal blocks (the
          substitution prework of the reference path — DESIGN.md §12)
    ok:   (...,) bool per-member condition flag (False: singular /
          non-finite factor — the member's solves will carry inf/nan)
    """

    lu: jnp.ndarray
    perm: jnp.ndarray
    linv: jnp.ndarray
    uinv: jnp.ndarray
    ok: jnp.ndarray


# The batched-LU kernels are written for Mosaic (TPU): VMEM-resident
# arbitrary-size blocks, fori_loop row slicing.  On GPU the reference path
# (cuBLAS/cuSOLVER batched LU via lax.linalg) is both safe and fast, so
# Pallas engages by default only on TPU; interpret mode is for tests.
_PALLAS_DEFAULT = jax.default_backend() == "tpu"


def _use_pallas(use_pallas: Optional[bool]) -> bool:
    return _PALLAS_DEFAULT if use_pallas is None else use_pallas


def _flatten_batch(x, core_ndim):
    lead = x.shape[: x.ndim - core_ndim]
    flat = x.reshape((-1,) + x.shape[x.ndim - core_ndim:])
    return flat, lead


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def batched_factor(mats: jnp.ndarray, *, use_pallas: Optional[bool] = None
                   ) -> BatchedLU:
    """Factor a batch of dense systems: mats (..., V, V) -> BatchedLU.

    Any number of leading batch dims is accepted (they are flattened into
    the kernel grid and restored on return); composes with jax.vmap/scan.
    """
    flat, lead = _flatten_batch(mats, 2)
    V = flat.shape[-1]
    if _use_pallas(use_pallas):
        lu = _bs.lu_factor(flat, interpret=INTERPRET)
        perm = jnp.broadcast_to(jnp.arange(V, dtype=jnp.int32),
                                flat.shape[:1] + (V,))
        linv, uinv = _bs.block_inverses(lu)
    else:
        lu, perm, linv, uinv = _bs.ref_factor(flat)
    ok = _bs.factor_ok(lu)
    return BatchedLU(
        lu=lu.reshape(lead + (V, V)),
        perm=perm.reshape(lead + (V,)),
        linv=linv.reshape(lead + linv.shape[1:]),
        uinv=uinv.reshape(lead + uinv.shape[1:]),
        ok=ok.reshape(lead),
    )


@functools.partial(jax.jit, static_argnames=("trans", "use_pallas"))
def batched_solve_factored(fact: BatchedLU, rhs: jnp.ndarray, *,
                           trans: int = 0,
                           use_pallas: Optional[bool] = None) -> jnp.ndarray:
    """Solve A x = rhs (trans=0) or A^T x = rhs (trans=1) from factors.

    fact.lu (..., V, V), rhs (..., V) -> (..., V).  O(V^2) per member —
    the factorization cost is paid once per GP step, not once per stage
    sweep (core/traffic.py, core/marginals.py).
    """
    lu_flat, lead = _flatten_batch(fact.lu, 2)
    rhs_flat, _ = _flatten_batch(rhs, 1)
    if _use_pallas(use_pallas):
        # Honor the row permutation even for kernel solves, so factors are
        # path-portable: Pallas factors carry an identity perm (no-op
        # gather), while reference (LAPACK-pivoted) factors solve
        # correctly here too.
        perm_flat, _ = _flatten_batch(fact.perm.astype(jnp.int32), 1)
        if trans == 0:
            rhs_flat = jnp.take_along_axis(rhs_flat, perm_flat, axis=1)
        x = _bs.lu_solve(lu_flat, rhs_flat, trans=trans, interpret=INTERPRET)
        if trans != 0:
            inv_perm = jnp.argsort(perm_flat, axis=1)
            x = jnp.take_along_axis(x, inv_perm, axis=1)
    else:
        perm_flat, _ = _flatten_batch(fact.perm, 1)
        linv_flat, _ = _flatten_batch(fact.linv, 3)
        uinv_flat, _ = _flatten_batch(fact.uinv, 3)
        x = _bs.ref_solve(lu_flat, perm_flat, linv_flat, uinv_flat,
                          rhs_flat, trans=trans)
    return x.reshape(rhs.shape)


@functools.partial(jax.jit, static_argnames=("trans", "reverse", "clamp",
                                              "use_pallas"))
def fused_chain_solve(fact: BatchedLU, base: jnp.ndarray, mult: jnp.ndarray,
                      *, trans: int = 0, reverse: bool = False,
                      clamp: bool = False,
                      use_pallas: Optional[bool] = None) -> jnp.ndarray:
    """Fused sequential solve along the stage axis of a factor stack.

    fact with leading dims (..., K), base/mult (..., K, V) -> x (..., K, V)
    where, walking k forward (or backward with ``reverse=True``),

        x_k = A_k^{-1(T)} (base_k + mult_k * x_prev),   x_prev(start) = 0,

    optionally clamped at 0 (``clamp=True`` — the marginal recursion's
    nonnegativity).  This is the chain-scan substitution of BOTH GP sweeps
    (traffic: trans=1 forward, marginals: trans=0 reverse) issued as ONE
    call consuming the whole (K, V, V) factor stack: per-stage fixed costs
    (padding, transposes, permutation sorts, dispatch) are paid once per GP
    step instead of once per stage (DESIGN.md §13).

    The Pallas path runs each member's chain inside one kernel invocation
    (factor stack VMEM-resident) and assumes the identity row permutation
    of the unpivoted Pallas factors; LAPACK-pivoted reference factors are
    handled by the reference path.
    """
    lu_flat, lead = _flatten_batch(fact.lu, 3)         # (Bf, K, V, V)
    base_flat, _ = _flatten_batch(base, 2)
    mult_flat, _ = _flatten_batch(mult, 2)
    if _use_pallas(use_pallas):
        x = _bs.chain_solve(lu_flat, base_flat, mult_flat, trans=trans,
                            reverse=reverse, clamp=clamp, interpret=INTERPRET)
    else:
        perm_flat, _ = _flatten_batch(fact.perm, 2)
        linv_flat, _ = _flatten_batch(fact.linv, 4)
        uinv_flat, _ = _flatten_batch(fact.uinv, 4)
        x = jax.vmap(
            functools.partial(_bs.ref_chain_solve, trans=trans,
                              reverse=reverse, clamp=clamp)
        )(lu_flat, perm_flat, linv_flat, uinv_flat, base_flat, mult_flat)
    return x.reshape(base.shape)


@functools.partial(jax.jit, static_argnames=("trans", "use_pallas"))
def batched_solve(mats: jnp.ndarray, rhs: jnp.ndarray, *, trans: int = 0,
                  use_pallas: Optional[bool] = None
                  ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One-shot factor + solve with per-member residual flags.

    Returns (x (..., V), resid (...,)) where resid is the relative
    residual ``|A x - b|_inf / (|b|_inf + 1)`` (inf for non-finite
    members).  A singular member flags itself without poisoning the rest
    of the batch — the contract the GP loop's loopy-candidate rejection
    relies on (DESIGN.md §2, §12).
    """
    fact = batched_factor(mats, use_pallas=use_pallas)
    x = batched_solve_factored(fact, rhs, trans=trans, use_pallas=use_pallas)
    mats_flat, lead = _flatten_batch(mats, 2)
    x_flat, _ = _flatten_batch(x, 1)
    rhs_flat, _ = _flatten_batch(rhs, 1)
    resid = _bs.residuals(mats_flat, x_flat, rhs_flat, trans=trans)
    return x, resid.reshape(lead)


# ---------------------------------------------------------------------------
# Sparse stage solves on padded neighbor lists (kernels/sparse_solve.py, §18)
# ---------------------------------------------------------------------------

class SparseTopo(NamedTuple):
    """The sparse-topology arrays of an Instance, as one hashable-shape
    bundle the sparse kernels consume (``network.with_sparse`` attaches the
    fields; ``sparse_topo`` extracts them).

    out_nbr/out_mask, in_nbr/in_mask: (V, D) padded neighbor lists
    blk_nbr/blk_mask: (NB, BD) block-level neighbor lists (BSR structure)
    """

    out_nbr: jnp.ndarray
    out_mask: jnp.ndarray
    in_nbr: jnp.ndarray
    in_mask: jnp.ndarray
    blk_nbr: jnp.ndarray
    blk_mask: jnp.ndarray


def sparse_topo(inst) -> SparseTopo:
    """Extract the SparseTopo bundle of an instance (raises if absent)."""
    if inst.out_nbr is None:
        raise ValueError(
            "instance carries no sparse topology; attach one with "
            "network.with_sparse(inst) before solver='sparse'")
    return SparseTopo(out_nbr=inst.out_nbr, out_mask=inst.out_mask,
                      in_nbr=inst.in_nbr, in_mask=inst.in_mask,
                      blk_nbr=inst.blk_nbr, blk_mask=inst.blk_mask)


@functools.partial(jax.jit, static_argnames=("trans", "reverse", "clamp",
                                              "use_pallas"))
def sparse_chain_solve(topo: SparseTopo, phi_e: jnp.ndarray,
                       base: jnp.ndarray, mult: jnp.ndarray, *,
                       trans: int = 0, reverse: bool = False,
                       clamp: bool = False,
                       use_pallas: Optional[bool] = None) -> jnp.ndarray:
    """Sparse drop-in for ``fused_chain_solve``: solve the whole stage chain

        x_k = (I - M_k)^{-1} (base_k + mult_k * x_prev),
        M_k = Phi_k (trans=0) or Phi_k^T (trans=1),

    by O(E)-per-sweep fixed-point iteration on the padded neighbor lists —
    exact for loop-free (nilpotent) strategies, divergent (and rejected by
    ``traffic_is_valid``) for loopy candidates, mirroring the dense
    contract (kernels/sparse_solve.py).

    phi_e (..., K, V, V), base/mult (..., K, V) -> x (..., K, V).  No
    factorization object: the topology bundle replaces ``BatchedLU``.  The
    Pallas path (TPU default, interpret on request) runs the
    partition-blocked BSR kernel over the nonzero blocks only; the jnp path
    gathers per-edge values.  Collective-free and per-member, like every
    wrapper here (shard_map safe).
    """
    phi_flat, lead = _flatten_batch(phi_e, 3)          # (Bf, K, V, V)
    base_flat, _ = _flatten_batch(base, 2)
    mult_flat, _ = _flatten_batch(mult, 2)
    if _use_pallas(use_pallas):
        M = phi_flat if trans == 0 else jnp.swapaxes(phi_flat, -1, -2)
        bvals = _ss.block_values(M, topo.blk_nbr, topo.blk_mask,
                                 _ss.SPARSE_BLOCK)
        x = _ss.chain_solve_bsr(bvals, topo.blk_nbr, base_flat, mult_flat,
                                reverse=reverse, clamp=clamp,
                                interpret=INTERPRET)
    else:
        nbr, mask = ((topo.out_nbr, topo.out_mask) if trans == 0
                     else (topo.in_nbr, topo.in_mask))
        vals = _ss.neighbor_values(phi_flat, nbr, mask, trans=trans)
        x = _ss.chain_solve_nbr(vals, nbr, base_flat, mult_flat,
                                reverse=reverse, clamp=clamp)
    return x.reshape(base.shape)


@functools.partial(jax.jit, static_argnames=("with_rounds",))
def blocked_tagged_nbr(route: jnp.ndarray, improper: jnp.ndarray,
                       nbr: jnp.ndarray, mask: jnp.ndarray, *,
                       with_rounds: bool = False):
    """Neighbor-list variant of ``blocked_tagged``: O(E) per round.

    route/improper (..., V, V) bool, nbr/mask (V, D) -> tagged (..., V)
    bool, bit-equal to ``blocked_tagged`` and the dense scan (the fixed
    point is the same monotone map; see kernels/sparse_solve.py).
    ``with_rounds=True`` also returns the sweep's round counter (§19
    telemetry — the counter already exists in the while-loop).
    """
    flat, lead = _flatten_batch(route, 2)
    V = flat.shape[-1]
    idx = jnp.broadcast_to(nbr, flat.shape[:-1] + nbr.shape[-1:])
    rv = jnp.take_along_axis(flat, idx, axis=-1) & mask
    iv = jnp.take_along_axis(improper.reshape(flat.shape), idx, axis=-1)
    if with_rounds:
        tagged, rounds = _ss.tagged_nbr(rv, iv, nbr, with_rounds=True)
        return tagged.reshape(lead + (V,)), rounds
    tagged = _ss.tagged_nbr(rv, iv, nbr)
    return tagged.reshape(lead + (V,))


# ---------------------------------------------------------------------------
# Bit-packed blocked-set propagation (kernels/blocked_sets.py — DESIGN.md §13)
# ---------------------------------------------------------------------------

# The Pallas tagged kernel keeps packed successor words on the lane axis
# (W = ceil(V/32) lanes), which only fills real-TPU lanes at V >= 4096; below
# that the packed-jnp path wins even on TPU, so the Pallas path engages by
# default only for very large graphs (interpret mode on request, for tests).
_BITSET_PALLAS_MIN_V = 4096


@functools.partial(jax.jit, static_argnames=("use_pallas", "with_rounds"))
def blocked_tagged(route: jnp.ndarray, improper: jnp.ndarray, *,
                   use_pallas: Optional[bool] = None,
                   with_rounds: bool = False):
    """Category-3 "tagged node" flags of the blocked sets B_i(a,k).

    route, improper (..., V, V) bool -> tagged (..., V) bool: node p is
    tagged iff its routing subtree contains an improper link, i.e. the
    monotone fixed point of

        tagged[p] = exists q: route[p, q] and (improper[p, q] or tagged[q]).

    Both matrices are bit-packed into uint32 lanes once and the fixed point
    is reached by word-wise OR-AND rounds with a while-loop frontier early
    exit at the routing-DAG diameter — exactly equal to the seed's dense
    V-round sweep, at ~1/32 the traffic and ~diameter/V the rounds
    (kernels/blocked_sets.py).

    ``with_rounds=True`` additionally returns the sweep's round counter
    (§19 telemetry).  The Pallas path runs its loop in-kernel and does not
    expose the counter — it reports -1 (not measured).
    """
    flat, lead = _flatten_batch(route, 2)
    V = flat.shape[-1]
    Vp, _ = _bset.padded_nodes(V)
    imp_flat = improper.reshape(flat.shape)
    row_pad = ((0, 0), (0, Vp - V), (0, 0))
    route_bits = jnp.pad(_bset.pack_bits(flat), row_pad)
    imp_bits = jnp.pad(_bset.pack_bits(imp_flat), row_pad)
    pallas = (_PALLAS_DEFAULT and V >= _BITSET_PALLAS_MIN_V
              if use_pallas is None else use_pallas)
    rounds = jnp.int32(-1)
    if pallas:
        tagged = _bset.tagged_pallas(route_bits, imp_bits, V,
                                     interpret=INTERPRET)
    elif with_rounds:
        tagged, rounds = _bset.tagged_packed(route_bits, imp_bits, V,
                                             with_rounds=True)
    else:
        tagged = _bset.tagged_packed(route_bits, imp_bits, V)
    tagged = tagged.reshape(lead + (V,))
    if with_rounds:
        return tagged, rounds
    return tagged
