"""jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute with interpret=True; on a real
TPU the same call sites compile to Mosaic.  ``INTERPRET`` flips automatically
from the backend.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import chain_propagate as _cp
from repro.kernels import flash_attention as _fa
from repro.kernels import ssd_chunk as _sc

INTERPRET = jax.default_backend() == "cpu"


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention(q, k, v, *, causal=True, window=None):
    """(B,S,H,hd) layout public API (matches models.attention.sdpa)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    qt, S = _pad_to(qt, 2, _fa.DEFAULT_BQ)
    kt, _ = _pad_to(kt, 2, _fa.DEFAULT_BK)
    vt, _ = _pad_to(vt, 2, _fa.DEFAULT_BK)
    out = _fa.flash_attention_fwd(qt, kt, vt, causal=causal, window=window,
                                  interpret=INTERPRET)
    return out[:, :, :S].transpose(0, 2, 1, 3)


@jax.jit
def propagate_step(t, M, src):
    return _cp.propagate_step(t, M, src, interpret=INTERPRET)


@functools.partial(jax.jit, static_argnames=("sweeps",))
def solve_fixed_point(M, src, *, sweeps: int):
    return _cp.solve_fixed_point(M, src, sweeps=sweeps, interpret=INTERPRET)


@jax.jit
def ssd_chunk(xh, dt, dtA, cum, BH, CH):
    """Adapter matching models.ssm.ssd_chunked's kernel call signature."""
    return _sc.ssd_chunk_fwd(xh, dt, cum, BH, CH, interpret=INTERPRET)
