"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention(q, k, v, *, causal=True, window=None):
    """q: (B,H,S,hd), k/v: (B,KV,S,hd) -> (B,H,S,hd)."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    rep = H // KV
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    scale = hd ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= kp > qp - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def propagate_step(t, M, src):
    """out[s] = t[s] @ M[s] + src[s]."""
    return jnp.einsum("sv,svw->sw", t.astype(jnp.float32),
                      M.astype(jnp.float32)) + src.astype(jnp.float32)


def solve_fixed_point(M, src, *, sweeps: int):
    t = jnp.zeros_like(src, dtype=jnp.float32)
    for _ in range(sweeps):
        t = propagate_step(t, M, src)
    return t


def ssd_chunk(xh, dt, cum, BH, CH):
    """Intra-chunk SSD core; shapes as kernels.ssd_chunk.ssd_chunk_fwd."""
    f32 = jnp.float32
    xh, dt, cum, BH, CH = (a.astype(f32) for a in (xh, dt, cum, BH, CH))
    Q = xh.shape[2]
    scores = jnp.einsum("bcqhn,bckhn->bchqk", CH, BH)
    decay = jnp.exp(cum.transpose(0, 1, 3, 2)[:, :, :, :, None]
                    - cum.transpose(0, 1, 3, 2)[:, :, :, None, :])
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    w = jnp.where(tri[None, None, None], scores * decay, 0.0)
    w = w * dt.transpose(0, 1, 3, 2)[:, :, :, None, :]
    y = jnp.einsum("bchqk,bckhp->bcqhp", w, xh)
    total = cum[:, :, -1, :]
    sdec = jnp.exp(total[:, :, None, :] - cum) * dt
    state = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn", sdec, BH, xh)
    return y, state
