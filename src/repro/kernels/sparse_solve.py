"""Sparse stage-system solves on padded neighbor lists (DESIGN.md §18).

The GP stage systems are ``(I - Phi_k^T) t = b`` (traffic, trans=1) and
``(I - Phi_k) pdt = b`` (marginals, trans=0).  For loop-free strategies
``Phi_k`` restricted to its support is *nilpotent* — routing follows a DAG —
so the Neumann series terminates and the fixed-point sweep

    x <- b + M x,        M = Phi_k (trans=0) or Phi_k^T (trans=1)

converges EXACTLY after (DAG depth + 1) sweeps: once every dependency of a
node has settled, recomputing its value is bit-deterministic, so the
``x != prev`` early exit stops precisely at the fixed point (the same
argument as the bitset sweep's monotone early exit, DESIGN.md §13).  Loopy
candidate strategies make the sweep diverge — values blow past the
``traffic_is_valid`` bound (or are frozen at +inf by the divergence latch)
and the candidate is rejected, exactly like the dense path's singular-solve
contract.

Each sweep costs O(E) instead of the dense path's O(V^2) substitution (and
no O(V^3) factorization at all), which is what makes metro-scale graphs
(V >= several hundred at O(V) edges) viable.

Two executable paths, dispatched by ``kernels.ops.sparse_chain_solve``:

  * :func:`chain_solve_nbr`  — gather/scatter-free jnp sweeps on the padded
    neighbor lists (``x[..., nbr]`` is one gather per sweep); CPU/GPU path.
  * :func:`chain_solve_bsr`  — the partition-blocked Pallas kernel: the
    stage matrices are gathered into BSR-style ``(NB, BD, bs, bs)`` blocks
    (``network.block_neighbors``) and the kernel iterates ONLY the nonzero
    blocks — ``NB * BD`` dense ``bs x bs`` matmuls per sweep, MXU-shaped on
    TPU (Mosaic; interpret mode for tests).

Both compute the same linear map, so they agree to float tolerance; parity
with the dense LU path on loop-free strategies is exact up to roundoff
(tests/test_sparse.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Edge length of the partition blocks (``network.block_neighbors`` re-exports
# this as ``network.SPARSE_BLOCK``): 32 matches both the bitset word width
# and the TPU sublane tile.
SPARSE_BLOCK = 32

# Iterates beyond this magnitude are frozen at +inf: the lane has provably
# diverged (every physical traffic/marginal is orders of magnitude smaller),
# and freezing makes the while-loop exit instead of chasing a runaway
# geometric series to the sweep cap.
_DIVERGE = 1e12


def neighbor_values(phi_e: jnp.ndarray, nbr: jnp.ndarray, mask: jnp.ndarray,
                    *, trans: int) -> jnp.ndarray:
    """Gather the sparse matrix entries aligned to the padded neighbor lists.

    phi_e (..., V, V), nbr/mask (V, D) -> vals (..., V, D) with

        trans=0:  vals[..., i, d] = phi_e[..., i, out_nbr[i, d]]
        trans=1:  vals[..., j, d] = phi_e[..., in_nbr[j, d], j]

    i.e. row p of ``vals`` holds the nonzero entries of row p of ``M``
    (``M = Phi`` or ``Phi^T``), so the sweep ``b + sum_d vals * x[nbr]`` is
    the sparse matvec ``b + M x``.  Masked columns are zeroed.
    """
    M = phi_e if trans == 0 else jnp.swapaxes(phi_e, -1, -2)
    idx = jnp.broadcast_to(nbr, M.shape[:-1] + nbr.shape[-1:])
    vals = jnp.take_along_axis(M, idx, axis=-1)
    return jnp.where(mask, vals, 0.0)


def _fixed_point(vals: jnp.ndarray, nbr: jnp.ndarray, b: jnp.ndarray,
                 cap: int) -> jnp.ndarray:
    """Solve x = b + M x by sweeps with an exact-settle early exit.

    vals (..., V, D), nbr (V, D), b (..., V) -> x (..., V).  The loop exits
    when no entry changed (exact for nilpotent M, see module docstring) or
    after ``cap`` sweeps; diverging entries latch at +inf.
    """
    def sweep(x):
        y = b + jnp.sum(vals * x[..., nbr], axis=-1)
        bad = ~jnp.isfinite(y) | (jnp.abs(y) > _DIVERGE)
        return jnp.where(bad, jnp.inf, y)

    def cond(carry):
        x, prev, i = carry
        return jnp.any(x != prev) & (i < cap)

    def body(carry):
        x, _, i = carry
        return sweep(x), x, i + 1

    x0 = sweep(jnp.zeros_like(b))
    prev0 = jnp.full_like(b, jnp.inf)
    x, _, _ = jax.lax.while_loop(cond, body, (x0, prev0, jnp.int32(1)))
    return x


def chain_solve_nbr(vals: jnp.ndarray, nbr: jnp.ndarray,
                    base: jnp.ndarray, mult: jnp.ndarray, *,
                    reverse: bool = False, clamp: bool = False) -> jnp.ndarray:
    """Fused chain of sparse stage solves (the neighbor-list jnp path).

    vals (B, K, V, D) row-aligned stage matrices (``neighbor_values``),
    nbr (V, D), base/mult (B, K, V) -> x (B, K, V) where, walking k forward
    (or backward with ``reverse=True``),

        x_k = (I - M_k)^{-1} (base_k + mult_k * x_prev),  x_prev(start) = 0,

    optionally clamped at 0 after each stage — exactly the
    ``ops.fused_chain_solve`` contract, with the dense triangular
    substitutions replaced by O(E) fixed-point sweeps.
    """
    V = base.shape[-1]
    cap = V + 2
    # scan over the stage axis: move K in front of the member axis
    vals_t = jnp.moveaxis(vals, 1, 0)      # (K, B, V, D)
    base_t = jnp.moveaxis(base, 1, 0)      # (K, B, V)
    mult_t = jnp.moveaxis(mult, 1, 0)

    def step(x_prev, xs):
        vals_k, base_k, mult_k = xs
        x = _fixed_point(vals_k, nbr, base_k + mult_k * x_prev, cap)
        if clamp:
            x = jnp.maximum(x, 0.0)
        return x, x

    _, xs = jax.lax.scan(step, jnp.zeros_like(base_t[0]),
                         (vals_t, base_t, mult_t), reverse=reverse)
    return jnp.moveaxis(xs, 0, 1)


# ---------------------------------------------------------------------------
# Partition-blocked (BSR) Pallas kernel
# ---------------------------------------------------------------------------

def block_values(M: jnp.ndarray, blk_nbr: jnp.ndarray, blk_mask: jnp.ndarray,
                 block: int) -> jnp.ndarray:
    """Gather the nonzero ``block x block`` blocks of a stage matrix stack.

    M (..., V, V), blk_nbr/blk_mask (NB, BD) -> bvals (..., NB, BD, bs, bs)
    with ``bvals[..., I, d] = M[rows of I, cols of blk_nbr[I, d]]`` (zero
    where masked).  V is zero-padded to NB*bs — exact for the fixed-point
    form, which needs no diagonal.
    """
    NB, BD = blk_nbr.shape
    Vp = NB * block
    V = M.shape[-1]
    if Vp != V:
        widths = [(0, 0)] * (M.ndim - 2) + [(0, Vp - V), (0, Vp - V)]
        M = jnp.pad(M, widths)
    Mb = M.reshape(M.shape[:-2] + (NB, block, NB, block))
    Mb = jnp.swapaxes(Mb, -3, -2)                        # (..., NB, NB, bs, bs)
    idx = jnp.broadcast_to(blk_nbr[:, :, None, None],
                           Mb.shape[:-3] + (BD, block, block))
    bvals = jnp.take_along_axis(Mb, idx, axis=-3)        # (..., NB, BD, bs, bs)
    return jnp.where(blk_mask[:, :, None, None], bvals, 0.0)


def _bsr_chain_kernel(nbr_ref, bvals_ref, base_ref, mult_ref, out_ref, *,
                      reverse: bool, clamp: bool, cap: int):
    """One flattened member per grid step; stage chain unrolled in-kernel.

    bvals (1, K, NB, BD, bs, bs), base/mult/out (1, K, Vp), nbr (NB, BD).
    Each sweep touches only the NB*BD nonzero blocks — BD dense (bs, bs)
    matmuls per block row, accumulated into the row block of the new
    iterate.
    """
    K, NB, BD, bs = bvals_ref.shape[1:5]
    Vp = NB * bs

    def solve_stage(k: int, b):
        bvals_k = bvals_ref[0, k]                        # (NB, BD, bs, bs)

        def sweep(x):
            rows = []
            for I in range(NB):
                acc = jax.lax.dynamic_slice(b, (I * bs,), (bs,))
                for d in range(BD):
                    J = nbr_ref[I, d]
                    xj = jax.lax.dynamic_slice(x, (J * bs,), (bs,))
                    acc = acc + bvals_k[I, d] @ xj
                rows.append(acc)
            y = jnp.concatenate(rows)
            bad = ~jnp.isfinite(y) | (jnp.abs(y) > _DIVERGE)
            return jnp.where(bad, jnp.inf, y)

        def cond(carry):
            x, prev, i = carry
            return jnp.any(x != prev) & (i < cap)

        def body(carry):
            x, _, i = carry
            return sweep(x), x, i + 1

        x0 = sweep(jnp.zeros((Vp,), b.dtype))
        prev0 = jnp.full((Vp,), jnp.inf, b.dtype)
        x, _, _ = jax.lax.while_loop(cond, body, (x0, prev0, jnp.int32(1)))
        return x

    ks = range(K - 1, -1, -1) if reverse else range(K)
    x_prev = jnp.zeros((Vp,), base_ref.dtype)
    for k in ks:
        b = base_ref[0, k] + mult_ref[0, k] * x_prev
        x = solve_stage(k, b)
        if clamp:
            x = jnp.maximum(x, 0.0)
        out_ref[0, k, :] = x
        x_prev = x


def chain_solve_bsr(bvals: jnp.ndarray, blk_nbr: jnp.ndarray,
                    base: jnp.ndarray, mult: jnp.ndarray, *,
                    reverse: bool = False, clamp: bool = False,
                    interpret: bool = False) -> jnp.ndarray:
    """Blocked-sparse fused chain solve (the Pallas path).

    bvals (B, K, NB, BD, bs, bs) from :func:`block_values`, blk_nbr (NB, BD),
    base/mult (B, K, V) -> x (B, K, V); same semantics as
    :func:`chain_solve_nbr`.
    """
    B, K, NB, BD, bs = bvals.shape[:5]
    Vp = NB * bs
    V = base.shape[-1]
    if Vp != V:
        widths = ((0, 0), (0, 0), (0, Vp - V))
        base = jnp.pad(base, widths)
        mult = jnp.pad(mult, widths)
    kernel = functools.partial(_bsr_chain_kernel, reverse=reverse,
                               clamp=clamp, cap=V + 2)
    out = pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((NB, BD), lambda b: (0, 0)),
            pl.BlockSpec((1, K, NB, BD, bs, bs), lambda b: (b, 0, 0, 0, 0, 0)),
            pl.BlockSpec((1, K, Vp), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, K, Vp), lambda b: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, K, Vp), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, Vp), base.dtype),
        interpret=interpret,
    )(blk_nbr, bvals, base, mult)
    return out[..., :V]


# ---------------------------------------------------------------------------
# Neighbor-list blocked-set ("tagged node") sweep
# ---------------------------------------------------------------------------

def tagged_nbr(route_vals: jnp.ndarray, improper_vals: jnp.ndarray,
               nbr: jnp.ndarray, *, with_rounds: bool = False):
    """Category-3 tagged flags by O(E)-per-round sweeps on neighbor lists.

    route_vals/improper_vals (..., V, D) bool — ``route``/``improper``
    gathered onto the padded out-neighbor lists (masked columns False),
    nbr (V, D) -> tagged (..., V) bool: the monotone fixed point of

        tagged[p] = exists d: route[p, d] and (improper[p, d] or
                                               tagged[nbr[p, d]])

    The map is monotone (tagged only grows), so the ``!=`` early exit is
    exact: the result is bit-equal to the dense V-round scan and the bitset
    sweep, at O(E) per round instead of O(V^2)(/32) (DESIGN.md §18).

    ``with_rounds=True`` additionally returns the sweep's existing round
    counter (rounds until the fixed point settled — telemetry, §19);
    propagation arithmetic is unchanged.
    """
    V = route_vals.shape[-2]
    seed = jnp.any(route_vals & improper_vals, axis=-1)       # (..., V)

    def cond(carry):
        t, prev, i = carry
        return jnp.any(t != prev) & (i < V + 1)

    def body(carry):
        t, _, i = carry
        hit = seed | jnp.any(route_vals & t[..., nbr], axis=-1)
        return hit, t, i + 1

    prev0 = jnp.zeros_like(seed)
    t, _, rounds = jax.lax.while_loop(
        cond, body, (seed, prev0, jnp.int32(1)))
    if with_rounds:
        return t, rounds
    return t
