"""Pallas TPU kernel for the Mamba-2 SSD intra-chunk core.

Computes, for one (batch, chunk, head) grid cell with Q tokens resident in
VMEM:

    scores[i,j] = (C_i . B_j) * exp(cum_i - cum_j) * dt_j        (j <= i)
    y_intra     = scores @ X                                      (Q, P)
    state_c     = sum_j exp(total - cum_j) * dt_j * B_j (x) X_j   (N, P)

This is the matmul-rich part of SSD that maps onto the MXU ((Q,N)x(N,Q) and
(Q,Q)x(Q,P) products); the cross-chunk recurrence stays a lax.scan in
``repro.models.ssm``.  Q defaults to 128 (lane-aligned); P, N are padded by
the wrapper.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, dt_ref, cum_ref, b_ref, c_ref, y_ref, st_ref):
    x = x_ref[0, 0, :, 0].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)      # (Q, 1) -> squeeze below
    cum = cum_ref[0, 0, :, 0].astype(jnp.float32)    # (Q, 1)
    B = b_ref[0, 0, :, 0].astype(jnp.float32)        # (Q, N)
    C = c_ref[0, 0, :, 0].astype(jnp.float32)        # (Q, N)
    Q = x.shape[0]

    dt1 = dt[:, 0]
    cum1 = cum[:, 0]
    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())))     # (Q, Q)
    decay = jnp.exp(cum1[:, None] - cum1[None, :])
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    w = jnp.where(jj <= ii, scores * decay * dt1[None, :], 0.0)
    y_ref[0, 0, :, 0] = jax.lax.dot(w, x).astype(y_ref.dtype)        # (Q, P)

    total = cum1[-1]
    sdec = jnp.exp(total - cum1) * dt1                               # (Q,)
    st = jax.lax.dot_general(B * sdec[:, None], x, (((0,), (0,)), ((), ())))
    st_ref[0, 0, 0] = st.astype(st_ref.dtype)                        # (N, P)


def ssd_chunk_fwd(xh, dt, cum, BH, CH, *, interpret=False):
    """xh: (B,nc,Q,H,P), dt/cum: (B,nc,Q,H), BH/CH: (B,nc,Q,H,N).

    Returns (y_intra (B,nc,Q,H,P), state_c (B,nc,H,P,N)) — same contract as
    the jnp path in ``repro.models.ssm.ssd_chunked``.
    """
    Bsz, nc, Q, H, P = xh.shape
    N = BH.shape[-1]
    dt4 = dt[..., None]                               # (B,nc,Q,H,1)
    cum4 = cum[..., None]

    grid = (Bsz * nc, H)
    xr = xh.reshape(Bsz * nc, Q, H, P)
    dtr = dt4.reshape(Bsz * nc, Q, H, 1)
    cumr = cum4.reshape(Bsz * nc, Q, H, 1)
    br = BH.reshape(Bsz * nc, Q, H, N)
    cr = CH.reshape(Bsz * nc, Q, H, N)

    y, st = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, 1, P), lambda g, h: (g, 0, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, 1, 1), lambda g, h: (g, 0, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, 1, 1), lambda g, h: (g, 0, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, 1, N), lambda g, h: (g, 0, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, 1, N), lambda g, h: (g, 0, 0, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, 1, P), lambda g, h: (g, 0, 0, h, 0)),
            pl.BlockSpec((1, 1, 1, N, P), lambda g, h: (g, 0, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz * nc, 1, Q, H, P), jnp.float32),
            jax.ShapeDtypeStruct((Bsz * nc, 1, H, N, P), jnp.float32),
        ],
        interpret=interpret,
    )(xr[:, None], dtr[:, None], cumr[:, None], br[:, None], cr[:, None])

    y = y[:, 0].reshape(Bsz, nc, Q, H, P)
    st = st[:, 0].reshape(Bsz, nc, H, N, P).transpose(0, 1, 2, 4, 3)  # -> (...,P,N)
    return y, st
