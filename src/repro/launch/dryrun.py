import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Tests may shrink the fake-device count via
# REPRO_DRYRUN_DEVICES — still before jax import.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DRYRUN_DEVICES"]
    )
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import gc                # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import configs                                   # noqa: E402
from repro.launch import shardings as shr                   # noqa: E402
from repro.launch.mesh import (                             # noqa: E402
    make_mini_mesh, make_pod_mesh, make_production_mesh,
)
from repro.launch.specs import INPUT_SHAPES, input_specs    # noqa: E402
from repro.models.transformer import Model                  # noqa: E402
from repro.serve.engine import make_prefill_step, make_serve_step  # noqa: E402
from repro.train import trainer                             # noqa: E402

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) combination with ShapeDtypeStruct inputs (no allocation), and record
memory_analysis / cost_analysis / per-collective byte counts for the
roofline (EXPERIMENTS.md §Dry-run, §Roofline).
"""

_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_TYPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by every collective op, by op kind.

    XLA's HLO text does not always annotate operand types inline, so we
    parse the RESULT type(s) on the LHS of each collective instruction:
      * all-reduce / all-to-all / collective-permute: operand size ==
        result size.
      * all-gather: the result is the gathered (full) tensor — an upper
        bound on per-device wire bytes (ring moves (n-1)/n of it).
      * reduce-scatter: the result is 1/n of the reduced operand; scale by
        the replica-group size to recover operand bytes.
    """
    out: dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "=" not in line:
            continue
        kind = m.group(1)
        eq = line.find("=")
        if eq < 0 or eq > m.start():
            continue
        lhs = line[eq + 1:m.start()]
        total = 0.0
        for dt, dims in _TYPE_RE.findall(lhs):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES[dt]
        if kind == "reduce-scatter":
            g = _GROUPS_RE.search(line)
            if g:
                total *= int(g.group(2))
        if total:
            out[kind] = out.get(kind, 0.0) + total
    return out


def plan(arch: str, shape: str):
    """Returns (cfg, mode, note) or (None, None, skip_reason)."""
    cfg = configs.get(arch)
    seq, batch, kind = INPUT_SHAPES[shape]
    if cfg.encoder_only and kind == "decode":
        return None, None, "encoder-only: no decode step (DESIGN.md §6)"
    note = ""
    if shape == "long_500k":
        attention_free = cfg.is_attention_free or cfg.arch_type == "hybrid"
        has_window = cfg.window is not None
        if not attention_free and not has_window and cfg.attn_kind != "mla":
            if cfg.serve_window is None:
                return None, None, "pure full attention at 500k context"
            cfg = dataclasses.replace(cfg, window=cfg.serve_window)
            note = f"SWA serving variant W={cfg.serve_window} (DESIGN.md §6)"
    return cfg, kind, note


def _lower_one(cfg, mode, mesh, batch, seq, moment_dtype, unroll=False,
               opts=()):
    """opts: iterable of optimization-variant names (§Perf):
      blockwise — online-softmax attention (no S^2 temps)
      zero1     — shard optimizer moments over the data axis too
      f32moms   — float32 moments (cost of exactness, for comparison)
    """
    attn_impl = "blockwise" if "blockwise" in opts else "naive"
    expert_axis = "model" if "moeshard" in opts else None
    if expert_axis and cfg.moe and cfg.moe.n_experts % mesh.shape["model"]:
        expert_axis = None            # experts not divisible on this mesh
    ep_mesh = None
    if "epmoe" in opts and cfg.moe and cfg.moe.n_experts % mesh.shape["model"] == 0:
        ep_mesh = mesh
    mk = dict(dtype=jnp.bfloat16, unroll=unroll, attn_impl=attn_impl,
              expert_axis=expert_axis,
              remat_policy="mixer" if "rematmixer" in opts else None,
              ep_mesh=ep_mesh)
    if "f32moms" in opts:
        moment_dtype = jnp.float32
    t0 = time.time()
    with jax.default_device(jax.devices("cpu")[0]):
        if mode == "train":
            model = Model(cfg, remat=True, **mk)
            state_struct = jax.eval_shape(
                lambda k: trainer.init_state(model, k, moment_dtype=moment_dtype),
                jax.random.PRNGKey(0))
            batch_struct = input_specs(cfg, batch, seq, mode="train")
            pspecs = shr.param_specs(mesh, state_struct.params)
            mspecs = pspecs
            if "zero1" in opts:
                mspecs = shr.zero1_specs(mesh, pspecs, state_struct.params)
            state_specs = trainer.TrainState(
                params=pspecs,
                opt=type(state_struct.opt)(
                    step=jax.sharding.PartitionSpec(),
                    mu=mspecs, nu=mspecs),
            )
            bspecs = shr.batch_specs(mesh, batch_struct, batch)
            step = trainer.make_train_step(model)
            jitted = jax.jit(
                step,
                in_shardings=(shr.shardings_of(mesh, state_specs),
                              shr.shardings_of(mesh, bspecs)),
            )
            with mesh:
                lowered = jitted.lower(state_struct, batch_struct)
        elif mode == "prefill":
            model = Model(cfg, **mk)
            params_struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            cache_struct = jax.eval_shape(
                lambda: model.init_cache(batch, seq, dtype=jnp.bfloat16))
            batch_struct = input_specs(cfg, batch, seq, mode="prefill")
            pspecs = shr.param_specs(mesh, params_struct)
            cspecs = shr.cache_specs(mesh, cache_struct, batch)
            bspecs = shr.batch_specs(mesh, batch_struct, batch)
            step = make_prefill_step(model)
            jitted = jax.jit(
                step,
                in_shardings=(shr.shardings_of(mesh, pspecs),
                              shr.shardings_of(mesh, cspecs),
                              shr.shardings_of(mesh, bspecs)),
            )
            with mesh:
                lowered = jitted.lower(params_struct, cache_struct, batch_struct)
        else:  # decode
            model = Model(cfg, **mk)
            params_struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            cache_struct = jax.eval_shape(
                lambda: model.init_cache(batch, seq, dtype=jnp.bfloat16))
            batch_struct = input_specs(cfg, batch, seq, mode="decode")
            pspecs = shr.param_specs(mesh, params_struct)
            cspecs = shr.cache_specs(mesh, cache_struct, batch)
            bspecs = shr.batch_specs(mesh, batch_struct, batch)
            step = make_serve_step(model)
            jitted = jax.jit(
                step,
                in_shardings=(shr.shardings_of(mesh, pspecs),
                              shr.shardings_of(mesh, cspecs),
                              shr.shardings_of(mesh, bspecs)["tokens"],
                              None),
                # §Perf 'donate': alias the KV cache in/out so the decode
                # step updates it in place instead of copying ~the whole
                # cache every token (the dominant decode memory traffic)
                donate_argnums=(1,) if "donate" in opts else (),
            )
            with mesh:
                lowered = jitted.lower(params_struct, cache_struct,
                                       batch_struct["tokens"],
                                       jnp.int32(seq - 1))
        t_lower = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = collective_bytes(compiled.as_text())
    return {
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "cost": {k: cost.get(k) for k in
                 ("flops", "bytes accessed", "transcendentals", "optimal_seconds")
                 if k in cost},
        "collective_bytes": coll,
    }


def _probe_cfg(cfg, n_periods: int):
    """Same architecture family with the scanned body cut to n_periods."""
    from repro.models.transformer import build_stack

    stack = build_stack(cfg)
    period = len(stack.pattern)
    n_prefix = len(stack.prefix)
    return dataclasses.replace(cfg, n_layers=n_prefix + n_periods * period), stack


def lower_combo(arch: str, shape: str, mesh, *, moment_dtype=jnp.bfloat16,
                probe: bool = True, opts=()):
    """Lower+compile the full config; optionally also compile 1- and
    2-period probes to undo XLA's scan-body cost amortization (cost_analysis
    counts a scan body once regardless of trip count), extrapolating
      total = c1 + (n_periods - 1) * (c2 - c1)
    which is exact because period bodies are identical."""
    cfg, mode, note = plan(arch, shape)
    if cfg is None:
        return {"arch": arch, "shape": shape, "skipped": note}
    seq, batch, _ = INPUT_SHAPES[shape]

    rec = {
        "arch": arch, "shape": shape, "mode": mode, "note": note,
        "opts": list(opts),
        "mesh": dict(mesh.shape),
        "devices": int(jnp.prod(jnp.asarray(list(mesh.shape.values())))),
        "seq_len": seq, "global_batch": batch,
    }
    rec.update(_lower_one(cfg, mode, mesh, batch, seq, moment_dtype, opts=opts))

    if probe:
        cfg1, stack = _probe_cfg(cfg, 1)
        cfg2, _ = _probe_cfg(cfg, 2)
        r1 = _lower_one(cfg1, mode, mesh, batch, seq, moment_dtype, unroll=True, opts=opts)
        r2 = _lower_one(cfg2, mode, mesh, batch, seq, moment_dtype, unroll=True, opts=opts)
        n = stack.n_periods
        extra = {}
        for key in set(r1["cost"]) | set(r2["cost"]):
            c1, c2 = r1["cost"].get(key, 0) or 0, r2["cost"].get(key, 0) or 0
            extra[key] = c1 + (n - 1) * (c2 - c1)
        coll = {}
        for key in set(r1["collective_bytes"]) | set(r2["collective_bytes"]):
            c1 = r1["collective_bytes"].get(key, 0.0)
            c2 = r2["collective_bytes"].get(key, 0.0)
            coll[key] = c1 + (n - 1) * (c2 - c1)
        rec["cost_extrapolated"] = extra
        rec["collective_bytes_extrapolated"] = coll
        rec["probe"] = {"n_periods": n, "c1": r1["cost"], "c2": r2["cost"],
                        "coll1": r1["collective_bytes"],
                        "coll2": r2["collective_bytes"]}
    return rec


def run(args) -> int:
    os.makedirs(args.out, exist_ok=True)
    archs = configs.ARCH_NAMES if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = args.mesh.split(",")
    failures = 0
    for mesh_name in meshes:
        if mesh_name == "pod":
            mesh = make_production_mesh(multi_pod=False)
        elif mesh_name == "multipod":
            mesh = make_production_mesh(multi_pod=True)
        elif mesh_name == "mini":
            mesh = make_mini_mesh()
        elif "x" in mesh_name:                      # e.g. pod32x8
            d, m = mesh_name.replace("pod", "").split("x")
            mesh = make_pod_mesh(int(d), int(m))
        else:
            raise SystemExit(f"unknown mesh {mesh_name}")
        opts = tuple(o for o in args.opt.split(",") if o)
        for arch in archs:
            for shape in shapes:
                tag = f"{arch}__{shape}__{mesh_name}"
                if opts:
                    tag += "__opt-" + "-".join(opts)
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"[skip existing] {tag}")
                    continue
                print(f"[dryrun] {tag} ...", flush=True)
                try:
                    rec = lower_combo(arch, shape, mesh,
                                      probe=not args.no_probe, opts=opts)
                except Exception as e:  # noqa: BLE001 — record and continue
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "error": f"{type(e).__name__}: {e}"}
                    failures += 1
                    print(f"  ERROR {rec['error']}", flush=True)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                if "error" not in rec and "skipped" not in rec:
                    mem = rec["memory"]
                    arg_gb = (mem["argument_bytes"] or 0) / 1e9
                    tmp_gb = (mem["temp_bytes"] or 0) / 1e9
                    print(f"  ok lower {rec['lower_s']}s compile {rec['compile_s']}s "
                          f"args {arg_gb:.1f}GB temps {tmp_gb:.1f}GB "
                          f"flops {rec['cost'].get('flops', 0):.3g} "
                          f"coll {sum(rec['collective_bytes'].values()):.3g}B",
                          flush=True)
                elif "skipped" in rec:
                    print(f"  skipped: {rec['skipped']}", flush=True)
                gc.collect()
    return failures


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="pod",
                    help="comma list of pod|multipod|mini")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-probe", action="store_true",
                    help="skip the cost-extrapolation probes (multipod pass "
                         "only needs the compile proof)")
    ap.add_argument("--opt", default="",
                    help="comma list of §Perf variants: blockwise,zero1,"
                         "f32moms,moeshard,rematmixer,donate")
    args = ap.parse_args()
    failures = run(args)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
