"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — required because the 512-device
host-platform override must be set before jax initializes, and only
``launch/dryrun.py`` does that.
"""

from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # NOT repro.core.compat.make_mesh: importing repro.core would build
    # module-level jnp constants and initialize the backend, which this
    # module must never do (see module docstring).  Same fallback, inline.
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds the 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_pod_mesh(data: int, model: int):
    """Single-pod mesh with a custom (data, model) factorization of the 256
    chips — the §Perf 'resharding' knob (e.g. 32x8 for archs whose expert /
    kv-head counts don't divide 16)."""
    assert data * model == 256, (data, model)
    return _make_mesh((data, model), ("data", "model"))


def make_mini_mesh(data: int = 2, model: int = 4):
    """Small host mesh for CI-grade dry-run tests (8 fake devices)."""
    return _make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Axes that shard the batch: ('pod','data') when the pod axis exists."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)
