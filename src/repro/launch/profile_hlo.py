import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DRYRUN_DEVICES"])
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse      # noqa: E402
import re            # noqa: E402
from collections import defaultdict  # noqa: E402

import jax           # noqa: E402

from repro.launch import dryrun as dr   # noqa: E402
from repro.launch.specs import INPUT_SHAPES  # noqa: E402

"""HLO 'profiler' for the dry-run (no real hardware): compile a 1-period
unrolled probe of an (arch, shape) pair and rank ops by FLOPs estimated
from their output shapes — the structural profile the §Perf loop reasons
from (which dots dominate, what got replicated, what remat re-runs)."""

_DOT_RE = re.compile(
    r"%(fusion[\w.\-]*|dot[\w.\-]*|convolution[\w.\-]*) = (\w+)\[([0-9,]*)\]")
_META_RE = re.compile(r'op_name="([^"]*)"')


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--opt", default="")
    ap.add_argument("--top", type=int, default=25)
    args = ap.parse_args()

    from repro.launch.mesh import make_pod_mesh, make_production_mesh
    if args.mesh == "pod":
        mesh = make_production_mesh()
    else:
        d, m = args.mesh.replace("pod", "").split("x")
        mesh = make_pod_mesh(int(d), int(m))

    cfg, mode, note = dr.plan(args.arch, args.shape)
    cfg1, _ = dr._probe_cfg(cfg, 1)
    seq, batch, _ = INPUT_SHAPES[args.shape]
    opts = tuple(o for o in args.opt.split(",") if o)

    # reuse the dryrun lowering path but keep the compiled text
    import repro.launch.dryrun as d2
    orig = d2.collective_bytes
    captured = {}

    def capture(txt):
        captured["hlo"] = txt
        return orig(txt)

    d2.collective_bytes = capture
    rec = d2._lower_one(cfg1, mode, mesh, batch, seq,
                        moment_dtype=jax.numpy.bfloat16, unroll=True,
                        opts=opts)
    txt = captured["hlo"]

    sizes = defaultdict(float)
    for line in txt.splitlines():
        m = _DOT_RE.search(line)
        if not m:
            continue
        _, dt, dims = m.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        meta = _META_RE.search(line)
        name = meta.group(1) if meta else m.group(1)
        # compress op_name to its trailing semantic part
        name = "/".join(name.split("/")[-3:])[:110]
        sizes[name] += n

    print(f"# {args.arch} {args.shape} mesh={args.mesh} opts={opts} "
          f"(1-period probe; output-element counts of dot/fusion ops)")
    print(f"# total flops (cost_analysis): {rec['cost']['flops']:.3e}  "
          f"bytes: {rec['cost'].get('bytes accessed', 0):.3e}")
    for name, n in sorted(sizes.items(), key=lambda kv: -kv[1])[: args.top]:
        print(f"{n:16.4g}  {name}")


if __name__ == "__main__":
    main()
