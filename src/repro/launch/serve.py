"""Serving launcher: batched requests through the continuous-batching engine
(reduced config, CPU) — the inference-side end-to-end driver.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --requests 8 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models.transformer import Model
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=12)
    args = ap.parse_args()

    cfg = configs.get(args.arch, reduced=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, slots=args.slots, max_len=128)

    rng = np.random.default_rng(0)
    t0 = time.time()
    uids = [
        eng.submit(rng.integers(0, cfg.vocab, size=args.prompt_len),
                   max_new=args.max_new)
        for _ in range(args.requests)
    ]
    done = eng.run()
    dt = time.time() - t0
    toks = sum(len(v) for v in done.values())
    print(f"served {len(done)}/{len(uids)} requests, {toks} tokens "
          f"in {dt:.1f}s ({toks / dt:.1f} tok/s on CPU)")
    for uid in sorted(done):
        print(f"  req {uid}: {done[uid]}")


if __name__ == "__main__":
    main()
