"""Sharding rules: map every parameter / activation / cache tensor to a
PartitionSpec on the production mesh.

Baseline policy (the §Perf pass iterates on this):
  * batch dims        -> ("pod","data") when divisible, else ("data",), else replicated
  * heads / ffn / expert / vocab dims -> "model" when divisible, else replicated
  * KV caches         -> batch over data; heads over model (GQA), else cache
                         sequence over model (MLA's compressed cache has no
                         head dim); long-context batch=1 shards sequence
                         over data+model
  * optimizer moments mirror their parameters (ZeRO-style over 'model')

Rules key off parameter *names* in the params pytree (wq, w_gate, embed...),
so they survive the period-stacking (a leading scan axis just prepends None).
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axes


def _div(size: int, n: int) -> bool:
    return n > 0 and size % n == 0


def batch_axes(mesh: Mesh, batch: int):
    """Largest prefix of (pod, data) that divides the batch."""
    axes = data_axes(mesh)
    total = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    if _div(batch, total):
        return axes if len(axes) > 1 else axes[0] if axes else None
    if "data" in mesh.shape and _div(batch, mesh.shape["data"]):
        return "data"
    return None


def _model_if(mesh: Mesh, size: int):
    return "model" if _div(size, mesh.shape["model"]) else None


# parameter-name -> (function shape -> spec-dims)
def param_spec(mesh: Mesh, name: str, shape: tuple) -> P:
    m = lambda s: _model_if(mesh, s)
    tbl = {
        # embeddings / head
        "embed": lambda: P(m(shape[0]), None),
        "lm_head": lambda: P(None, m(shape[1])),
        # GQA attention
        "wq": lambda: P(None, m(shape[1]), None),
        "wk": lambda: P(None, m(shape[1]), None),
        "wv": lambda: P(None, m(shape[1]), None),
        "wo": lambda: P(m(shape[0]), None, None),
        # MLA
        "w_dq": lambda: P(None, m(shape[1])),
        "w_uq": lambda: P(None, m(shape[1]), None),
        "w_dkv": lambda: P(None, None),
        "w_uk": lambda: P(None, m(shape[1]), None),
        "w_uv": lambda: P(None, m(shape[1]), None),
        "w_o": lambda: P(m(shape[0]), None, None),
        # dense FFN & MoE experts
        "w_gate": lambda: _ffn_spec(mesh, shape),
        "w_up": lambda: _ffn_spec(mesh, shape),
        "w_down": lambda: _ffn_down_spec(mesh, shape),
        "router": lambda: P(None, m(shape[1])),
        "shared_gate": lambda: P(None, m(shape[1])),
        "shared_up": lambda: P(None, m(shape[1])),
        "shared_down": lambda: P(m(shape[0]), None),
        # SSM
        "w_in": lambda: P(None, m(shape[1])),
        "w_out": lambda: P(m(shape[0]), None),
        "conv_w": lambda: P(None, None),
    }
    if name in tbl and len(shape) == len(tbl[name]()):
        return tbl[name]()
    return P(*([None] * len(shape)))          # norms, biases, scalars


def _ffn_spec(mesh: Mesh, shape: tuple) -> P:
    if len(shape) == 3:                        # MoE experts (E, d, f)
        if _div(shape[0], mesh.shape["model"]):
            return P("model", None, None)      # expert-parallel
        return P(None, None, _model_if(mesh, shape[2]))
    return P(None, _model_if(mesh, shape[1]))  # dense (d, f)


def _ffn_down_spec(mesh: Mesh, shape: tuple) -> P:
    if len(shape) == 3:                        # (E, f, d)
        if _div(shape[0], mesh.shape["model"]):
            return P("model", None, None)
        return P(None, _model_if(mesh, shape[1]), None)
    return P(_model_if(mesh, shape[0]), None)  # dense (f, d)


def param_specs(mesh: Mesh, params_shape: Any) -> Any:
    """Pytree of PartitionSpecs matching a params(-shaped) pytree.

    Stacked body params (leading period axis) get a prepended None.
    """

    def leaf_spec(path, leaf) -> P:
        names = [
            p.key if hasattr(p, "key") else p.name if hasattr(p, "name") else None
            for p in path
        ]
        # NamedTuple fields appear as attribute accesses in the path via
        # their index; recover the field name from the enclosing tuple type.
        field = None
        for entry in reversed(path):
            if hasattr(entry, "name"):
                field = entry.name
                break
            if hasattr(entry, "key") and isinstance(entry.key, str):
                field = entry.key
                break
        shape = tuple(leaf.shape)
        stacked = False
        # body params carry a leading period axis: detect via path containing
        # the 'body' dict key
        for entry in path:
            if getattr(entry, "key", None) == "body":
                stacked = True
                break
        core_shape = shape[1:] if stacked and len(shape) > 1 else shape
        spec = param_spec(mesh, field or "", core_shape)
        if stacked and len(shape) > 1:
            spec = P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(leaf_spec, params_shape)


def cache_specs(mesh: Mesh, cache_shape: Any, batch: int) -> Any:
    """Specs for KV/state caches (see module docstring)."""
    baxes = batch_axes(mesh, batch)

    def leaf_spec(path, leaf) -> P:
        shape = tuple(leaf.shape)
        stacked = any(getattr(e, "key", None) == "body" for e in path)
        core = shape[1:] if stacked else shape
        dims: list = [None] * len(core)
        if len(core) >= 2:
            bdim, sdim = 0, 1
            if baxes is not None:
                dims[bdim] = baxes
            if len(core) == 4:                      # GQA (B, S, KV, hd)
                kv_model = _model_if(mesh, core[2])
                if baxes is None and _div(core[1], mesh.shape["data"] * mesh.shape["model"]):
                    dims[sdim] = ("data", "model")  # long-context batch=1
                elif kv_model:
                    dims[2] = kv_model
                elif _div(core[1], mesh.shape["model"]):
                    dims[sdim] = "model"
            elif len(core) in (3, 2) and core[1] > 4096:
                # MLA compressed cache (B, S, r) / (B, S): no head dim —
                # shard the sequence over 'model' (plus 'data' when batch=1)
                if baxes is None:
                    want = ("data", "model")
                    if _div(core[1], mesh.shape["data"] * mesh.shape["model"]):
                        dims[sdim] = want
                elif _div(core[1], mesh.shape["model"]):
                    dims[sdim] = "model"
        spec = P(*dims)
        if stacked:
            spec = P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_shape)


def zero1_specs(mesh: Mesh, pspecs: Any, shapes: Any) -> Any:
    """ZeRO-1: additionally shard optimizer moments over the data axis.

    For each moment tensor, the first dim that is unsharded and divisible
    by the data-axis size gets 'data'; a dim already sharded over 'model'
    whose shard is still divisible gets ('model', 'data').  GSPMD then
    reduce-scatters gradients into the moment update and all-gathers the
    parameter delta — the ZeRO-1 communication pattern, derived not
    hand-written.
    """
    n_data = mesh.shape["data"]

    def upgrade(spec: P, leaf) -> P:
        shape = tuple(leaf.shape)
        dims = list(spec) + [None] * (len(shape) - len(spec))
        for i, s in enumerate(shape):
            if dims[i] is None and s >= n_data and s % n_data == 0:
                dims[i] = "data"
                return P(*dims)
            if dims[i] == "model" and s % (n_data * mesh.shape["model"]) == 0:
                dims[i] = ("model", "data")
                return P(*dims)
        return P(*dims)

    return jax.tree_util.tree_map(
        upgrade, pspecs, shapes,
        is_leaf=lambda x: isinstance(x, P),
    )


def batch_specs(mesh: Mesh, batch_shape: dict, batch: int) -> dict:
    baxes = batch_axes(mesh, batch)
    out = {}
    for k, v in batch_shape.items():
        dims = [baxes] + [None] * (len(v.shape) - 1)
        out[k] = P(*dims)
    return out


def shardings_of(mesh: Mesh, specs: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )
