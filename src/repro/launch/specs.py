"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs`` is the single source of truth for what a train / prefill /
decode step consumes, per architecture and assignment shape.  The audio and
vision frontends are stubbed exactly here: their specs are precomputed
frame/patch embeddings of the right shape (DESIGN.md §5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# The four assignment input shapes: name -> (seq_len, global_batch, kind)
INPUT_SHAPES = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}

F32 = jnp.float32
BF16 = jnp.bfloat16
I32 = jnp.int32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, batch: int, seq_len: int, *,
                mode: str = "train") -> dict:
    """Input structs for one step.

    train:   full batch with targets (and mask for audio)
    prefill: prompt batch, no targets
    decode:  ONE new token per request (seq_len describes the cache, not
             the input — see launch/dryrun.py which sizes the cache)
    """
    if mode == "decode":
        assert not cfg.encoder_only, "encoder-only archs have no decode step"
        return {"tokens": _sds((batch, 1), I32)}

    if cfg.frontend == "audio":
        specs = {
            "embeds": _sds((batch, seq_len, cfg.d_model), BF16),
            "mask": _sds((batch, seq_len), jnp.bool_),
        }
        if mode == "train":
            specs["targets"] = _sds((batch, seq_len), I32)
        return specs

    if cfg.frontend == "vision":
        n_patch = min(cfg.n_patches, max(seq_len - 16, 0))
        n_text = seq_len - n_patch
        specs = {
            "patches": _sds((batch, n_patch, cfg.d_model), BF16),
            "tokens": _sds((batch, n_text), I32),
        }
        if mode == "train":
            specs["targets"] = _sds((batch, n_text), I32)
        return specs

    specs = {"tokens": _sds((batch, seq_len), I32)}
    if mode == "train":
        specs["targets"] = _sds((batch, seq_len), I32)
    return specs
