"""Training launcher.

Two modes:
  * default: REAL training of a reduced-config model on the host CPU
    (the end-to-end example path — a ~100M model learns a Markov stream).
  * --dryrun: delegate to launch/dryrun.py semantics for the full config on
    the production mesh (lower+compile only).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --steps 300 --batch 8 --seq 256
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp

from repro import configs
from repro.data.pipeline import SyntheticTokens
from repro.models.transformer import Model
from repro.train import trainer


def scale_to_params(cfg, target_params: float):
    """Scale a reduced config up/down to roughly target_params (for the
    'train a ~100M model' driver)."""
    from repro.models.flops import param_count

    lo, hi = 1, 16
    best = cfg
    for mult in range(lo, hi + 1):
        cand = dataclasses.replace(
            cfg,
            d_model=cfg.d_model * mult // 2 * 2,
            d_ff=cfg.d_ff * mult if cfg.d_ff else 0,
            n_layers=min(cfg.n_layers * mult, 16),
        )
        try:
            cand.validate()
        except AssertionError:
            continue
        total, _ = param_count(cand)
        best = cand
        if total >= target_params:
            break
    return best


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--params", type=float, default=0,
                    help="scale reduced config to ~this many parameters")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    cfg = configs.get(args.arch, reduced=True)
    if args.params:
        cfg = scale_to_params(cfg, args.params)
    model = Model(cfg)
    data = iter(SyntheticTokens(vocab=cfg.vocab, seq_len=args.seq,
                                batch=args.batch, seed=0))
    state, history = trainer.train_loop(
        model, data, steps=args.steps, peak_lr=args.lr,
        checkpoint_dir=args.ckpt_dir or None,
        ckpt_every=100 if args.ckpt_dir else 0,
        warmup=min(50, args.steps // 4), total=args.steps,
    )
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({(1 - last / first) * 100:.1f}% reduction)")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(history, f, indent=1)


if __name__ == "__main__":
    main()
