from repro.models.transformer import Model, make_model  # noqa: F401
from repro.models import flops  # noqa: F401
