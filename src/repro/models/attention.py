"""Attention: GQA with causal / sliding-window / local-global / bidirectional
masks, soft-capping, RoPE, prefill and single-token decode paths.

The jnp path below is the reference used for dry-runs (XLA fuses it well on
TPU); ``repro.kernels.flash_attention`` provides the Pallas TPU kernel with
the same semantics (``use_kernel=True``), validated against this code in
tests/test_kernels.py.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers


class AttnParams(NamedTuple):
    wq: jnp.ndarray    # (d, H, hd)
    wk: jnp.ndarray    # (d, KV, hd)
    wv: jnp.ndarray    # (d, KV, hd)
    wo: jnp.ndarray    # (H, hd, d)


def init(key, cfg: ModelConfig, dtype=jnp.float32) -> AttnParams:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return AttnParams(
        wq=layers.dense_init(k1, (d, H, hd), dtype=dtype),
        wk=layers.dense_init(k2, (d, KV, hd), dtype=dtype),
        wv=layers.dense_init(k3, (d, KV, hd), dtype=dtype),
        wo=layers.dense_init(k4, (H, hd, d), in_axis=1, dtype=dtype),
    )


def _mask(sq: int, skv: int, q_pos: jnp.ndarray, kv_pos: jnp.ndarray,
          causal: bool, window: Optional[int], kv_len: Optional[jnp.ndarray]):
    """(..., sq, skv) bool mask. True = attend."""
    m = jnp.ones((sq, skv), dtype=bool)
    dq = q_pos[..., :, None]
    dk = kv_pos[..., None, :]
    if causal:
        m = m & (dk <= dq)
    if window is not None:
        m = m & (dk > dq - window)
    if kv_len is not None:                      # decode: valid cache prefix
        m = m & (dk < kv_len[..., None, None])
    return m


def sdpa_blockwise(q, k, v, *, q_pos, kv_pos, causal=True, window=None,
                   softcap_val=None, kv_len=None, block=512):
    """Online-softmax attention in pure XLA: lax.scan over KV blocks.

    Never materializes the (Sq, Skv) score matrix — HBM temp traffic drops
    from O(S^2) to O(S * block).  This is the §Perf 'memory-term' variant
    (the Pallas kernel is the TPU-native version of the same schedule; this
    path is what the 512-device dry-run lowers through GSPMD).
    """
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    rep = H // KV
    nb = -(-Skv // block)
    pad = nb * block - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, [(0, 0)] * (kv_pos.ndim - 1) + [(0, pad)],
                         constant_values=jnp.iinfo(jnp.int32).max // 2)
    scale = hd ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, KV, rep, hd)
    kb = k.reshape(B, nb, block, KV, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, block, KV, hd).transpose(1, 0, 2, 3, 4)
    pb = jnp.broadcast_to(kv_pos if kv_pos.ndim == 2 else kv_pos[None],
                          (B, nb * block)).reshape(B, nb, block).transpose(1, 0, 2)

    def step(carry, xs):
        m_prev, l_prev, acc = carry
        k_c, v_c, p_c = xs                                  # (B,blk,KV,hd), (B,blk)
        s = jnp.einsum("bqgrh,bkgh->bgrqk", qf, k_c.astype(jnp.float32))
        if softcap_val is not None:
            s = softcap_val * jnp.tanh(s / softcap_val)
        dq = q_pos[:, None, None, :, None]
        dk = p_c[:, None, None, None, :]
        mask = jnp.ones(s.shape, bool)
        if causal:
            mask &= dk <= dq
        if window is not None:
            mask &= dk > dq - window
        if kv_len is not None:
            mask &= dk < kv_len[:, None, None, None, None]
        mask &= dk < jnp.iinfo(jnp.int32).max // 4          # padding
        s = jnp.where(mask, s, -1e30)
        m_cur = jnp.maximum(m_prev, s.max(-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.where(mask, jnp.exp(s - m_cur[..., None]), 0.0)
        l_cur = l_prev * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bgrqk,bkgh->bgrqh", p, v_c.astype(jnp.float32))
        return (m_cur, l_cur, acc), None

    m0 = jnp.full((B, KV, rep, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, KV, rep, Sq), jnp.float32)
    acc0 = jnp.zeros((B, KV, rep, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd).astype(q.dtype)


def sdpa(q, k, v, *, q_pos, kv_pos, causal=True, window=None,
         softcap_val=None, kv_len=None, use_kernel=False):
    """q: (B,Sq,H,hd), k/v: (B,Skv,KV,hd) -> (B,Sq,H,hd).

    GQA: H must be a multiple of KV; kv heads are broadcast.
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV

    if use_kernel and Sq > 1 and softcap_val is None and kv_len is None:
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=causal, window=window)

    qh = q.reshape(B, Sq, KV, rep, hd)
    scale = hd ** -0.5
    logits = jnp.einsum("bqgrh,bkgh->bgrqk", qh.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    logits = layers.softcap(logits, softcap_val)
    mask = _mask(Sq, k.shape[1], q_pos, kv_pos, causal, window, kv_len)
    # mask is (sq,skv) or (B,sq,skv); align to logits (B,g,r,q,k)
    if mask.ndim == 2:
        mask = mask[None, None, None]
    else:
        mask = mask[:, None, None]
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrqk,bkgh->bqgrh", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def apply(params: AttnParams, cfg: ModelConfig, x: jnp.ndarray, *,
          positions: jnp.ndarray, window: Optional[int],
          cache: Optional[tuple] = None, cache_index: Optional[jnp.ndarray] = None,
          use_kernel: bool = False, impl: str = "naive"):
    """Full attention block body (no residual/norm — the caller owns those).

    cache: (k_cache, v_cache) with shape (B, S_max, KV, hd); when given, new
    k/v are written at ``cache_index`` and attention runs against the cache
    (decode / incremental prefill).  Returns (out, new_cache).
    """
    B, S, d = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params.wq)
    k = jnp.einsum("bsd,dgk->bsgk", x, params.wk)
    v = jnp.einsum("bsd,dgk->bsgk", x, params.wv)
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)

    causal = not cfg.encoder_only
    attn = sdpa_blockwise if impl == "blockwise" else sdpa
    kw = {} if impl == "blockwise" else {"use_kernel": use_kernel}
    if cache is None:
        out = attn(q, k, v, q_pos=positions, kv_pos=positions, causal=causal,
                   window=window, softcap_val=cfg.attn_softcap, **kw)
        new_cache = None
    else:
        kc, vc = cache
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), cache_index, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), cache_index, axis=1)
        S_max = kc.shape[1]
        kv_pos = jnp.arange(S_max)[None, :].astype(positions.dtype)
        kv_len = cache_index + S
        out = attn(q, kc, vc, q_pos=positions, kv_pos=kv_pos, causal=causal,
                   window=window, softcap_val=cfg.attn_softcap,
                   kv_len=jnp.full((B,), kv_len))
        new_cache = (kc, vc)

    out = jnp.einsum("bshk,hkd->bsd", out, params.wo)
    return out, new_cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    KV, hd = cfg.n_kv_heads, cfg.hd
    shape = (batch, max_len, KV, hd)
    return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
