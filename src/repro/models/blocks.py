"""Transformer blocks: one residual block = norm -> mixer -> norm -> FFN.

A block's *mixer* is GQA attention, MLA, or a Mamba-2 SSD layer; its FFN is
dense SwiGLU, MoE, or absent (pure-SSM archs).  ``LayerMeta`` describes a
layer position's static structure so heterogeneous stacks (Jamba 1:7,
Gemma-2 local/global, DeepSeek first-k-dense) can be scanned over periods.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, layers, mla, moe, ssm


@dataclasses.dataclass(frozen=True)
class LayerMeta:
    idx: int                 # absolute layer index
    kind: str                # 'attn' | 'ssm'
    is_moe: bool
    window: Optional[int]


def layer_meta(cfg: ModelConfig, idx: int) -> LayerMeta:
    return LayerMeta(
        idx=idx,
        kind=cfg.layer_kind(idx),
        is_moe=cfg.layer_is_moe(idx),
        window=cfg.layer_window(idx),
    )


class FFNParams(NamedTuple):
    w_gate: jnp.ndarray
    w_up: jnp.ndarray
    w_down: jnp.ndarray


def init_block(key, cfg: ModelConfig, meta: LayerMeta, dtype=jnp.float32) -> dict:
    kmix, kffn = jax.random.split(key)
    p: dict[str, Any] = {"ln1": jnp.zeros((cfg.d_model,), dtype)}
    if meta.kind == "attn":
        if cfg.attn_kind == "mla":
            p["mixer"] = mla.init(kmix, cfg, dtype)
        else:
            p["mixer"] = attention.init(kmix, cfg, dtype)
    else:
        p["mixer"] = ssm.init(kmix, cfg, dtype)
    if cfg.post_norm:
        p["ln1_post"] = jnp.zeros((cfg.d_model,), dtype)

    has_ffn = meta.is_moe or (cfg.d_ff > 0 and not (cfg.arch_type == "ssm"))
    if has_ffn:
        p["ln2"] = jnp.zeros((cfg.d_model,), dtype)
        if meta.is_moe:
            p["ffn"] = moe.init(kffn, cfg, dtype)
        else:
            k1, k2, k3 = jax.random.split(kffn, 3)
            d, f = cfg.d_model, cfg.d_ff
            p["ffn"] = FFNParams(
                w_gate=layers.dense_init(k1, (d, f), dtype=dtype),
                w_up=layers.dense_init(k2, (d, f), dtype=dtype),
                w_down=layers.dense_init(k3, (f, d), dtype=dtype),
            )
        if cfg.post_norm:
            p["ln2_post"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def init_block_cache(cfg: ModelConfig, meta: LayerMeta, batch: int,
                     max_len: int, dtype=jnp.bfloat16):
    if meta.kind == "ssm":
        return ssm.init_cache(cfg, batch, dtype)
    if cfg.attn_kind == "mla":
        return mla.init_cache(cfg, batch, max_len, dtype)
    return attention.init_cache(cfg, batch, max_len, dtype)


def apply_block(params: dict, cfg: ModelConfig, meta: LayerMeta, x: jnp.ndarray,
                *, positions: jnp.ndarray, cache=None, cache_index=None,
                use_kernel: bool = False, attn_impl: str = "naive",
                expert_axis: str | None = None, ep_mesh=None):
    """Returns (x_out, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)

    h = layers.rms_norm(x, params["ln1"], cfg.norm_eps)
    if meta.kind == "attn":
        mod = mla if cfg.attn_kind == "mla" else attention
        mix, new_cache = mod.apply(
            params["mixer"], cfg, h, positions=positions, window=meta.window,
            cache=cache, cache_index=cache_index, use_kernel=use_kernel,
            impl=attn_impl,
        )
    else:
        mix, new_cache = ssm.apply(
            params["mixer"], cfg, h, cache=cache, use_kernel=use_kernel,
        )
    if cfg.post_norm:
        mix = layers.rms_norm(mix, params["ln1_post"], cfg.norm_eps)
    # named for selective remat: policy save_only_these_names("mixer_out")
    # keeps mixer outputs across the checkpoint so the backward pass does
    # not re-run attention/SSD forward (inert without the policy)
    from jax.ad_checkpoint import checkpoint_name
    mix = checkpoint_name(mix, "mixer_out")
    x = x + mix

    if "ffn" in params:
        h = layers.rms_norm(x, params["ln2"], cfg.norm_eps)
        if meta.is_moe:
            if ep_mesh is not None:
                from repro.models import moe_ep
                f, aux = moe_ep.apply_ep(params["ffn"], cfg, h, ep_mesh)
            else:
                f, aux = moe.apply(params["ffn"], cfg, h, expert_axis=expert_axis)
        else:
            fp: FFNParams = params["ffn"]
            f = layers.swiglu(h, fp.w_gate, fp.w_up, fp.w_down, cfg.act)
        if cfg.post_norm:
            f = layers.rms_norm(f, params["ln2_post"], cfg.norm_eps)
        x = x + f
    return x, new_cache, aux
