"""Analytic FLOP / parameter / byte models for every architecture.

Used by three consumers:
  * ``core/chain.py`` — packet sizes and workloads for DNN-vertical-split
    service chains,
  * ``benchmarks/roofline.py`` — MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D
    (MoE) and the useful-compute ratio,
  * sanity tests (parameter counts vs the models' advertised sizes).

Conventions: per-TOKEN forward FLOPs unless stated; a matmul of (m,k)x(k,n)
counts 2*m*k*n.  Causal attention averages sequence interaction to S/2.
"""

from __future__ import annotations

from repro.configs.base import ModelConfig


def embed_bits_per_token(cfg: ModelConfig) -> float:
    """Bits entering the network per token (stage-0 packets of the chain)."""
    if cfg.frontend in ("audio", "vision"):
        return cfg.d_model * 16.0          # precomputed bf16 embeddings (stub)
    return 32.0                            # int32 token ids


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

def _attn_params(cfg: ModelConfig) -> int:
    d, hd = cfg.d_model, cfg.hd
    if cfg.attn_kind == "mla":
        m = cfg.mla
        qk = m.nope_head_dim + m.rope_head_dim
        p = d * m.q_lora_rank + m.q_lora_rank * cfg.n_heads * qk        # W_DQ, W_UQ
        p += d * (m.kv_lora_rank + m.rope_head_dim)                     # W_DKV
        p += m.kv_lora_rank * cfg.n_heads * (m.nope_head_dim + m.v_head_dim)
        p += cfg.n_heads * m.v_head_dim * d                             # W_O
        p += m.q_lora_rank + m.kv_lora_rank                             # norms
        return p
    return d * (cfg.n_heads * hd + 2 * cfg.n_kv_heads * hd) + cfg.n_heads * hd * d


def _ffn_params(cfg: ModelConfig, d_ff: int) -> int:
    return 3 * cfg.d_model * d_ff          # SwiGLU: gate, up, down


def _moe_params(cfg: ModelConfig) -> tuple[int, int]:
    """(total, active) MoE-FFN params per MoE layer."""
    m = cfg.moe
    router = cfg.d_model * m.n_experts
    per_exp = 3 * cfg.d_model * m.d_expert
    total = router + (m.n_experts + m.n_shared) * per_exp
    active = router + (m.top_k + m.n_shared) * per_exp
    return total, active


def _ssm_params(cfg: ModelConfig) -> int:
    s = cfg.ssm
    d, di = cfg.d_model, s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    conv_dim = di + 2 * s.n_groups * s.d_state
    p = d * (2 * di + 2 * s.n_groups * s.d_state + nh)   # in_proj (z,x,B,C,dt)
    p += conv_dim * s.d_conv                             # depthwise conv
    p += nh * 2 + nh                                     # A_log, D, dt_bias
    p += di * d                                          # out_proj
    return p


def param_count(cfg: ModelConfig) -> tuple[int, int]:
    """(total, active) parameter counts for the full model."""
    total = active = cfg.vocab * cfg.d_model             # embedding
    if not cfg.tie_embeddings and not cfg.encoder_only:
        total += cfg.d_model * cfg.vocab
        active += cfg.d_model * cfg.vocab
    if cfg.encoder_only:
        total += cfg.d_model * cfg.vocab                 # prediction head
        active += cfg.d_model * cfg.vocab
    for idx in range(cfg.n_layers):
        lp = la = 2 * cfg.d_model                        # pre-norms
        if cfg.layer_kind(idx) == "attn":
            a = _attn_params(cfg)
            lp += a
            la += a
        else:
            s = _ssm_params(cfg)
            lp += s
            la += s
        if cfg.layer_kind(idx) == "attn" or cfg.d_ff or cfg.moe:
            if cfg.layer_is_moe(idx):
                t, a = _moe_params(cfg)
                lp += t
                la += a
            elif cfg.d_ff:
                f = _ffn_params(cfg, cfg.d_ff)
                lp += f
                la += f
        total += lp
        active += la
    return total, active


# ---------------------------------------------------------------------------
# per-token forward FLOPs
# ---------------------------------------------------------------------------

def _attn_flops(cfg: ModelConfig, ctx: float) -> float:
    """Per-token attention FLOPs with average context length ctx."""
    d, hd = cfg.d_model, cfg.hd
    if cfg.attn_kind == "mla":
        m = cfg.mla
        qk = m.nope_head_dim + m.rope_head_dim
        f = 2 * d * m.q_lora_rank + 2 * m.q_lora_rank * cfg.n_heads * qk
        f += 2 * d * (m.kv_lora_rank + m.rope_head_dim)
        f += 2 * m.kv_lora_rank * cfg.n_heads * (m.nope_head_dim + m.v_head_dim)
        f += 2 * ctx * cfg.n_heads * (qk + m.v_head_dim)     # scores + AV
        f += 2 * cfg.n_heads * m.v_head_dim * d
        return f
    f = 2 * d * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd      # qkv proj
    f += 2 * ctx * cfg.n_heads * hd * 2                      # scores + AV
    f += 2 * cfg.n_heads * hd * d                            # out proj
    return f


def _ssm_flops(cfg: ModelConfig, chunk: int = 256) -> float:
    s = cfg.ssm
    d, di = cfg.d_model, s.d_inner(cfg.d_model)
    N = s.d_state
    f = 2 * d * (2 * di + 2 * s.n_groups * N + s.n_heads(cfg.d_model))
    f += 2 * di * N * 2                                      # state update + output
    f += 2 * chunk * di                                      # intra-chunk quadratic
    f += 2 * di * d                                          # out proj
    return f


def _ffn_flops(cfg: ModelConfig, idx: int) -> float:
    if cfg.layer_is_moe(idx):
        m = cfg.moe
        f = 2 * cfg.d_model * m.n_experts                    # router
        f += (m.top_k + m.n_shared) * 3 * 2 * cfg.d_model * m.d_expert
        return f
    if cfg.d_ff:
        return 3 * 2 * cfg.d_model * cfg.d_ff
    return 0.0


def layer_flops(cfg: ModelConfig, seq_len: int, decode: bool = False,
                cache_len: int = 0) -> float:
    """Average per-token forward FLOPs of one *average* layer.

    decode=True: one new token attending to cache_len context.
    """
    total = 0.0
    for idx in range(cfg.n_layers):
        if cfg.layer_kind(idx) == "attn":
            win = cfg.layer_window(idx)
            if decode:
                ctx = min(cache_len, win) if win else cache_len
            else:
                ctx = min(seq_len, win) if win else seq_len
                ctx = ctx / 2 if not cfg.encoder_only else ctx
            total += _attn_flops(cfg, ctx)
        else:
            total += _ssm_flops(cfg)
        total += _ffn_flops(cfg, idx)
    return total / cfg.n_layers


def model_flops_per_token(cfg: ModelConfig, seq_len: int, decode: bool = False,
                          cache_len: int = 0) -> float:
    """Forward FLOPs per token for the whole model incl. embeddings/head."""
    f = cfg.n_layers * layer_flops(cfg, seq_len, decode, cache_len)
    f += 2 * cfg.d_model * cfg.vocab                         # lm/prediction head
    return f


def training_flops_per_token(cfg: ModelConfig, seq_len: int) -> float:
    """fwd + bwd ~ 3x fwd."""
    return 3.0 * model_flops_per_token(cfg, seq_len)


def model_flops_6nd(cfg: ModelConfig, tokens: float) -> float:
    """The roofline reference: 6*N*D with N = active params (MoE-aware)."""
    _, active = param_count(cfg)
    return 6.0 * active * tokens
