"""Shared neural-net building blocks (pure-JAX, functional params)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def softcap(x: jnp.ndarray, cap: float | None) -> jnp.ndarray:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
           w_down: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    g = act_fn(act)(x @ w_gate)
    return (g * (x @ w_up)) @ w_down


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd), positions: (..., S) -> rotated x (same dtype)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                            # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs   # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32) -> jnp.ndarray:
    fan_in = max(shape[in_axis], 1)          # zero-width params (n_shared=0)
    std = fan_in ** -0.5
    return (std * jax.random.normal(key, shape)).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32) -> jnp.ndarray:
    return (jax.random.normal(key, shape)).astype(dtype)


def zeros_init(_key, shape, dtype=jnp.float32) -> jnp.ndarray:
    return jnp.zeros(shape, dtype)
