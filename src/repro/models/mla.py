"""Multi-head Latent Attention (DeepSeek-V3, arXiv:2412.19437).

Train/prefill path: full low-rank decomposition —
  q  = RoPE-split( W_UQ . norm(W_DQ x) )                 per-head (nope|rope)
  kv = W_DKV x  ->  c_kv (rank 512)  +  k_rope (shared 64-dim, RoPE'd)
  k  = (W_UK c_kv | broadcast k_rope),  v = W_UV c_kv

Decode path (absorbed): the cache stores ONLY (c_kv, k_rope) — 576 floats
per token instead of H*(128+128); W_UK is absorbed into the query and W_UV
into the output projection, so decode attention runs in the compressed
space.  This is MLA's central serving trick and what makes long_500k decode
feasible for a 128-head model (DESIGN.md §6).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers


class MLAParams(NamedTuple):
    w_dq: jnp.ndarray      # (d, q_rank)
    q_norm: jnp.ndarray    # (q_rank,)
    w_uq: jnp.ndarray      # (q_rank, H, nope+rope)
    w_dkv: jnp.ndarray     # (d, kv_rank + rope)
    kv_norm: jnp.ndarray   # (kv_rank,)
    w_uk: jnp.ndarray      # (kv_rank, H, nope)
    w_uv: jnp.ndarray      # (kv_rank, H, v)
    w_o: jnp.ndarray       # (H, v, d)


def init(key, cfg: ModelConfig, dtype=jnp.float32) -> MLAParams:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    return MLAParams(
        w_dq=layers.dense_init(ks[0], (d, m.q_lora_rank), dtype=dtype),
        q_norm=jnp.zeros((m.q_lora_rank,), dtype),
        w_uq=layers.dense_init(ks[1], (m.q_lora_rank, H, m.nope_head_dim + m.rope_head_dim), dtype=dtype),
        w_dkv=layers.dense_init(ks[2], (d, m.kv_lora_rank + m.rope_head_dim), dtype=dtype),
        kv_norm=jnp.zeros((m.kv_lora_rank,), dtype),
        w_uk=layers.dense_init(ks[3], (m.kv_lora_rank, H, m.nope_head_dim), dtype=dtype),
        w_uv=layers.dense_init(ks[4], (m.kv_lora_rank, H, m.v_head_dim), dtype=dtype),
        w_o=layers.dense_init(ks[5], (H, m.v_head_dim, d), in_axis=1, dtype=dtype),
    )


def _queries(p: MLAParams, cfg: ModelConfig, x, positions):
    m = cfg.mla
    cq = layers.rms_norm(x @ p.w_dq, p.q_norm, cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p.w_uq)
    q_nope, q_rope = q[..., : m.nope_head_dim], q[..., m.nope_head_dim:]
    q_rope = layers.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latents(p: MLAParams, cfg: ModelConfig, x, positions):
    m = cfg.mla
    dkv = x @ p.w_dkv
    c_kv = layers.rms_norm(dkv[..., : m.kv_lora_rank], p.kv_norm, cfg.norm_eps)
    k_rope = dkv[..., m.kv_lora_rank:][:, :, None, :]        # (B,S,1,rope)
    k_rope = layers.apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def apply(p: MLAParams, cfg: ModelConfig, x, *, positions,
          cache: Optional[tuple] = None, cache_index=None, impl: str = "naive",
          **_):
    """Returns (out, new_cache); cache = (c_kv, k_rope) compressed."""
    m = cfg.mla
    B, S, _ = x.shape
    q_nope, q_rope = _queries(p, cfg, x, positions)
    c_kv, k_rope = _latents(p, cfg, x, positions)
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5

    if cache is None:
        # ---- train / prefill: expand keys and values per head ----
        k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p.w_uk)
        v = jnp.einsum("bsr,rhk->bshk", c_kv, p.w_uv)
        lg = jnp.einsum("bqhk,bshk->bhqs", q_nope.astype(jnp.float32), k_nope.astype(jnp.float32))
        lg += jnp.einsum("bqhk,bsk->bhqs", q_rope.astype(jnp.float32), k_rope.astype(jnp.float32))
        lg = lg * scale
        mask = positions[:, None, :, None] >= positions[:, None, None, :] if positions.ndim == 2 \
            else (jnp.arange(S)[:, None] >= jnp.arange(S)[None, :])[None, None]
        lg = jnp.where(mask if mask.ndim == 4 else mask[None], lg, -1e30)
        pr = jax.nn.softmax(lg, axis=-1)
        out = jnp.einsum("bhqs,bshk->bqhk", pr, v.astype(jnp.float32)).astype(x.dtype)
        new_cache = None
    else:
        # ---- absorbed decode against the compressed cache ----
        ckv_c, krope_c = cache
        ckv_c = jax.lax.dynamic_update_slice_in_dim(ckv_c, c_kv.astype(ckv_c.dtype), cache_index, axis=1)
        krope_c = jax.lax.dynamic_update_slice_in_dim(krope_c, k_rope.astype(krope_c.dtype), cache_index, axis=1)
        S_max = ckv_c.shape[1]
        # absorb W_UK into the query: q_eff (B,S,H,kv_rank)
        q_eff = jnp.einsum("bqhk,rhk->bqhr", q_nope, p.w_uk)
        lg = jnp.einsum("bqhr,bsr->bhqs", q_eff.astype(jnp.float32), ckv_c.astype(jnp.float32))
        lg += jnp.einsum("bqhk,bsk->bhqs", q_rope.astype(jnp.float32), krope_c.astype(jnp.float32))
        lg = lg * scale
        kv_len = cache_index + S
        q_abs = positions[..., :, None] if positions.ndim == 2 else jnp.arange(S)[None, :, None]
        s_pos = jnp.arange(S_max)[None, None, :]
        valid = (s_pos <= q_abs) & (s_pos < kv_len)
        lg = jnp.where(valid[:, None], lg, -1e30)
        pr = jax.nn.softmax(lg, axis=-1)
        # attend in compressed space, then expand with W_UV
        ctx = jnp.einsum("bhqs,bsr->bqhr", pr, ckv_c.astype(jnp.float32))
        out = jnp.einsum("bqhr,rhk->bqhk", ctx, p.w_uv.astype(jnp.float32)).astype(x.dtype)
        new_cache = (ckv_c, krope_c)

    out = jnp.einsum("bqhk,hkd->bqd", out, p.w_o)
    return out, new_cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    m = cfg.mla
    return (jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
            jnp.zeros((batch, max_len, m.rope_head_dim), dtype))
