"""Mixture-of-Experts FFN with capacity-based token dropping.

Dispatch uses scatter/gather over a fixed-capacity per-expert buffer
(E, C, d) — compile-friendly for the 512-device dry-run (the buffer's
expert axis carries the "model"-axis sharding) and exact for the smoke
tests when capacity is ample.  Top-k routing with softmax-normalized
gates; optional always-on shared experts (DeepSeek-V3); auxiliary
load-balance loss (Switch-style) returned to the caller.

The shard_map expert-parallel (all-to-all) variant lives in
``repro.models.moe_ep`` and is the §Perf beyond-baseline optimization.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers


class MoEParams(NamedTuple):
    router: jnp.ndarray        # (d, E)
    w_gate: jnp.ndarray        # (E, d, f)
    w_up: jnp.ndarray          # (E, d, f)
    w_down: jnp.ndarray        # (E, f, d)
    shared_gate: jnp.ndarray   # (d, n_shared*f) or (d, 0)
    shared_up: jnp.ndarray
    shared_down: jnp.ndarray   # (n_shared*f, d)


def init(key, cfg: ModelConfig, dtype=jnp.float32) -> MoEParams:
    m = cfg.moe
    d, E, f = cfg.d_model, m.n_experts, m.d_expert
    sf = m.n_shared * f
    ks = jax.random.split(key, 7)
    return MoEParams(
        router=layers.dense_init(ks[0], (d, E), dtype=jnp.float32),
        w_gate=layers.dense_init(ks[1], (E, d, f), in_axis=1, dtype=dtype),
        w_up=layers.dense_init(ks[2], (E, d, f), in_axis=1, dtype=dtype),
        w_down=layers.dense_init(ks[3], (E, f, d), in_axis=1, dtype=dtype),
        shared_gate=layers.dense_init(ks[4], (d, sf), dtype=dtype),
        shared_up=layers.dense_init(ks[5], (d, sf), dtype=dtype),
        shared_down=layers.dense_init(ks[6], (sf, d), in_axis=0, dtype=dtype),
    )


def route(router_w: jnp.ndarray, x: jnp.ndarray, top_k: int):
    """x: (T, d) -> (gate_weights (T,k), expert_ids (T,k), aux_loss, probs)."""
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                 # (T, E)
    gw, ids = jax.lax.top_k(probs, top_k)
    gw = gw / jnp.maximum(gw.sum(-1, keepdims=True), 1e-9)  # renormalize
    # Switch-style load-balance aux: E * sum_e f_e * P_e
    E = probs.shape[-1]
    hard = jax.nn.one_hot(ids[:, 0], E)
    aux = E * jnp.mean(hard.mean(0) * probs.mean(0)) * E
    return gw.astype(x.dtype), ids, aux, probs


def capacity(T: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(T * m.top_k * m.capacity_factor / m.n_experts)
    return max(8, -(-c // 8) * 8)                            # round up to 8


def apply(p: MoEParams, cfg: ModelConfig, x: jnp.ndarray,
          expert_axis: str | None = None):
    """x: (B, S, d) -> (out (B,S,d), aux_loss scalar).

    expert_axis: mesh axis name to pin the dispatch buffer's expert dim to
    (requires an ambient mesh, i.e. tracing under ``with mesh:``).  Without
    the constraint GSPMD may replicate the expert einsums across the model
    axis — the §Perf 'moeshard' fix.
    """
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    gw, ids, aux, _ = route(p.router, xt, m.top_k)           # (T,k)

    E, C = m.n_experts, capacity(T, cfg)
    flat_ids = ids.reshape(-1)                               # (T*k,)
    oh = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)        # (T*k, E)
    pos = jnp.cumsum(oh, axis=0) - 1                         # position in expert
    pos = jnp.take_along_axis(pos, flat_ids[:, None], axis=1)[:, 0]   # (T*k,)
    keep = pos < C
    safe_pos = jnp.where(keep, pos, 0)

    # scatter tokens into the (E, C, d) buffer (dropped tokens excluded)
    src = jnp.repeat(xt, m.top_k, axis=0) * keep[:, None].astype(xt.dtype)
    buf = jnp.zeros((E, C, d), xt.dtype).at[flat_ids, safe_pos].add(src)

    def pin(t):
        if expert_axis is None:
            return t
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(
            t, P(expert_axis, *([None] * (t.ndim - 1))))

    buf = pin(buf)
    # expert FFN (E, C, d) -> (E, C, d)
    g = layers.act_fn(cfg.act)(jnp.einsum("ecd,edf->ecf", buf, p.w_gate))
    u = jnp.einsum("ecd,edf->ecf", buf, p.w_up)
    eo = pin(jnp.einsum("ecf,efd->ecd", g * u, p.w_down))

    # gather back and combine with gate weights
    out_tk = eo[flat_ids, safe_pos] * keep[:, None].astype(eo.dtype)  # (T*k, d)
    out = (out_tk.reshape(T, m.top_k, d) * gw[..., None]).sum(1)

    if m.n_shared:
        out = out + layers.swiglu(xt, p.shared_gate, p.shared_up,
                                  p.shared_down, cfg.act)
    return out.reshape(B, S, d), aux
