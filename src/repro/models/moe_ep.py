"""Expert-parallel MoE with explicit all-to-all (shard_map).

The GSPMD path (`repro.models.moe`) lets the compiler place the dispatch;
this module expresses the canonical expert-parallel schedule explicitly:

  1. tokens are data-parallel (sharded over 'data'); each shard routes its
     tokens into a (E, C_loc, d) buffer indexed by *global* expert id,
  2. all-to-all over the 'model' axis regroups the buffer so each device
     holds (E_loc, n_model * C_loc, d) — all tokens for ITS experts,
  3. local expert FFN,
  4. reverse all-to-all + local combine.

Wire bytes per device per layer: 2 x (E * C_loc * d) — independent of the
expert count beyond the capacity total, vs. the all-reduce of the full
activation the baseline pays.  This is the §Perf 'collective-term' variant
for MoE layers and the paper's all-to-all analogue of its service-chain
forwarding (DESIGN.md §4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import compat
from repro.models import layers, moe


def apply_ep(p: moe.MoEParams, cfg: ModelConfig, x: jnp.ndarray, mesh: Mesh,
             *, data_axis: str = "data", model_axis: str = "model"):
    """x: (B, S, d) global -> (out, aux).  Requires E % mesh[model] == 0 and
    B % mesh[data] == 0."""
    m = cfg.moe
    E = m.n_experts
    n_model = mesh.shape[model_axis]
    assert E % n_model == 0, (E, n_model)
    E_loc = E // n_model

    def shard_fn(router, w_gate, w_up, w_down, x_loc):
        B_loc, S, d = x_loc.shape
        T = B_loc * S
        xt = x_loc.reshape(T, d)
        gw, ids, aux, _ = moe.route(router, xt, m.top_k)
        C = moe.capacity(T, cfg)

        flat_ids = ids.reshape(-1)
        oh = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)
        pos = jnp.cumsum(oh, axis=0) - 1
        pos = jnp.take_along_axis(pos, flat_ids[:, None], axis=1)[:, 0]
        keep = pos < C
        safe_pos = jnp.where(keep, pos, 0)
        src = jnp.repeat(xt, m.top_k, axis=0) * keep[:, None].astype(xt.dtype)
        buf = jnp.zeros((E, C, d), xt.dtype).at[flat_ids, safe_pos].add(src)

        # exchange: (n_model, E_loc, C, d) -> each device keeps its experts
        buf = buf.reshape(n_model, E_loc, C, d)
        buf = jax.lax.all_to_all(buf, model_axis, split_axis=0, concat_axis=0,
                                 tiled=False)
        # buf: (n_model, E_loc, C, d) where axis 0 now indexes source shards
        buf = buf.transpose(1, 0, 2, 3).reshape(E_loc, n_model * C, d)

        g = layers.act_fn(cfg.act)(jnp.einsum("ecd,edf->ecf", buf, w_gate))
        u = jnp.einsum("ecd,edf->ecf", buf, w_up)
        eo = jnp.einsum("ecf,efd->ecd", g * u, w_down)

        # reverse exchange
        eo = eo.reshape(E_loc, n_model, C, d).transpose(1, 0, 2, 3)
        eo = jax.lax.all_to_all(eo, model_axis, split_axis=0, concat_axis=0,
                                tiled=False)
        eo = eo.reshape(E, C, d)

        out_tk = eo[flat_ids, safe_pos] * keep[:, None].astype(eo.dtype)
        out = (out_tk.reshape(T, m.top_k, d) * gw[..., None]).sum(1)
        aux = jax.lax.pmean(aux, data_axis)
        return out.reshape(B_loc, S, d), aux

    rep = P()
    exp = P(model_axis)
    out, aux = compat.shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(rep, exp, exp, exp, P(data_axis, None, None)),
        out_specs=(P(data_axis, None, None), rep),
        check=False,
    )(p.router, p.w_gate, p.w_up, p.w_down, x)

    if m.n_shared:
        B, S, d = x.shape
        out = out + layers.swiglu(x.reshape(-1, d), p.shared_gate,
                                  p.shared_up, p.shared_down,
                                  cfg.act).reshape(B, S, d)
    return out, aux
