"""Mamba-2 SSD mixer (arXiv:2405.21060), TPU-adapted.

The SSD (state-space duality) forward is implemented in its *chunked
matmul form*: the sequence is split into chunks of Q tokens; intra-chunk
interactions are dense (C B^T ∘ decay) matmuls (MXU-friendly) and the
inter-chunk recurrence is a short ``lax.scan`` over chunk states — this is
precisely the TPU-native re-blocking of the paper's GPU kernel (DESIGN.md
§4).  The intra-chunk core also exists as a Pallas kernel
(``repro.kernels.ssd_chunk``).

Decode is the O(1) recurrent update on the (B, H, P, N) state.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers

CHUNK = 128


class SSMParams(NamedTuple):
    w_in: jnp.ndarray       # (d, 2*di + 2*g*N + nh)   -> z, x, B, C, dt
    conv_w: jnp.ndarray     # (conv_dim, d_conv)
    conv_b: jnp.ndarray     # (conv_dim,)
    a_log: jnp.ndarray      # (nh,)
    d_skip: jnp.ndarray     # (nh,)
    dt_bias: jnp.ndarray    # (nh,)
    norm: jnp.ndarray       # (di,)
    w_out: jnp.ndarray      # (di, d)


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    conv_dim = di + 2 * s.n_groups * s.d_state
    return s, di, nh, conv_dim


def init(key, cfg: ModelConfig, dtype=jnp.float32) -> SSMParams:
    s, di, nh, conv_dim = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    in_dim = 2 * di + 2 * s.n_groups * s.d_state + nh
    return SSMParams(
        w_in=layers.dense_init(ks[0], (d, in_dim), dtype=dtype),
        conv_w=(jax.random.normal(ks[1], (conv_dim, s.d_conv)) / s.d_conv).astype(dtype),
        conv_b=jnp.zeros((conv_dim,), dtype),
        a_log=jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        d_skip=jnp.ones((nh,), jnp.float32),
        dt_bias=jnp.zeros((nh,), jnp.float32),
        norm=jnp.zeros((di,), dtype),
        w_out=layers.dense_init(ks[2], (di, d), dtype=dtype),
    )


def _split(cfg: ModelConfig, proj: jnp.ndarray):
    s, di, nh, _ = _dims(cfg)
    gN = s.n_groups * s.d_state
    z, xs, Bc, Cc, dt = jnp.split(proj, [di, 2 * di, 2 * di + gN, 2 * di + 2 * gN], axis=-1)
    return z, xs, Bc, Cc, dt


def _causal_conv(seq: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv1d.  seq: (B,S,Cd), w: (Cd,K).  Returns (out,
    new_state) where state is the last K-1 inputs for streaming decode."""
    B, S, Cd = seq.shape
    K = w.shape[1]
    if state is None:
        pad = jnp.zeros((B, K - 1, Cd), seq.dtype)
    else:
        pad = state.astype(seq.dtype)
    full = jnp.concatenate([pad, seq], axis=1)               # (B, S+K-1, Cd)
    idx = jnp.arange(S)[:, None] + jnp.arange(K)[None, :]    # (S, K)
    windows = full[:, idx]                                   # (B, S, K, Cd)
    out = jnp.einsum("bskc,ck->bsc", windows, w) + b
    new_state = full[:, S:][:, -(K - 1):] if S >= K - 1 else full[:, -(K - 1):]
    return out, new_state


def ssd_chunked(xh, dt, A, Bc, Cc, h0=None, use_kernel: bool = False):
    """SSD forward in chunked matmul form.

    xh: (B,S,H,P), dt: (B,S,H), A: (H,) (negative), Bc/Cc: (B,S,G,N).
    Returns (y (B,S,H,P), h_last (B,H,P,N)).
    """
    Bsz, S, H, P = xh.shape
    G, N = Bc.shape[2], Bc.shape[3]
    rep = H // G
    Q = min(CHUNK, S)
    nc = S // Q
    assert nc * Q == S, "seq len must be divisible by the SSD chunk"

    f32 = jnp.float32
    xh = xh.astype(f32).reshape(Bsz, nc, Q, H, P)
    dt = dt.astype(f32).reshape(Bsz, nc, Q, H)
    Bc = Bc.astype(f32).reshape(Bsz, nc, Q, G, N)
    Cc = Cc.astype(f32).reshape(Bsz, nc, Q, G, N)
    BH = jnp.repeat(Bc, rep, axis=3)                         # (B,nc,Q,H,N)
    CH = jnp.repeat(Cc, rep, axis=3)

    dtA = dt * A[None, None, None, :]                        # (B,nc,Q,H)
    cum = jnp.cumsum(dtA, axis=2)                            # within-chunk
    seg_total = cum[:, :, -1, :]                             # (B,nc,H)

    # intra-chunk: scores[i,j] = (C_i . B_j) * exp(cum_i - cum_j) * dt_j, j <= i
    if use_kernel:
        from repro.kernels import ops as kops
        y_intra, state_c = kops.ssd_chunk(xh, dt, dtA, cum, BH, CH)
    else:
        scores = jnp.einsum("bcqhn,bckhn->bchqk", CH, BH)
        diff = (cum[:, :, :, None, :].transpose(0, 1, 4, 2, 3)
                - cum[:, :, None, :, :].transpose(0, 1, 4, 2, 3))  # (B,nc,H,Q,K)
        tri = jnp.tril(jnp.ones((Q, Q), bool))[None, None, None]
        # mask INSIDE the exponent: exp of masked entries would overflow and
        # poison the backward pass through jnp.where (NaN gradients)
        decay = jnp.exp(jnp.where(tri, diff, -1e9))
        w = jnp.where(tri, scores * decay, 0.0)
        w = w * dt.transpose(0, 1, 3, 2)[:, :, :, None, :]   # weight by dt_j
        y_intra = jnp.einsum("bchqk,bckhp->bcqhp", w, xh)
        # chunk state contribution: sum_j exp(seg_total - cum_j) dt_j B_j x_j
        sdec = jnp.exp(seg_total[:, :, None, :] - cum) * dt  # (B,nc,Q,H)
        state_c = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn", sdec, BH, xh)

    # inter-chunk recurrence over chunk states
    gamma = jnp.exp(seg_total)                               # (B,nc,H)

    def scan_fn(h, xs):
        g_c, s_c = xs                                        # (B,H), (B,H,P,N)
        h_next = h * g_c[:, :, None, None] + s_c
        return h_next, h                                     # emit h at chunk START

    h_init = jnp.zeros((Bsz, H, P, N), f32) if h0 is None else h0.astype(f32)
    h_last, h_starts = jax.lax.scan(
        scan_fn, h_init,
        (gamma.transpose(1, 0, 2), state_c.transpose(1, 0, 2, 3, 4)),
    )
    h_starts = h_starts.transpose(1, 0, 2, 3, 4)             # (B,nc,H,P,N)

    # inter contribution: C_i . (exp(cum_i) * h_start)
    y_inter = jnp.einsum("bcqhn,bchpn->bcqhp", CH * jnp.exp(cum)[..., None], h_starts)
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y, h_last


def apply(p: SSMParams, cfg: ModelConfig, x: jnp.ndarray, *,
          cache: Optional[tuple] = None, use_kernel: bool = False, **_):
    """Mamba-2 block body.  cache = (conv_state, ssm_state) for decode."""
    s, di, nh, conv_dim = _dims(cfg)
    B, S, d = x.shape
    proj = x @ p.w_in
    z, xs, Bc, Cc, dt = _split(cfg, proj)

    conv_in = jnp.concatenate([xs, Bc, Cc], axis=-1)
    conv_state = cache[0] if cache is not None else None
    conv_out, new_conv_state = _causal_conv(conv_in, p.conv_w, p.conv_b, conv_state)
    conv_out = jax.nn.silu(conv_out)
    xs = conv_out[..., :di]
    Bc = conv_out[..., di:di + s.n_groups * s.d_state]
    Cc = conv_out[..., di + s.n_groups * s.d_state:]

    P = s.head_dim
    xh = xs.reshape(B, S, nh, P)
    Bc = Bc.reshape(B, S, s.n_groups, s.d_state)
    Cc = Cc.reshape(B, S, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p.dt_bias)
    A = -jnp.exp(p.a_log)

    if cache is None or S > 1:
        h0 = cache[1] if cache is not None else None
        y, h_last = ssd_chunked(xh, dt, A, Bc, Cc, h0=h0, use_kernel=use_kernel)
    else:
        # single-token recurrent decode: h = h*exp(dtA) + dt * B (x) x
        h0 = cache[1]
        rep = nh // s.n_groups
        BH = jnp.repeat(Bc, rep, axis=2)[:, 0]               # (B,H,N)
        CH = jnp.repeat(Cc, rep, axis=2)[:, 0]
        dt1 = dt[:, 0]                                       # (B,H)
        decay = jnp.exp(dt1 * A[None, :])                    # (B,H)
        upd = jnp.einsum("bh,bhn,bhp->bhpn", dt1, BH, xh[:, 0].astype(jnp.float32))
        h_last = h0.astype(jnp.float32) * decay[..., None, None] + upd
        y = jnp.einsum("bhn,bhpn->bhp", CH, h_last)[:, None]  # (B,1,H,P)

    y = y + xh.astype(jnp.float32) * p.d_skip[None, None, :, None]
    y = y.reshape(B, S, di).astype(x.dtype)
    y = layers.rms_norm(y * jax.nn.silu(z), p.norm, cfg.norm_eps)
    out = y @ p.w_out
    new_cache = (new_conv_state, h_last) if cache is not None else None
    return out, new_cache


def init_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    s, di, nh, conv_dim = _dims(cfg)
    return (jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
            jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32))
