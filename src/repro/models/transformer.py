"""Config-driven model: embedding -> (prefix blocks) -> scanned periodic
block stack -> final norm -> head.

Heterogeneous layer patterns (Jamba 1:7 hybrid, Gemma-2 local/global pairs,
DeepSeek-V3 first-3-dense + MoE, ...) are handled by scanning over *periods*:
the layer pattern repeats every ``period`` layers, parameters of equal
pattern-positions are stacked with a leading period axis, and one
``lax.scan`` body runs a whole period.  This keeps the lowered HLO small
(61-layer models compile as 1-2 scan bodies) — essential for the 512-device
dry-runs.

All functions are functional: ``init`` returns a params pytree, ``apply``
is pure.  ``Model.apply`` supports three modes:
  * train/score: full sequence, no cache -> logits (B,S,V)
  * prefill:     full sequence, cache=empty -> logits + filled cache
  * decode:      S=1 token against a cache at ``cache_index``
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks, layers


@dataclasses.dataclass(frozen=True)
class Stack:
    """Static description of the layer stack decomposition."""
    prefix: tuple          # tuple[LayerMeta] — unscanned leading layers
    pattern: tuple         # tuple[LayerMeta] — metas of one period (by position)
    n_periods: int


def build_stack(cfg: ModelConfig) -> Stack:
    n_prefix = cfg.moe.first_k_dense if cfg.moe else 0
    body = cfg.n_layers - n_prefix
    if cfg.hybrid_attn_period:
        period = cfg.hybrid_attn_period
    elif cfg.local_global:
        period = 2
    elif cfg.moe and cfg.moe.every > 1:
        period = cfg.moe.every
    else:
        period = 1
    assert body % period == 0, (cfg.name, body, period)
    prefix = tuple(blocks.layer_meta(cfg, i) for i in range(n_prefix))
    pattern = tuple(blocks.layer_meta(cfg, n_prefix + p) for p in range(period))
    return Stack(prefix=prefix, pattern=pattern, n_periods=body // period)


class Model:
    def __init__(self, cfg: ModelConfig, *, dtype=jnp.float32,
                 remat: bool = False, use_kernel: bool = False,
                 unroll: bool = False, attn_impl: str = "naive",
                 expert_axis: str | None = None,
                 remat_policy: str | None = None,
                 ep_mesh=None):
        cfg.validate()
        self.cfg = cfg
        self.dtype = dtype
        self.remat = remat
        self.use_kernel = use_kernel
        # attn_impl="blockwise": online-softmax over KV blocks (no S^2
        # temps) — the §Perf memory-term optimization
        self.attn_impl = attn_impl
        # expert_axis: pin MoE dispatch buffers to this mesh axis (§Perf)
        self.expert_axis = expert_axis
        # unroll=True replaces the period scan with a python loop — bigger
        # HLO, but exact cost_analysis (XLA amortizes scan-body costs);
        # used by the dry-run probes (launch/dryrun.py).
        self.unroll = unroll
        # remat_policy: None = full remat; "mixer" = save mixer outputs so
        # the backward pass does not re-run attention/SSD forward — §Perf
        self.remat_policy = remat_policy
        # ep_mesh: run MoE layers via the explicit all-to-all expert-
        # parallel schedule (models/moe_ep.py) on this mesh — §Perf
        self.ep_mesh = ep_mesh
        self.stack = build_stack(cfg)

    # ------------------------------------------------------------------ init
    def init(self, key) -> dict:
        cfg = self.cfg
        kE, kH, kP, kB, kM = jax.random.split(key, 5)
        params: dict[str, Any] = {
            "embed": layers.embed_init(kE, (cfg.vocab, cfg.d_model), self.dtype),
            "final_norm": jnp.zeros((cfg.d_model,), self.dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = layers.dense_init(kH, (cfg.d_model, cfg.vocab), dtype=self.dtype)
        if cfg.frontend == "audio":
            params["mask_emb"] = 0.02 * jax.random.normal(kM, (cfg.d_model,)).astype(self.dtype)

        params["prefix"] = [
            blocks.init_block(k, cfg, m, self.dtype)
            for k, m in zip(jax.random.split(kP, max(len(self.stack.prefix), 1)),
                            self.stack.prefix)
        ]
        # stacked periodic body: for each pattern position, stack n_periods inits
        body = []
        keys = jax.random.split(kB, self.stack.n_periods * len(self.stack.pattern))
        for p, meta in enumerate(self.stack.pattern):
            per = [
                blocks.init_block(keys[c * len(self.stack.pattern) + p], cfg, meta, self.dtype)
                for c in range(self.stack.n_periods)
            ]
            body.append(jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per))
        params["body"] = body
        return params

    # ----------------------------------------------------------------- cache
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        cache = {
            "prefix": [
                blocks.init_block_cache(cfg, m, batch, max_len, dtype)
                for m in self.stack.prefix
            ],
            "body": [
                jax.tree_util.tree_map(
                    lambda x: jnp.broadcast_to(x, (self.stack.n_periods,) + x.shape).copy(),
                    blocks.init_block_cache(cfg, meta, batch, max_len, dtype),
                )
                for meta in self.stack.pattern
            ],
        }
        return cache

    # ----------------------------------------------------------------- embed
    def embed(self, params, batch: dict) -> jnp.ndarray:
        cfg = self.cfg
        if cfg.frontend == "audio":
            x = batch["embeds"].astype(self.dtype)
            if "mask" in batch:      # masked-prediction: blank masked frames
                x = jnp.where(batch["mask"][..., None], params["mask_emb"], x)
            return x
        tok = params["embed"][batch["tokens"]]
        if cfg.frontend == "vision" and "patches" in batch:
            x = jnp.concatenate([batch["patches"].astype(self.dtype), tok], axis=1)
        else:
            x = tok
        return x

    def head(self, params, x: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
        w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = x @ w.astype(x.dtype)
        return layers.softcap(logits.astype(jnp.float32), cfg.final_softcap)

    # ----------------------------------------------------------------- apply
    def apply(self, params, batch: dict, *, cache=None, cache_index=None):
        """Returns (logits, new_cache, aux_loss)."""
        cfg = self.cfg
        x = self.embed(params, batch)
        B, S, _ = x.shape
        if cache_index is None:
            positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        else:
            positions = cache_index + jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        ci = cache_index if cache_index is not None else 0

        aux_total = jnp.zeros((), jnp.float32)
        new_prefix_caches = []
        for li, meta in enumerate(self.stack.prefix):
            c = cache["prefix"][li] if cache is not None else None
            x, nc, aux = blocks.apply_block(
                params["prefix"][li], cfg, meta, x, positions=positions,
                cache=c, cache_index=ci, use_kernel=self.use_kernel,
                    attn_impl=self.attn_impl, expert_axis=self.expert_axis,
                    ep_mesh=self.ep_mesh)
            new_prefix_caches.append(nc)
            aux_total += aux

        def period_body(carry, xs):
            x, aux_tot = carry
            params_slice, cache_slice = xs
            new_caches = []
            for p, meta in enumerate(self.stack.pattern):
                c = cache_slice[p] if cache_slice is not None else None
                x, nc, aux = blocks.apply_block(
                    params_slice[p], cfg, meta, x, positions=positions,
                    cache=c, cache_index=ci, use_kernel=self.use_kernel,
                    attn_impl=self.attn_impl, expert_axis=self.expert_axis,
                    ep_mesh=self.ep_mesh)
                new_caches.append(nc)
                aux_tot = aux_tot + aux
            return (x, aux_tot), new_caches

        if self.remat and self.remat_policy == "mixer":
            policy = jax.checkpoint_policies.save_only_these_names("mixer_out")
            body_fn = jax.checkpoint(period_body, policy=policy)
        elif self.remat:
            body_fn = jax.checkpoint(period_body)
        else:
            body_fn = period_body
        cache_xs = cache["body"] if cache is not None else None
        if self.unroll:
            carry = (x, aux_total)
            outs = []
            for c in range(self.stack.n_periods):
                sl = jax.tree_util.tree_map(lambda a: a[c], params["body"])
                csl = (jax.tree_util.tree_map(lambda a: a[c], cache_xs)
                       if cache_xs is not None else None)
                carry, nc = body_fn(carry, (sl, csl))
                outs.append(nc)
            (x, aux_total) = carry
            new_body_caches = (
                jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)
                if cache is not None else outs)
        else:
            (x, aux_total), new_body_caches = jax.lax.scan(
                body_fn, (x, aux_total), (params["body"], cache_xs))

        logits = self.head(params, x)
        new_cache = None
        if cache is not None:
            new_cache = {"prefix": new_prefix_caches, "body": new_body_caches}
        return logits, new_cache, aux_total


def make_model(cfg_or_name, *, reduced: bool = False, **kw) -> Model:
    if isinstance(cfg_or_name, str):
        from repro import configs
        cfg = configs.get(cfg_or_name, reduced=reduced)
    else:
        cfg = cfg_or_name
    return Model(cfg, **kw)
