"""Solver observability layer (DESIGN.md §19).

Three layers, from device to report:

  * :mod:`repro.obs.device`  — the on-device iteration ring riding the GP
    scan carry (``TelemetryConfig``, zero extra host syncs, bit-identical
    when off);
  * :mod:`repro.obs.metrics` / :mod:`repro.obs.spans` — host-side fleet
    metrics and nested spans with a Chrome-trace/perfetto exporter;
  * :mod:`repro.obs.report`  — ``python -m repro.obs.report`` turns a
    recorded trace (``benchmarks/online_bench.py --trace-out``) into a
    per-member convergence timeline + fleet summary under ``results/``.
"""

from repro.obs.device import (            # noqa: F401
    COLUMNS, DEFAULT_TELEMETRY, TEL_WIDTH, TelemetryConfig, empty_ring,
    records_to_dicts, resolve_telemetry, ring_overflow, ring_valid,
)
from repro.obs.metrics import Metrics, collect_compile_caches  # noqa: F401
from repro.obs.spans import Tracer, load_chrome                # noqa: F401
