"""On-device iteration telemetry: the ring riding the GP scan carry (§19).

The solver's inner loop is a jitted ``lax.scan`` with an on-device
early-stop latch (DESIGN.md §10) — by design it never syncs to host, which
also means nothing inside it is observable.  This module adds the one
mechanism that can see inside without breaking that property: a fixed-size
``(R, TEL_WIDTH)`` float32 ring buffer that travels IN the scan carry,
written once per committed iteration, and drained on host only at the
chunk boundaries the drivers already sync at.

Invariants the whole layer leans on:

  * **Zero extra host syncs.**  The ring is a carry leaf like the §15
    Anderson buffers; recording is a single masked ``.at[idx].set`` per
    iteration.  Draining happens where ``carry.done`` is already read.
  * **``telemetry=None`` is bit-identical.**  Exactly like the accel
    fields, the ring is a zero-size ``(0, TEL_WIDTH)`` placeholder when
    telemetry is off and the scan body never touches it — the compiled
    program is the same one shipped today.
  * **Telemetry ON is also trajectory-identical.**  Every recorded column
    is a value the step already computed (cost, residual, winning rung,
    Anderson verdict, phi movement); the only *new* computation is
    returning the blocked-set sweep's existing round counter.  Parity is
    asserted on the Table II scenarios (tests/test_obs.py).
  * **Write index = ``carry.iters``.**  The carry's committed-iteration
    counter increments exactly when a record is written (both are masked
    by the ``done`` freeze) and is zeroed by ``engine.reset_carry``
    alongside the ring, so records ``[0 : min(iters, R))`` are always the
    valid prefix.  Iterations past ``R`` keep counting but stop writing —
    truncation, not wrap-around, so ``iters - R`` is the exact number of
    dropped tail records (:func:`ring_overflow`).
  * **Shard-identical under ``shard_map``.**  Every column is replicated
    by construction (cost/residual/alpha/rung derive from the psum-reduced
    F/G; the sweep round counter and phi delta are pmax-reduced by the
    engine), so the ring travels with a replicated PartitionSpec and no
    per-shard gather is needed.

This module is imported by ``core/engine.py`` and therefore depends on
nothing but JAX/numpy — keep it that way.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

# Record layout: one (TEL_WIDTH,) float32 row per committed iteration.
TEL_WIDTH = 8
COL_ITER = 0        # 0-based committed-iteration index
COL_COST = 1        # committed cost after this iteration
COL_RESIDUAL = 2    # committed sufficiency residual
COL_ALPHA = 3       # stepsize the winning ladder rung used
COL_RUNG = 4        # winning rung index in the evaluated ladder
COL_ANDERSON = 5    # 1 = mix accepted, 0 = rejected, -1 = mixer off
COL_BS_ROUNDS = 6   # blocked-set frontier rounds to fixed point (-1: n/a)
COL_PHI_DELTA = 7   # max|dphi| of the committed move

COLUMNS = ("iter", "cost", "residual", "alpha", "rung", "anderson",
           "bs_rounds", "phi_delta")


class TelemetryConfig(NamedTuple):
    """Static telemetry toggles, mirroring :class:`engine.AccelConfig`.

    Hashable (ints/bools only) so it rides as a jit static argument and an
    ``lru_cache`` key for the mesh chunk programs; each distinct config
    compiles its own program, exactly like ``solver=``/``accel=``.

      ring       ring capacity in records; iterations past it are counted
                 but not recorded (truncation — see :func:`ring_overflow`)
      bs_rounds  also return the blocked-set sweep's frontier round
                 counter (the counter already exists inside the sweep
                 while-loops; this only plumbs it out)
    """

    ring: int = 256
    bs_rounds: bool = True


DEFAULT_TELEMETRY = TelemetryConfig()


def resolve_telemetry(telemetry) -> Optional[TelemetryConfig]:
    """None/False -> None (no ring, bit-identical legacy programs);
    True/"default"/"on" -> :data:`DEFAULT_TELEMETRY`; a
    :class:`TelemetryConfig` passes through."""
    if telemetry is None or telemetry is False:
        return None
    if telemetry is True or telemetry in ("default", "on"):
        return DEFAULT_TELEMETRY
    if isinstance(telemetry, TelemetryConfig):
        return telemetry
    raise TypeError(
        f"telemetry must be None/bool/'default'/TelemetryConfig, "
        f"got {telemetry!r}")


def empty_ring(telemetry: Optional[TelemetryConfig]) -> jnp.ndarray:
    """Fresh carry ring: ``(ring, TEL_WIDTH)`` zeros, ``(0, TEL_WIDTH)``
    when telemetry is off (the zero-size placeholder pattern the §15 accel
    fields use — fixed pytree structure per static config)."""
    R = telemetry.ring if telemetry is not None else 0
    return jnp.zeros((R, TEL_WIDTH), jnp.float32)


def ring_record(tb: jnp.ndarray, slot: jnp.ndarray, row: jnp.ndarray,
                write: jnp.ndarray) -> jnp.ndarray:
    """Masked ring write: put ``row`` at ``slot`` when ``write`` and the
    slot is within capacity; otherwise return the ring unchanged.

    Callers must only invoke this with a non-empty ring (telemetry on) —
    the off path never touches the placeholder.  ``slot`` saturates at the
    last index so the lane stays in bounds when the ring has overflowed;
    the ``write`` mask then keeps the stale row.
    """
    R = tb.shape[0]
    idx = jnp.minimum(slot, R - 1)
    keep = write & (slot < R)
    return tb.at[idx].set(jnp.where(keep, row, tb[idx]))


def ring_valid(tb, iters) -> np.ndarray:
    """Host-side drain: the valid record prefix ``[0 : min(iters, R))``
    as a ``(n, TEL_WIDTH)`` numpy array (copy — safe to keep after the
    carry moves on)."""
    R = int(np.asarray(tb).shape[0])
    n = min(int(iters), R)
    return np.asarray(tb[:n]).copy()


def ring_overflow(tb, iters) -> int:
    """How many committed iterations were NOT recorded (truncated tail)."""
    R = int(np.asarray(tb).shape[0])
    return max(0, int(iters) - R)


def records_to_dicts(records: np.ndarray) -> list[dict]:
    """(n, TEL_WIDTH) -> one JSON-friendly dict per record."""
    out = []
    for row in np.asarray(records):
        d = {name: float(v) for name, v in zip(COLUMNS, row)}
        d["iter"] = int(d["iter"])
        d["rung"] = int(d["rung"])
        d["bs_rounds"] = int(d["bs_rounds"])
        out.append(d)
    return out
