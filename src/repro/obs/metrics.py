"""Fleet metrics registry: counters, gauges, histograms (DESIGN.md §19).

A deliberately small, dependency-free registry the online service (and any
driver) increments on the host side — solver-level facts that do not live
inside the device programs: skip-gate hits, escalation-rung climbs, LKG
rollbacks, quarantines, fault injections, compile-cache traffic.

Names are dot-separated (``online.gate.skip``, ``faults.injected.nan_carry``)
so exports group naturally.  Exports are plain JSON / JSONL; the span layer
(:mod:`repro.obs.spans`) mirrors counters into Chrome-trace ``"C"`` events
when a tracer is attached.
"""

from __future__ import annotations

import json
import math
from typing import Optional


class Metrics:
    """In-process metrics registry.

    ``counter`` accumulates, ``gauge`` overwrites, ``observe`` appends to a
    histogram (summarized at export: count/sum/min/max/mean/p50/p90/p99).
    """

    def __init__(self):
        self.counters: dict[str, float] = {}
        self.gauges: dict[str, float] = {}
        self.histograms: dict[str, list[float]] = {}

    def counter(self, name: str, inc: float = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + inc

    def gauge(self, name: str, value: float) -> None:
        self.gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        self.histograms.setdefault(name, []).append(float(value))

    @staticmethod
    def _summary(vals: list[float]) -> dict:
        s = sorted(vals)
        n = len(s)

        def pct(p: float) -> float:
            return s[min(n - 1, int(math.ceil(p * n)) - 1)] if n else 0.0

        return {"count": n, "sum": sum(s),
                "min": s[0] if n else 0.0, "max": s[-1] if n else 0.0,
                "mean": (sum(s) / n) if n else 0.0,
                "p50": pct(0.50), "p90": pct(0.90), "p99": pct(0.99)}

    def snapshot(self) -> dict:
        """One JSON-serializable view of everything recorded so far."""
        return {
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "histograms": {k: self._summary(v)
                           for k, v in self.histograms.items()},
        }

    def export_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1, sort_keys=True)

    def export_jsonl(self, path: str) -> None:
        """One line per metric — the stream-friendly export."""
        with open(path, "w") as f:
            for name, v in sorted(self.counters.items()):
                f.write(json.dumps(
                    {"kind": "counter", "name": name, "value": v}) + "\n")
            for name, v in sorted(self.gauges.items()):
                f.write(json.dumps(
                    {"kind": "gauge", "name": name, "value": v}) + "\n")
            for name, vals in sorted(self.histograms.items()):
                f.write(json.dumps(
                    {"kind": "histogram", "name": name,
                     **self._summary(vals)}) + "\n")


def collect_compile_caches(metrics: Optional[Metrics]) -> dict:
    """Gauge the solver's compile caches into ``metrics`` (and return them).

    Two cache layers matter for online-service latency (a miss is a full
    XLA compile in the event's critical path):

      * ``compile.mesh_chunk.{hits,misses,entries}`` — the
        ``functools.lru_cache`` on ``distributed._chunk_program`` (one
        entry per mesh x chunk-config combination);
      * ``compile.jit.<name>_entries`` — tracing-cache sizes of the jitted
        single-device chunk programs (one entry per static-arg combination;
        jit exposes no hit/miss counts, so entry growth is the signal).
    """
    out: dict[str, float] = {}
    try:
        from repro.core import distributed, gp
        info = distributed._chunk_program.cache_info()
        out["compile.mesh_chunk.hits"] = float(info.hits)
        out["compile.mesh_chunk.misses"] = float(info.misses)
        out["compile.mesh_chunk.entries"] = float(info.currsize)
        for name in ("_scan_chunk", "_scan_chunk_batched", "_jit_step"):
            fn = getattr(gp, name, None)
            if fn is not None and hasattr(fn, "_cache_size"):
                out[f"compile.jit.{name}_entries"] = float(fn._cache_size())
    except Exception:
        pass  # cache introspection is best-effort telemetry, never fatal
    if metrics is not None:
        for k, v in out.items():
            metrics.gauge(k, v)
    return out
