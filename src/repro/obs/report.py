"""Trace -> report: per-member convergence timelines + fleet summary (§19).

    PYTHONPATH=src python -m repro.obs.report --trace PREFIX \
        [--out results/obs_report.json] \
        [--check-bench BENCH_gp.json --scenario fig6-trace50]

``PREFIX`` names the artifact family ``benchmarks/online_bench.py
--trace-out`` writes (``PREFIX.events.jsonl`` is required;
``PREFIX.iters.jsonl`` / ``PREFIX.metrics.json`` / ``PREFIX.trace.json``
enrich the report when present).  The generator distills them into one
JSON report:

  * **per-member timeline** — for every fleet member, the ordered events
    it handled (iterations, cost, residual, status, rungs, wall clock)
    plus the member's per-iteration residual/cost trajectory grouped by
    solve segment from the device telemetry ring;
  * **fleet summary** — event/iteration totals, status and event-type
    tallies, skip-gate and rollback counts, escalation-rung spend,
    wall-clock attribution from the span trace, telemetry-ring drops.

``--check-bench`` cross-checks the report against a committed
``BENCH_gp.json``: the summed per-event iteration count from the recorded
trace must equal the ``iters`` field of the matching online row — the
telemetry pipeline reproducing the committed perf trajectory end-to-end
is the §19 acceptance criterion, and a mismatch means dropped or
double-drained segments.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Optional


def _read_jsonl(path: str) -> list[dict]:
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def load_trace(prefix: str) -> dict:
    """Load the ``--trace-out`` artifact family rooted at ``prefix``.

    Returns ``{"events": [...], "iters": [...], "metrics": {...},
    "spans": [...]}`` — ``events`` is required (raises
    ``FileNotFoundError`` when absent), the rest default to empty.
    """
    ev_path = prefix + ".events.jsonl"
    if not os.path.exists(ev_path):
        raise FileNotFoundError(
            f"{ev_path} not found — run the bench with --trace-out {prefix}")
    out = {"events": _read_jsonl(ev_path), "iters": [], "metrics": {},
           "spans": []}
    it_path = prefix + ".iters.jsonl"
    if os.path.exists(it_path):
        out["iters"] = _read_jsonl(it_path)
    m_path = prefix + ".metrics.json"
    if os.path.exists(m_path):
        with open(m_path) as f:
            out["metrics"] = json.load(f)
    t_path = prefix + ".trace.json"
    if os.path.exists(t_path):
        with open(t_path) as f:
            obj = json.load(f)
        out["spans"] = (obj["traceEvents"]
                        if isinstance(obj, dict) else obj)
    return out


def _member_segments(iters: list[dict], member: int) -> list[dict]:
    """The member's solve segments, each with its iteration trajectory."""
    segs: dict[int, dict] = {}
    for rec in iters:
        if rec.get("member") != member:
            continue
        seg = segs.setdefault(rec["segment"], {
            "segment": rec["segment"], "event": rec.get("event"),
            "phase": rec.get("phase"), "recorded": 0,
            "residual": [], "cost": []})
        seg["recorded"] += 1
        seg["residual"].append(rec.get("residual"))
        seg["cost"].append(rec.get("cost"))
    return [segs[k] for k in sorted(segs)]


def build_report(trace: dict) -> dict:
    """Distill loaded trace streams into the report dict (see module doc)."""
    events, iters = trace["events"], trace["iters"]
    members = sorted({e["member"] for e in events}
                     | {r["member"] for r in iters})

    timelines = []
    for b in members:
        evs = [e for e in events if e["member"] == b]
        timelines.append({
            "member": b,
            "events": [{k: e.get(k) for k in (
                "t", "event", "iterations", "cost", "residual", "status",
                "rungs", "rung_iters", "wall_s", "solved_apps",
                "skipped_apps", "cold_restart", "rolled_back", "shed")}
                for e in evs],
            "total_iters": sum(e["iterations"] for e in evs),
            "segments": _member_segments(iters, b),
        })

    statuses: dict[str, int] = {}
    event_types: dict[str, int] = {}
    rung_iters: dict[str, int] = {}
    for e in events:
        statuses[e.get("status", "?")] = statuses.get(e.get("status", "?"),
                                                      0) + 1
        event_types[e["event"]] = event_types.get(e["event"], 0) + 1
        for rung, spend in zip(e.get("rungs", ()),
                               e.get("rung_iters", ())):
            rung_iters[rung] = rung_iters.get(rung, 0) + int(spend)

    # wall-clock attribution: top-level event spans vs inner solve phases
    span_s: dict[str, float] = {}
    for s in trace["spans"]:
        if s.get("ph") == "X":
            key = s["name"].split(":")[0]
            span_s[key] = span_s.get(key, 0.0) + s.get("dur", 0.0) / 1e6

    counters = trace["metrics"].get("counters", {})
    cold_iters = sum(r.get("iter") is not None for r in iters
                     if r.get("event") == -1)
    summary = {
        "n_members": len(members),
        "n_events": len(events),
        "event_iters": sum(e["iterations"] for e in events),
        "cold_start_iters_recorded": cold_iters,
        "iters_recorded": len(iters),
        "ring_dropped": counters.get("telemetry.ring.dropped", 0),
        "statuses": statuses,
        "event_types": event_types,
        "rung_iters": rung_iters,
        "gate_skips": counters.get("online.gate.skip", 0),
        "rollbacks": counters.get("online.rollback", 0),
        "quarantines": counters.get("online.quarantine", 0),
        "wall_s_by_span": {k: round(v, 4)
                           for k, v in sorted(span_s.items())},
        "wall_s_total": round(sum(e.get("wall_s", 0.0) for e in events), 4),
    }
    return {"summary": summary, "members": timelines}


def check_bench(report: dict, bench_rows: list[dict], scenario: str
                ) -> list[str]:
    """Cross-check the report against committed online bench rows.

    The recorded trace must reproduce the committed event-level iteration
    count exactly: ``sum(iterations over events.jsonl)`` == the ``iters``
    field of the (online, ``scenario``, online/online-chaos) row.  Returns
    human-readable failure lines (empty = check passes).
    """
    rows = [r for r in bench_rows
            if r.get("bench") == "online" and r.get("scenario") == scenario
            and r.get("solver") in ("online", "online-chaos")]
    if not rows:
        return [f"no committed online row for scenario {scenario!r}"]
    failures = []
    got = report["summary"]["event_iters"]
    for row in rows:
        want = int(row.get("iters", -1))
        if got != want:
            failures.append(
                f"{scenario}/{row['solver']}: trace records {got} event "
                f"iterations but the committed row says {want}")
    return failures


def _print_summary(report: dict) -> None:
    s = report["summary"]
    print(f"fleet: {s['n_members']} members, {s['n_events']} events, "
          f"{s['event_iters']} event iters "
          f"(+{s['cold_start_iters_recorded']} cold-start recorded)")
    print(f"statuses:    {s['statuses']}")
    print(f"event types: {s['event_types']}")
    if s["rung_iters"]:
        print(f"rung spend:  {s['rung_iters']}")
    print(f"gate skips: {s['gate_skips']}  rollbacks: {s['rollbacks']}  "
          f"quarantines: {s['quarantines']}  "
          f"ring drops: {s['ring_dropped']}")
    if s["wall_s_by_span"]:
        print(f"wall clock by span: {s['wall_s_by_span']}")
    for m in report["members"]:
        segs = len(m["segments"])
        print(f"  member {m['member']}: {len(m['events'])} events, "
              f"{m['total_iters']} iters, {segs} telemetry segments")


def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="repro.obs.report")
    ap.add_argument("--trace", required=True, metavar="PREFIX",
                    help="artifact prefix written by --trace-out")
    ap.add_argument("--out", default=None,
                    help="report JSON path (default: results/"
                         "obs_report_<basename>.json)")
    ap.add_argument("--check-bench", default=None, metavar="BENCH_JSON",
                    help="committed BENCH_gp.json to cross-check against")
    ap.add_argument("--scenario", default="fig6-trace50",
                    help="online bench scenario for --check-bench")
    args = ap.parse_args(argv)

    trace = load_trace(args.trace)
    report = build_report(trace)
    _print_summary(report)

    out = args.out
    if out is None:
        root = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))))
        base = os.path.basename(args.trace.rstrip("/")) or "trace"
        out = os.path.join(root, "results", f"obs_report_{base}.json")
    os.makedirs(os.path.dirname(os.path.abspath(out)), exist_ok=True)
    with open(out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"report: {out}")

    if args.check_bench:
        with open(args.check_bench) as f:
            rows = json.load(f)["rows"]
        failures = check_bench(report, rows, args.scenario)
        if failures:
            for line in failures:
                print(f"CHECK FAILED {line}")
            return 1
        print(f"check-bench: OK — trace reproduces the committed "
              f"{args.scenario} iteration count")
    return 0


if __name__ == "__main__":
    sys.exit(main())
