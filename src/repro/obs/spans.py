"""Structured spans with a Chrome-trace/perfetto exporter (DESIGN.md §19).

The online service's event handling is a small tree of phases —
event -> converge -> (ladder rung)* -> rollback — whose wall-clock
attribution is exactly what a trace viewer is built for.  This module
records nested spans on the host side and exports them in the Chrome
trace-event format (the JSON flavour https://ui.perfetto.dev and
chrome://tracing both load):

  * ``ph: "X"`` complete events — one per finished span, microsecond
    ``ts``/``dur``, ``tid`` = fleet member (so each member renders as its
    own track), ``pid`` = 1;
  * ``ph: "i"`` instant events — point markers (rollbacks, injections);
  * ``ph: "C"`` counter events — numeric series over time;
  * ``ph: "M"`` metadata — process/thread names.

Spans nest per (pid, tid) by plain stack discipline: the exporter emits
them as complete events and the viewer reconstructs the nesting from
containment, so the only requirement is that a child closes before its
parent (guaranteed by the context manager).  The JSONL export mirrors the
same records one-per-line for programmatic consumers
(:mod:`repro.obs.report`).
"""

from __future__ import annotations

import contextlib
import json
import time
from typing import Optional


class Tracer:
    """Host-side span recorder.

    ``clock`` is injectable for tests (must be monotonic, in seconds).
    All public methods are cheap enough for per-event (not per-iteration)
    call sites; per-iteration data belongs to the device ring
    (:mod:`repro.obs.device`).
    """

    def __init__(self, clock=time.perf_counter):
        self._clock = clock
        self._t0 = clock()
        self._stack: dict[int, list[dict]] = {}   # tid -> open spans
        self.events: list[dict] = []              # finished, in close order

    def _us(self) -> float:
        return (self._clock() - self._t0) * 1e6

    @contextlib.contextmanager
    def span(self, name: str, *, tid: int = 0, **args):
        """Context manager recording one complete ("X") span."""
        rec = {"name": name, "ph": "X", "pid": 1, "tid": int(tid),
               "ts": self._us(), "args": {k: _jsonable(v)
                                          for k, v in args.items()}}
        stack = self._stack.setdefault(int(tid), [])
        rec["depth"] = len(stack)
        stack.append(rec)
        try:
            yield rec
        finally:
            rec["dur"] = self._us() - rec["ts"]
            stack.pop()
            self.events.append(rec)

    def instant(self, name: str, *, tid: int = 0, **args) -> None:
        """Point marker ("i" event) — rollbacks, injections, drains."""
        self.events.append(
            {"name": name, "ph": "i", "pid": 1, "tid": int(tid), "s": "t",
             "ts": self._us(), "args": {k: _jsonable(v)
                                        for k, v in args.items()}})

    def counter(self, name: str, value: float, *, tid: int = 0) -> None:
        """Numeric series sample ("C" event) — renders as a track graph."""
        self.events.append(
            {"name": name, "ph": "C", "pid": 1, "tid": int(tid),
             "ts": self._us(), "args": {name.rsplit(".", 1)[-1]:
                                        float(value)}})

    # -- exports ---------------------------------------------------------

    def to_chrome(self, *, process_name: str = "repro.online",
                  tid_names: Optional[dict] = None) -> dict:
        """The Chrome trace-event JSON object (``{"traceEvents": [...]}``).

        Emits metadata names first, then every recorded event sorted by
        ``ts`` (viewers do not require the sort, but diff-friendly output
        does).  Open spans are not exported — close them first.
        """
        meta = [{"name": "process_name", "ph": "M", "pid": 1,
                 "args": {"name": process_name}}]
        for tid, label in sorted((tid_names or {}).items()):
            meta.append({"name": "thread_name", "ph": "M", "pid": 1,
                         "tid": int(tid), "args": {"name": str(label)}})
        events = []
        for e in sorted(self.events, key=lambda e: e["ts"]):
            out = {k: v for k, v in e.items() if k != "depth"}
            events.append(out)
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def export_chrome(self, path: str, **kw) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(**kw), f, indent=1)

    def export_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for e in sorted(self.events, key=lambda e: e["ts"]):
                f.write(json.dumps(e) + "\n")


def load_chrome(path: str) -> list[dict]:
    """Load a Chrome-trace JSON file back into its event list."""
    with open(path) as f:
        obj = json.load(f)
    return obj["traceEvents"] if isinstance(obj, dict) else obj


def _jsonable(v):
    """Span args must survive json.dumps — stringify anything exotic."""
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    try:
        return float(v)
    except (TypeError, ValueError):
        return str(v)
