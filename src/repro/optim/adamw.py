"""AdamW, implemented in-house (no optax in this environment).

Supports a ``moment_dtype`` knob: float32 for exactness or bfloat16 to
halve optimizer memory (the DeepSeek-V3-scale dry-runs are optimizer-state
bound; see EXPERIMENTS.md §Dry-run).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any          # first moments  (pytree like params)
    nu: Any          # second moments


def adamw_init(params, moment_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
    )


def adamw_update(params, grads, state: AdamWState, *, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1, grad_clip=1.0):
    """Returns (new_params, new_state, grad_norm)."""
    # global-norm clip
    sq = jax.tree_util.tree_map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), grads)
    gnorm = jnp.sqrt(jax.tree_util.tree_reduce(jnp.add, sq))
    scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m_new = b1 * m32 + (1 - b1) * g
        v_new = b2 * v32 + (1 - b2) * g * g
        mhat, vhat = m_new / c1, v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), gnorm
