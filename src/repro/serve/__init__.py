"""Serving layer: the transformer serve engine and the online GP service.

Imports are lazy: ``serve.engine`` pulls in the transformer model stack,
which ``serve.online`` (pure solver service) does not need — importing one
must not pay for the other.
"""

from typing import TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover - static analysis only
    from repro.serve.engine import ServeEngine, make_serve_step  # noqa: F401
    from repro.serve.online import (EventReport, FleetHealth,  # noqa: F401
                                    HealthReport, OnlineSolver)

_ENGINE = ("ServeEngine", "make_serve_step", "make_prefill_step", "Request")
_ONLINE = ("OnlineSolver", "EventReport", "HealthReport", "FleetHealth")

__all__ = list(_ENGINE + _ONLINE)


def __getattr__(name):
    if name in _ENGINE:
        from repro.serve import engine
        return getattr(engine, name)
    if name in _ONLINE:
        from repro.serve import online
        return getattr(online, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
