"""Serving engine: batched prefill + single-token decode with KV caches.

``make_serve_step`` builds the jittable decode step that the decode-shape
dry-runs lower: ONE new token per request against a ``seq_len``-long cache
(the assignment's decode_32k / long_500k shapes).  ``ServeEngine`` is the
host-side continuous-batching wrapper used by the serving example.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import Model


def make_serve_step(model: Model, *, greedy: bool = True, temperature: float = 1.0):
    """decode step: (params, cache, tokens (B,1), cache_index) ->
    (next_tokens (B,1), new_cache, logits)."""

    def serve_step(params, cache, tokens, cache_index, rng=None):
        batch = {"tokens": tokens}
        logits, new_cache, _ = model.apply(
            params, batch, cache=cache, cache_index=cache_index)
        last = logits[:, -1]
        if greedy:
            nxt = jnp.argmax(last, axis=-1)
        else:
            nxt = jax.random.categorical(rng, last / temperature, axis=-1)
        return nxt[:, None].astype(jnp.int32), new_cache, last

    return serve_step


def make_prefill_step(model: Model):
    def prefill_step(params, cache, batch):
        logits, new_cache, _ = model.apply(params, batch, cache=cache,
                                           cache_index=jnp.int32(0))
        return logits, new_cache

    return prefill_step


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray
    max_new: int
    out: list = dataclasses.field(default_factory=list)


class ServeEngine:
    """Minimal continuous-batching engine (fixed batch slots).

    Slots hold independent requests; decode advances all active slots in one
    jitted step.  Finished slots are refilled from the queue — the standard
    "continuous batching" pattern, at flow-level fidelity (matching how the
    paper's service chains treat request streams).
    """

    def __init__(self, model: Model, params, *, slots: int = 4, max_len: int = 512):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.cache = model.init_cache(slots, max_len, dtype=jnp.float32)
        self.positions = np.zeros(slots, np.int64)
        self.active: list[Optional[Request]] = [None] * slots
        self.queue: list[Request] = []
        self._decode = jax.jit(make_serve_step(model))
        self._uid = 0

    def submit(self, prompt: np.ndarray, max_new: int = 16) -> int:
        self._uid += 1
        self.queue.append(Request(self._uid, np.asarray(prompt), max_new))
        return self._uid

    def _fill_slots(self):
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.pop(0)
                self.active[s] = req
                # prefill this slot token-by-token via the decode step
                # (single-slot prefill keeps cache layouts identical)
                for t in req.prompt:
                    tok = jnp.zeros((self.slots, 1), jnp.int32).at[s, 0].set(int(t))
                    _, self.cache, _ = self._decode(
                        self.params, self.cache, tok, jnp.int32(self.positions[s]))
                    self.positions[s] += 1

    def step(self) -> list[tuple[int, int]]:
        """One decode step over all active slots; returns finished uids."""
        self._fill_slots()
        if not any(self.active):
            return []
        last_tokens = np.zeros((self.slots, 1), np.int32)
        for s, req in enumerate(self.active):
            if req is not None:
                last_tokens[s, 0] = req.out[-1] if req.out else req.prompt[-1]
        # NOTE: per-slot cache_index; we advance the max and mask per-slot in
        # the engine (flow-level simplification: slots stay position-aligned
        # per request because prefill wrote at the true positions).
        nxt, self.cache, _ = self._decode(
            self.params, self.cache, jnp.asarray(last_tokens),
            jnp.int32(int(self.positions.max())))
        finished = []
        for s, req in enumerate(self.active):
            if req is None:
                continue
            req.out.append(int(nxt[s, 0]))
            self.positions[s] += 1
            if len(req.out) >= req.max_new:
                finished.append((req.uid, req.out))
                self.active[s] = None
        return finished

    def run(self) -> dict[int, list]:
        done = {}
        while any(self.active) or self.queue:
            for uid, out in self.step():
                done[uid] = out
        return done
