"""OnlineSolver: the GP solver as a long-running service (DESIGN.md §16/§17).

The paper's Section IV closes by noting the distributed algorithm "adapts
to changes in input rates and network topology, and can be implemented as
an online algorithm".  This module is that claim as a subsystem: a
device-resident engine that holds the *live* forwarding/offloading strategy
for a fleet of problem instances and re-converges incrementally as typed
events (``core/events.py``) stream in.

Architecture — everything rides the existing batched machinery:

  * **Fleet state** — members are padded to one envelope
    (``events.pad_fleet``, §9 invariants) and stacked into a single batched
    Instance pytree; the live solver state is one batched
    ``engine.ScanCarry`` whose ``phi`` is the fleet's current strategy.
  * **Event ingestion** — ``apply_event`` rewrites the member's instance
    *in place in the envelope* (no shape changes), so every re-convergence
    reuses the same compiled chunk programs as ``gp.solve_batched``.
  * **Warm start + phi repair** — re-convergence starts from the live
    strategy; after topology events ``traffic.repair_phi`` masks dead
    directions and reseeds emptied rows before the solver touches it.
  * **Skip gates** — two levels.  Fleet members an event did not touch
    never enter the device program (members are uncoupled).  Within the
    touched member, ``conditions.per_app_residual`` is the gate: only
    applications whose problem data changed, whose strategy carried mass on
    a failed link, or whose sufficiency residual exceeds ``gate_tol`` are
    unfrozen (``app_mask``); everyone else's strategy is provably optimal
    already (condition (6) per app) and is frozen — their flows still count
    in the shared F/G measurement, so the restricted solve is exact.  After
    convergence the gate re-checks *all* apps and unfreezes any that
    drifted (congestion moved under them); re-convergence repeats until the
    full fleet member satisfies the residual, so final costs match a cold
    solve.
  * **Acceleration carry (§15)** — across *small rate deltas* (factor in
    ``events.SMALL_RATE_WINDOW``) the Anderson window and adaptive stepsize
    survive the event (``engine.reset_carry(keep_window=True)``): the
    stored (x, f) pairs are stale but the scan body's safeguard costs every
    mix under the NEW instance, so descent is preserved and the window
    still cuts iterations.  Topology/app churn clears the window.

Fault tolerance (DESIGN.md §17) — the service guarantees it never serves a
strategy worse than its last known good one:

  * **Last-known-good checkpoints** — every member keeps an incumbent
    (phi, cost, residual).  The incumbent is repaired alongside the live
    strategy on topology events and *re-costed under the current instance
    on every event*, so "served cost <= incumbent cost" is an invariant
    the service can always check — and enforce by rolling back.
  * **Escalation ladder** — when a re-convergence ends non-finite, worse
    than the incumbent, or exhausts its full iteration budget without the
    residual certificate, the watchdog climbs: warm retry (Anderson window
    kept) → window-cleared warm retry → cold restart → SPOC/LCOF
    baseline-mask fallback (``baselines.fallback_strategy`` — always
    feasible, admission-safe), each rung on a backoff budget.  The best
    finite candidate is served iff it beats the incumbent; otherwise the
    incumbent is served (rollback).  ``HealthReport.status`` records the
    outcome: ``converged`` / ``capped`` / ``degraded`` / ``rolled_back`` /
    ``rejected``.
  * **Runtime invariants** — ``verify_fleet`` measures simplex rows, stray
    mass on dead links/apps/CPUs, cost finiteness and capacity slack per
    member (``traffic.strategy_violations`` + ``traffic.capacity_slack``).
    With ``debug=True`` it runs after every event and a *corrupt* member
    (invariant violation, not mere saturation) is quarantined onto the
    baseline-mask strategy instead of poisoning the batched carry.
  * **Fault injection** — ``fault_injector=faults.FaultInjector(...)``
    corrupts the member's carry at the solve boundary before each event
    (non-finite entries, de-normalized rows), exercising exactly these
    recovery paths; ``benchmarks/online_bench.py --chaos`` drives a
    100-event ``faults.chaos_trace`` through them and records ladder hit
    counts as a BENCH_gp.json chaos row.

Example::

    >>> insts = [network.table_ii_instance("abilene", rate_scale=s)
    ...          for s in (0.5, 1.0)]
    >>> solver = OnlineSolver(insts, spare_apps=1, alpha=0.1, accel=True)
    >>> rep = solver.process(events.RateScale(member=0, factor=1.5, app=0))
    >>> rep.iterations < solver.cold_iters[0]          # doctest: +SKIP
    True

``benchmarks/online_bench.py`` drives a 50-event trace over the fig6
family and records cost parity (<= 1e-4) and the warm/cold iteration ratio
as BENCH_gp.json online rows; ``tests/test_online.py`` pins the semantics.
"""

from __future__ import annotations

import dataclasses
import time
from contextlib import nullcontext
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (baselines, batch, conditions, engine, events, gp,
                        traffic)
from repro.core.network import Instance
from repro.core.traffic import Phi
from repro.obs.device import records_to_dicts, ring_overflow, ring_valid

# Corrupt-class invariant thresholds (DESIGN.md §17): the GP projection and
# repair_phi keep simplex rows normalized to float32 roundoff (~1e-6) and
# place exactly zero mass on dead directions, so anything past these is
# state corruption, not numerical drift.
FEAS_TOL = 1e-3
MASS_TOL = 1e-4


@dataclasses.dataclass(frozen=True)
class EventReport:
    """What one event cost the service.

    ``iterations`` counts GP iterations actually committed for this event
    (0 when every live app passed the skip gate); ``solved_apps`` /
    ``skipped_apps`` split the member's live applications into gate-opened
    and gate-frozen; ``unfroze`` counts apps the post-convergence re-check
    promoted from frozen to solved (congestion drift); ``repaired`` /
    ``kept_window`` record the phi-repair and Anderson-carry decisions;
    ``converged`` is the solver's convergence certificate (residual within
    tol, or the §15 phi fixed-point latch) — False means the served
    strategy is best-effort (budget cap / stall), not provably stationary.
    """

    event: events.Event
    member: int
    iterations: int
    cost: float
    residual: float
    solved_apps: int
    skipped_apps: int
    unfroze: int
    repaired: bool
    kept_window: bool
    cold_restart: bool = False
    converged: bool = True


@dataclasses.dataclass(frozen=True)
class HealthReport(EventReport):
    """EventReport plus the §17 guardrail verdict.

    ``status`` is the service-level outcome:

      * ``converged``   — GP result served, residual certificate holds
      * ``capped``      — GP result served best-effort (budget exhausted /
                          stalled above ``gate_tol``) but finite and no
                          worse than the incumbent
      * ``degraded``    — a baseline-mask (SPOC/LCOF) strategy is being
                          served (ladder floor or quarantine)
      * ``rolled_back`` — the last-known-good incumbent is being served
                          because every fresh candidate was worse
      * ``rejected``    — nothing finite exists, not even the incumbent;
                          the incumbent strategy is parked best-effort

    ``rungs`` lists the escalation-ladder rungs climbed (empty on the
    healthy path); ``incumbent_cost`` is the last-known-good cost re-costed
    under the post-event instance — the bound served costs are held to.
    """

    status: str = "converged"
    rungs: tuple = ()
    incumbent_cost: float = float("nan")
    rolled_back: bool = False
    quarantined: bool = False
    injected: Optional[str] = None
    shed: tuple = ()
    # watchdog accounting (§19): ``rung_iters`` is the per-rung iteration
    # spend, parallel to ``rungs`` (empty on the healthy path); ``wall_s``
    # is the host wall-clock the whole event took, solve + guardrails.
    rung_iters: tuple = ()
    wall_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class FleetHealth:
    """One member's runtime invariant measurements (``verify_fleet``)."""

    member: int
    simplex: float          # max |strategy row sum - expected|
    dead_link_mass: float   # max phi.e mass on absent links
    dead_app_mass: float    # max mass on dead/padded app rows
    cpu_mass: float         # max phi.c where offloading is disallowed
    nonfinite: bool         # any non-finite phi entry
    cost: float             # the cost being served
    capacity_slack: float   # min over links of theta*cap - F (inf: LINEAR)

    @property
    def corrupt(self) -> bool:
        """Invariant violation (state corruption) — quarantine-worthy."""
        return bool(self.nonfinite or not np.isfinite(self.cost)
                    or self.simplex > FEAS_TOL
                    or self.dead_link_mass > MASS_TOL
                    or self.dead_app_mass > MASS_TOL
                    or self.cpu_mass > MASS_TOL)

    @property
    def saturated(self) -> bool:
        """Load past the modelled M/M/1 region — reported, NOT corrupt
        (the quadratic cost extension keeps it finite and recoverable)."""
        return bool(self.capacity_slack < 0)


class OnlineSolver:
    """Device-resident online GP service over a fleet of instances.

    Parameters mirror ``gp.solve`` (alpha/tol/patience/max_iters/solver/
    blocked/accel apply to every re-convergence).  ``spare_apps`` reserves
    dead application slots per member for :class:`events.AppArrival`;
    ``gate_tol`` (default: ``tol``) is the per-app residual threshold of
    the skip gate — apps below it are provably within tolerance of
    stationary and are frozen; ``carry_window=False`` disables the §15
    Anderson-window carry across small rate deltas (ablation hook).

    Fault-tolerance knobs (§17): ``rollback_margin`` is the relative slack
    a served cost may exceed the incumbent by before the watchdog
    escalates; ``debug=True`` runs ``verify_fleet`` after every event and
    quarantines corrupt members; ``fault_injector`` (a
    ``faults.FaultInjector``) corrupts the member's carry before each
    event, for chaos testing.

    Construction cold-solves the whole fleet in one batched program;
    per-member cold iteration counts are kept in ``cold_iters`` as the
    warm-start baseline.  ``process`` ingests one event, ``step`` a list.
    """

    def __init__(
        self,
        insts: Sequence[Instance],
        *,
        spare_apps: int = 0,
        alpha: float = 0.02,
        tol: float = 1e-4,
        gate_tol: Optional[float] = None,
        max_iters: int = 400,
        patience: int = 40,
        solver: str = "auto",
        blocked: str = "bitset",
        accel=True,
        carry_window: bool = True,
        max_unfreeze_rounds: int = 4,
        plateau_res: Optional[float] = None,
        rollback_margin: float = 1e-4,
        debug: bool = False,
        fault_injector=None,
        telemetry=None,
        metrics=None,
        tracer=None,
    ):
        self._members = events.pad_fleet(insts, spare_apps=spare_apps)
        self.binst: Instance = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *self._members)
        self.B = len(self._members)
        self.tol = float(tol)
        self.gate_tol = float(tol if gate_tol is None else gate_tol)
        self.max_iters = int(max_iters)
        self.patience = int(patience)
        self.solver = solver
        self.blocked = blocked
        self.carry_window = bool(carry_window)
        self.max_unfreeze_rounds = int(max_unfreeze_rounds)
        # Warm-start plateau detector (see _converge): a repaired strategy
        # can itself be a *spurious* near-fixed point of the GP map — the
        # residual starts tiny but the ladder crawls on micro-improvements
        # for hundreds of iterations (healthy warm starts begin at residual
        # ~1e-1 and drop fast).  If after the first chunk the member is not
        # done yet its residual is already below this, restarting cold is
        # strictly faster AND lands on the same optimum as the cold
        # baseline, preserving cost parity.
        self.plateau_res = float(20 * tol if plateau_res is None else plateau_res)
        self.rollback_margin = float(rollback_margin)
        self.debug = bool(debug)
        self.fault_injector = fault_injector
        # §19 observability hooks, all optional and off by default:
        # ``telemetry`` turns on the on-device iteration ring (drained into
        # ``iter_trace`` at the chunk boundaries the service already syncs
        # on); ``metrics`` (a repro.obs.Metrics) takes fleet counters;
        # ``tracer`` (a repro.obs.Tracer) records nested event spans.
        self._telemetry = engine.resolve_telemetry(telemetry)
        self.metrics = metrics
        self.tracer = tracer
        self.iter_trace: list[dict] = []
        self._segments = 0                 # drained solve segments
        self._accel = engine.resolve_accel(accel)
        self._alpha = jnp.float32(alpha)
        self._tol = jnp.float32(tol)
        self._patience = jnp.int32(patience)
        self._max_iters = jnp.int32(max_iters)
        self._residual_fn = jax.jit(conditions.per_app_residual)
        # per-event guardrail measurements run OUTSIDE the scan programs;
        # eager dispatch of the whole flow computation costs more than the
        # event's solve on small instances, so both are jitted once here
        self._cost_fn = jax.jit(
            lambda i, p: traffic.total_cost(i, p, solver=solver))
        self._health_fn = jax.jit(
            lambda i, p: (traffic.strategy_violations(i, p),
                          traffic.capacity_slack(
                              i, traffic.flows(i, p, solver=solver).F)))

        phi0 = jax.vmap(gp.init_phi)(self.binst)
        self.carry: engine.ScanCarry = jax.vmap(
            lambda i, p: engine.init_carry(i, p, accel=self._accel,
                                           telemetry=self._telemetry)
        )(self.binst, phi0)

        self.total_iters = 0                       # all committed iterations
        self.reports: list[EventReport] = []
        self.ladder_hits: dict[str, int] = {}      # escalation-rung counters
        self.quarantines = 0
        self.cold_iters, _ = self._converge(list(range(self.B)),
                                            phase="cold-start")
        self.event_iters = 0                       # iterations after cold start
        # Last-known-good checkpoints: the cold solve is the first LKG.
        self._lkg_phi: list[Phi] = [self.phi(b) for b in range(self.B)]
        self._lkg_cost: list[float] = [float(c) for c in self.costs()]
        self._lkg_residual: list[float] = [float(r) for r in self.residuals()]
        self._lkg_cert: list[bool] = [self._certificate(b)
                                      for b in range(self.B)]

    # -- fleet state accessors ------------------------------------------

    def member(self, b: int) -> Instance:
        """Member ``b``'s current (padded) problem instance."""
        return self._members[b]

    def phi(self, b: int) -> Phi:
        """Member ``b``'s live strategy (padded to the fleet envelope)."""
        return jax.tree_util.tree_map(lambda x: x[b], self.carry.phi)

    def costs(self) -> np.ndarray:
        """(B,) current aggregate delay of every fleet member."""
        return np.asarray(self.carry.cost)

    def residuals(self) -> np.ndarray:
        """(B,) per-member sufficiency residual of the live strategies."""
        out = np.zeros(self.B, np.float32)
        for b in range(self.B):
            res = np.asarray(self._residual_fn(self._members[b], self.phi(b)))
            out[b] = res.max(initial=0.0)
        return out

    def incumbent(self, b: int) -> tuple[Phi, float]:
        """Member ``b``'s last-known-good (phi, cost) checkpoint."""
        return self._lkg_phi[b], self._lkg_cost[b]

    # -- runtime invariants (§17) ---------------------------------------

    def verify_member(self, b: int) -> FleetHealth:
        """Measure member ``b``'s strategy against the §17 invariants."""
        inst_b = self._members[b]
        phi_b = self.phi(b)
        sv, slack = self._health_fn(inst_b, phi_b)
        if bool(sv.nonfinite):
            slack = float("nan")       # flows of a NaN strategy are noise
        else:
            slack = float(slack)
        return FleetHealth(
            member=b,
            simplex=float(sv.simplex),
            dead_link_mass=float(sv.dead_link_mass),
            dead_app_mass=float(sv.dead_app_mass),
            cpu_mass=float(sv.cpu_mass),
            nonfinite=bool(sv.nonfinite),
            cost=float(self.carry.cost[b]),
            capacity_slack=slack,
        )

    def verify_fleet(self, members: Optional[Sequence[int]] = None
                     ) -> list[FleetHealth]:
        """Run the runtime invariant checker over the fleet (public API).

        Checks simplex rows, stray mass on dead links/apps/CPUs,
        finiteness and capacity slack for every member (or the given
        subset).  Pure measurement — quarantining is the caller's (or
        ``debug`` mode's) decision via :attr:`FleetHealth.corrupt`.
        """
        return [self.verify_member(b)
                for b in (range(self.B) if members is None else members)]

    # -- event ingestion ------------------------------------------------

    def process(self, ev: events.Event) -> HealthReport:
        """Ingest one event and re-converge its member incrementally."""
        t0 = time.perf_counter()
        with self._span(f"event:{type(ev).__name__}", tid=ev.member,
                        member=ev.member, index=len(self.reports)):
            rep = self._process(ev, t0)
        if self.metrics is not None:
            self.metrics.counter(f"online.event.{type(ev).__name__}")
            self.metrics.observe("online.event.iters", rep.iterations)
            self.metrics.observe("online.event.wall_s", rep.wall_s)
            if rep.rolled_back:
                self.metrics.counter("online.rollback")
            if rep.shed:
                self.metrics.counter("online.shed", len(rep.shed))
        return rep

    def _process(self, ev: events.Event, t0: float) -> HealthReport:
        b = ev.member
        injected = None
        if self.fault_injector is not None:
            carry_b = jax.tree_util.tree_map(lambda x: x[b], self.carry)
            carry_b, injected = self.fault_injector.maybe_corrupt(
                carry_b, b, len(self.reports))
            if injected is not None:
                self._scatter_carry(b, carry_b)

        inst_b, eff = events.apply_event(self._members[b], ev)
        self._members[b] = inst_b
        self.binst = jax.tree_util.tree_map(
            lambda full, x: full.at[b].set(x), self.binst, inst_b)
        seed_phi = gp.init_phi(inst_b)

        phi_b = self.phi(b)
        touched = np.array(eff.touched, dtype=bool)
        repaired = False
        if eff.topology:
            # apps that routed over a now-dead link must re-solve even if
            # repair leaves their residual small (their mass was moved)
            for i, j in eff.dead_links:
                touched |= np.asarray(
                    phi_b.e[:, :, i, j].sum(axis=1)) > 1e-6
            phi_b = traffic.repair_phi(inst_b, phi_b, seed_phi)
            repaired = True

        # Last-known-good maintenance: repair the incumbent alongside the
        # live strategy and re-cost it under the post-event instance, so
        # the rollback bound is always measured on the CURRENT problem.
        lkg_phi = self._lkg_phi[b]
        if eff.topology:
            lkg_phi = traffic.repair_phi(inst_b, lkg_phi, seed_phi)
            self._lkg_phi[b] = lkg_phi
        incumbent = float(self._cost_fn(inst_b, lkg_phi))
        self._lkg_cost[b] = incumbent

        live = np.asarray(inst_b.stage_mask).any(axis=1)
        res = np.asarray(self._residual_fn(inst_b, phi_b))
        # a non-finite residual means the (repaired) strategy drives some
        # link past capacity — nothing about that app is provably stationary
        active = (touched | ~np.isfinite(res) | (res > self.gate_tol)) & live
        keep = (self.carry_window and eff.small and not eff.topology)

        carry_b = jax.tree_util.tree_map(lambda x: x[b], self.carry)
        carry_b = engine.reset_carry(inst_b, phi_b, carry_b,
                                     keep_window=keep, solver=self.solver)
        cost_now = float(carry_b.cost)
        if not np.isfinite(cost_now):
            active = live.copy()       # over-capacity strategy: solve everyone
        elif np.isfinite(incumbent) and cost_now > incumbent * (
                1 + self.rollback_margin):
            # serving as-is would break the LKG guarantee — open the gate
            active = live.copy()
        if not active.any():
            # every live app is provably stationary at the new instance:
            # commit bookkeeping (cost under the new rates) and skip the solve
            carry_b = carry_b._replace(
                done=jnp.asarray(True),
                residual=jnp.float32(res.max(initial=0.0)))
            self._scatter_carry(b, carry_b)
            self._count("online.gate.skip")
            self._instant("gate-skip", tid=b, member=b)
            return self._finish(
                ev, b, inst_b, incumbent, iters=0, solved=0,
                skipped=int(live.sum()), unfroze=0, repaired=repaired,
                keep=keep, cold_restart=False, rungs=(), served="gp",
                converged=True, injected=injected, shed=eff.shed,
                rung_iters=(), t0=t0)

        self._scatter_carry(b, carry_b)
        am = active
        iters_total = 0
        unfroze = 0
        cold_restart = False

        if isinstance(ev, (events.AppArrival, events.LinkUp)):
            # Expansion policy: restart cold, no warm round.  Arrivals and
            # restored links *expand the strategy space* — the incumbent
            # strategy is stationary for the smaller problem and carries
            # zero mass in the new directions (the new app's rows, the
            # revived link), so the GP map crawls into them one
            # alpha-limited step at a time while gp.init_phi simply
            # redistributes.  Warm rounds on these events measure slower
            # than the cold baseline itself; perturbation events (rates,
            # failures) are where warm starts pay.
            plateaued = True
        else:
            # warm round with the plateau probe: bail early if the repaired
            # strategy turns out to be a spurious near-fixed point
            it, plateaued = self._converge([b], app_mask=am[None, :],
                                           plateau_res=self.plateau_res,
                                           phase="warm")
            iters_total += int(it[0])
            if not np.isfinite(float(self.carry.cost[b])):
                # the repaired strategy exceeded some link capacity and the
                # GP map cannot descend from an infinite cost (marginals are
                # nan): a cold restart from gp.init_phi is the only sound
                # recovery — and it is exactly the cold baseline, so parity
                # is preserved
                plateaued = True
            elif eff.topology and int(it[0]) <= gp._CHUNK_MIN:
                # a repaired strategy that latches done within the first
                # chunk is suspect: mass was force-moved off dead links yet
                # the residual certificate fired almost immediately, which
                # in practice means a near-fixed point a hair above the
                # optimum (residual <= tol only bounds *stationarity*, not
                # the cost gap).  Restarting costs roughly the cold solve
                # and lands bit-identically on the cold baseline's answer.
                plateaued = True
        if plateaued:
            cold_restart = True
            self._count("online.cold_restart")
            self._reset_member(b, seed_phi, keep_window=False)
            am = live.copy()          # a cold start moves every live app
            it, _ = self._converge([b], app_mask=am[None, :], phase="cold")
            iters_total += int(it[0])

        res = np.asarray(self._residual_fn(inst_b, self.phi(b)))
        for _round in range(self.max_unfreeze_rounds):
            drifted = live & ~am & (~np.isfinite(res) | (res > self.gate_tol))
            if not drifted.any():
                break
            # congestion moved under gate-frozen apps: unfreeze and go again
            unfroze += int(drifted.sum())
            self._count("online.unfreeze", int(drifted.sum()))
            am = am | drifted
            self._reset_member(b, self.phi(b), keep_window=True)
            it, _ = self._converge([b], app_mask=am[None, :],
                                   phase="unfreeze")
            iters_total += int(it[0])
            res = np.asarray(self._residual_fn(inst_b, self.phi(b)))

        # -- watchdog (§17): escalate on non-finite / worse-than-incumbent
        # -- / true budget exhaustion
        served = "gp"
        rungs: tuple = ()
        rung_iters: tuple = ()
        served_cost = float(self.carry.cost[b])
        converged = self._certificate(b)
        if self._needs_escalation(b, served_cost, incumbent):
            extra, rungs, rung_iters, served, converged = self._escalate(
                b, inst_b, seed_phi, live, incumbent,
                already_cold=cold_restart)
            iters_total += extra

        self.event_iters += iters_total
        return self._finish(
            ev, b, inst_b, incumbent, iters=iters_total,
            solved=int(am.sum()), skipped=int((live & ~am).sum()),
            unfroze=unfroze, repaired=repaired, keep=keep,
            cold_restart=cold_restart, rungs=rungs, served=served,
            converged=converged, injected=injected, shed=eff.shed,
            rung_iters=rung_iters, t0=t0)

    def step(self, evs: Sequence[events.Event]) -> list[HealthReport]:
        """Ingest a list of events in order (the trace-replay entry point)."""
        return [self.process(ev) for ev in evs]

    # -- guardrails (§17) -----------------------------------------------

    def _certificate(self, b: int) -> bool:
        """True iff member ``b``'s last solve stopped *with* a convergence
        certificate.  The engine's done latch fires for four reasons
        (engine.py): committed residual <= tol, the §15 phi fixed-point
        freeze, stall patience, or budget exhaustion.  The first two are
        certificates (the scan's committed residual is an approximation
        from pre-step marginals, so a fixed-point latch can legitimately
        carry a residual a hair above tol); stall and budget caps are
        best-effort stops."""
        if not bool(self.carry.done[b]):
            return False
        res = float(self.carry.residual[b])
        if np.isfinite(res) and res <= self.tol:
            return True
        return (int(self.carry.stall[b]) < self.patience
                and int(self.carry.iters[b]) < self.max_iters)

    def _needs_escalation(self, b: int, cost: float,
                          incumbent: float) -> bool:
        if not np.isfinite(cost):
            return True
        if np.isfinite(incumbent) and cost > incumbent * (
                1 + self.rollback_margin):
            return True
        # true budget exhaustion: the last re-convergence burned the whole
        # budget AND left no certificate.  A stall-latched stop below
        # max_iters is a plateau, not exhaustion — it does not escalate
        # (the §16 plateau probe already handled it).
        capped = int(self.carry.iters[b]) >= self.max_iters
        return capped and not self._certificate(b)

    def _escalate(self, b: int, inst_b: Instance, seed_phi: Phi,
                  live: np.ndarray, incumbent: float, *,
                  already_cold: bool) -> tuple[int, tuple, tuple, str, bool]:
        """Climb the degradation ladder; returns (iterations, rungs,
        rung_iters, served, converged).

        Rungs, each on a backoff budget: ``warm`` (continue from the live
        strategy, Anderson window kept), ``warm-clear`` (window zeroed — a
        misled mixer gets a different trajectory), ``cold`` (gp.init_phi,
        full budget; skipped when the event path already restarted cold),
        ``baseline:<SPOC|LCOF>`` (mask-restricted solve from
        ``baselines.fallback_strategy`` — always feasible).  The best
        finite candidate wins iff it beats the incumbent, else the
        incumbent is rolled back in; ``served`` is one of
        "gp" / "baseline" / "incumbent" / "none".  ``rung_iters`` is the
        per-rung iteration spend, parallel to ``rungs`` (§19 watchdog
        accounting).
        """
        extra = 0
        rungs: list[str] = []
        rung_iters: list[int] = []
        am = live[None, :]
        margin = 1 + self.rollback_margin

        def measure(tag: str, is_baseline: bool = False) -> dict:
            # ``cert``/``cert_ok`` travel with the candidate: the committed
            # scan residual and whether the stop carried a convergence
            # certificate (residual latch or phi fixed-point freeze), so
            # serving a candidate re-installs its own verdict.
            return dict(rung=tag, phi=self.phi(b),
                        cost=float(self.carry.cost[b]),
                        cert=float(self.carry.residual[b]),
                        cert_ok=self._certificate(b),
                        baseline=is_baseline)

        def run(rung: str, phi0: Phi, keep_w: bool, budget: int,
                allowed=None, is_baseline: bool = False) -> dict:
            nonlocal extra
            self.ladder_hits[rung] = self.ladder_hits.get(rung, 0) + 1
            self._count(f"online.rung.{rung}")
            rungs.append(rung)
            self._reset_member(b, phi0, keep_window=keep_w)
            it, _ = self._converge([b], app_mask=am, max_iters=budget,
                                   allowed=allowed, phase=f"rung:{rung}")
            extra += int(it[0])
            rung_iters.append(int(it[0]))
            c = measure(rung, is_baseline)
            cands.append(c)
            return c

        def acceptable(c: dict) -> bool:
            return (np.isfinite(c["cost"]) and c["cert_ok"]
                    and (not np.isfinite(incumbent)
                         or c["cost"] <= incumbent * margin))

        cands = [measure("event")]
        half = max(1, self.max_iters // 2)
        done = False
        if np.isfinite(cands[0]["cost"]):
            # warm rungs only make sense from a finite live strategy; a
            # NaN-poisoned phi jumps straight to the cold rung
            done = acceptable(run("warm", self.phi(b), True, half))
            if not done:
                done = acceptable(run("warm-clear", self.phi(b), False, half))
        if not done and not already_cold:
            done = acceptable(run("cold", seed_phi, False, self.max_iters))
        if not done:
            fb = baselines.fallback_strategy(inst_b)
            if fb is not None:
                name, allowed_e, allowed_c, phi0, _ = fb
                run(f"baseline:{name}", phi0, False,
                    max(1, self.max_iters // 4),
                    allowed=(allowed_e, allowed_c), is_baseline=True)

        served, converged = self._serve_best(b, inst_b, cands, incumbent)
        return extra, tuple(rungs), tuple(rung_iters), served, converged

    def _serve_best(self, b: int, inst_b: Instance, cands: list[dict],
                    incumbent: float) -> tuple[str, bool]:
        """Commit the winning candidate (or the incumbent) to the carry;
        returns (served, converged)."""
        margin = 1 + self.rollback_margin
        finite = [c for c in cands if np.isfinite(c["cost"])]
        best = min(finite, key=lambda c: c["cost"]) if finite else None
        if best is not None and (not np.isfinite(incumbent)
                                 or best["cost"] <= incumbent * margin):
            self._commit_phi(b, inst_b, best["phi"], best["cert"])
            return ("baseline" if best.get("baseline") else "gp",
                    bool(best["cert_ok"]))
        if np.isfinite(incumbent):
            self._commit_phi(b, inst_b, self._lkg_phi[b],
                             self._lkg_residual[b])
            return "incumbent", self._lkg_cert[b]
        if best is not None:
            # incumbent is not even finite: serve the best-effort candidate
            self._commit_phi(b, inst_b, best["phi"], best["cert"])
            return ("baseline" if best.get("baseline") else "gp",
                    bool(best["cert_ok"]))
        # nothing finite anywhere — park on the (repaired) incumbent
        lkg = self._lkg_phi[b]
        self._commit_phi(b, inst_b, lkg, float("inf"))
        return "none", False

    def _quarantine(self, b: int, inst_b: Instance) -> int:
        """Replace a corrupt member's strategy with the baseline-mask
        fallback (short restricted solve); returns iterations spent."""
        fb = baselines.fallback_strategy(inst_b)
        if fb is None:
            # unservable instance — park on the repaired incumbent
            self._commit_phi(b, inst_b, self._lkg_phi[b], float("inf"))
            return 0
        name, allowed_e, allowed_c, phi0, _ = fb
        self.ladder_hits[f"quarantine:{name}"] = \
            self.ladder_hits.get(f"quarantine:{name}", 0) + 1
        self._count("online.quarantine")
        live = np.asarray(inst_b.stage_mask).any(axis=1)
        self._reset_member(b, phi0, keep_window=False)
        it, _ = self._converge([b], app_mask=live[None, :],
                               max_iters=max(1, self.max_iters // 4),
                               allowed=(allowed_e, allowed_c),
                               phase="quarantine")
        return int(it[0])

    def _finish(self, ev, b: int, inst_b: Instance, incumbent: float, *,
                iters: int, solved: int, skipped: int, unfroze: int,
                repaired: bool, keep: bool, cold_restart: bool,
                rungs: tuple, served: str, converged: bool,
                injected: Optional[str], shed: tuple,
                rung_iters: tuple = (), t0: float = 0.0) -> HealthReport:
        """Verdict + LKG update + (debug) invariant check, one report."""
        quarantined = False
        if self.debug and served != "none":
            health = self.verify_member(b)
            if health.corrupt:
                quarantined = True
                self.quarantines += 1
                iters += self._quarantine(b, inst_b)
                served = "baseline"
                converged = self._certificate(b)

        served_cost = float(self.carry.cost[b])
        res_max = float(np.asarray(
            self._residual_fn(inst_b, self.phi(b))).max(initial=0.0))
        converged = bool(converged and np.isfinite(served_cost))
        status = ("rolled_back" if served == "incumbent" else
                  "rejected" if served == "none" else
                  "degraded" if served == "baseline" else
                  "converged" if converged else "capped")

        # LKG advances on any finite serve that honours the incumbent
        # bound; a rollback re-affirms the incumbent (no-op by value).
        if np.isfinite(served_cost) and (
                not np.isfinite(incumbent)
                or served_cost <= incumbent * (1 + self.rollback_margin)):
            self._lkg_phi[b] = self.phi(b)
            self._lkg_cost[b] = served_cost
            self._lkg_residual[b] = res_max
            self._lkg_cert[b] = converged

        rep = HealthReport(
            event=ev, member=b, iterations=iters, cost=served_cost,
            residual=res_max, solved_apps=solved, skipped_apps=skipped,
            unfroze=unfroze, repaired=repaired, kept_window=keep,
            cold_restart=cold_restart, converged=converged, status=status,
            rungs=tuple(rungs), incumbent_cost=incumbent,
            rolled_back=(served == "incumbent"), quarantined=quarantined,
            injected=injected, shed=tuple(shed),
            rung_iters=tuple(rung_iters),
            wall_s=(time.perf_counter() - t0) if t0 else 0.0)
        self.reports.append(rep)
        return rep

    # -- observability plumbing (§19) -----------------------------------

    def _span(self, name: str, *, tid: int = 0, **args):
        """Nested tracer span, or a no-op when no tracer is attached."""
        if self.tracer is None:
            return nullcontext()
        return self.tracer.span(name, tid=tid, **args)

    def _instant(self, name: str, *, tid: int = 0, **args) -> None:
        if self.tracer is not None:
            self.tracer.instant(name, tid=tid, **args)

    def _count(self, name: str, n: int = 1) -> None:
        if self.metrics is not None:
            self.metrics.counter(name, n)

    def _drain_ring(self, b: int, tb, iters: int, phase: str) -> None:
        """Move one solve segment's ring rows into ``iter_trace``.

        Called at the end of every ``_converge`` — the chunk boundary where
        the service host-syncs anyway, so the transfer adds no device round
        trips.  Each record is tagged with the member, the event index
        being processed (-1 during the construction cold start), the phase
        label (warm/cold/unfreeze/rung:*/...) and a monotone segment id.
        Must run BEFORE any ``reset_carry`` zeroes the ring.
        """
        if self._telemetry is None:
            return
        n = int(iters)
        rows = ring_valid(tb, n)
        dropped = ring_overflow(tb, n)
        if dropped and self.metrics is not None:
            self.metrics.counter("telemetry.ring.dropped", dropped)
        ev_idx = -1 if phase == "cold-start" else len(self.reports)
        seg = self._segments
        self._segments += 1
        for rec in records_to_dicts(rows):
            rec.update(member=b, event=ev_idx, phase=phase, segment=seg)
            self.iter_trace.append(rec)

    # -- internals ------------------------------------------------------

    def _scatter_carry(self, b: int, carry_b: engine.ScanCarry) -> None:
        self.carry = jax.tree_util.tree_map(
            lambda full, part: full.at[b].set(part), self.carry, carry_b)

    def _reset_member(self, b: int, phi: Phi, *, keep_window: bool) -> None:
        carry_b = jax.tree_util.tree_map(lambda x: x[b], self.carry)
        carry_b = engine.reset_carry(self._members[b], phi, carry_b,
                                     keep_window=keep_window,
                                     solver=self.solver)
        self._scatter_carry(b, carry_b)

    def _commit_phi(self, b: int, inst_b: Instance, phi: Phi,
                    res_max: float) -> None:
        """Install ``phi`` as member ``b``'s served strategy (done-latched)."""
        carry_b = jax.tree_util.tree_map(lambda x: x[b], self.carry)
        carry_b = engine.reset_carry(inst_b, phi, carry_b,
                                     keep_window=False, solver=self.solver)
        # stall=patience marks the commit as certificate-free: the phi was
        # installed, not converged to, so _certificate must only accept it
        # when the recorded residual itself is within tol
        carry_b = carry_b._replace(done=jnp.asarray(True),
                                   residual=jnp.float32(res_max),
                                   stall=jnp.int32(self.patience))
        self._scatter_carry(b, carry_b)

    def _chunk_schedule(self, advance: Callable[[int], tuple[bool, float]],
                        *, plateau_res: Optional[float] = None,
                        max_iters: Optional[int] = None) -> bool:
        """The shared pow2 chunk ladder of every re-convergence.

        ``advance(length)`` runs one compiled chunk and returns
        ``(all_done, probe_residual)`` where the probe is the smallest
        residual among still-running lanes (inf when meaningless).  The
        schedule doubles chunk lengths from ``gp._CHUNK_MIN`` to
        ``gp._CHUNK_MAX`` exactly like ``gp.solve_batched``; ``max_iters``
        overrides the instance budget (the §17 ladder's per-rung backoff).

        With ``plateau_res`` set, the first chunk arms a *suspect* latch
        when a running lane's residual is already below it (a spurious
        near-fixed point of the GP map); one grace chunk later, if the
        done latch still hasn't fired, the run is declared plateaued and
        the caller restarts cold.  Returns that plateau flag.
        """
        budget = self.max_iters if max_iters is None else int(max_iters)
        steps, chunk = 0, gp._CHUNK_MIN
        suspect = False
        while steps < budget:
            length = min(chunk, gp._prev_pow2(budget - steps))
            chunk = min(chunk * 2, gp._CHUNK_MAX)
            done, probe = advance(length)
            steps += length
            if done:
                break
            if suspect:
                # grace chunk expired without the done latch: this is a
                # crawl, not a fixed point about to latch
                return True
            if plateau_res is not None:
                suspect = probe <= plateau_res
                plateau_res = None     # probe only the first chunk
        return False

    def _converge(self, members: Sequence[int],
                  app_mask: Optional[np.ndarray] = None,
                  plateau_res: Optional[float] = None,
                  max_iters: Optional[int] = None,
                  allowed=None,
                  phase: str = "solve",
                  ) -> tuple[np.ndarray, bool]:
        """Run the affected members to convergence through the batched
        chunk programs; returns (per-member committed iteration counts,
        plateau flag).

        Members are gathered into a power-of-two bucket (pad lanes
        duplicate member 0 but start ``done``), so event-time solves hit
        the same XLA cache entries regardless of how many members an event
        touched; chunk scheduling and the plateau probe live in
        ``_chunk_schedule``, shared with the single-member path.

        A single member (every event — events touch exactly one member)
        runs through the *unbatched* ``gp._scan_chunk`` program — the same
        arithmetic as ``gp.solve`` — because the vmapped bucket-of-one
        program rounds differently and its GP trajectories can take ~1.7x
        the iterations to the same optimum (tie-breaks flip under the
        batched fusion).  The batched path serves the initial fleet solve.
        """
        if len(members) == 1:
            return self._converge_one(members[0], app_mask, plateau_res,
                                      max_iters=max_iters, allowed=allowed,
                                      phase=phase)
        assert allowed is None, "direction masks are single-member only"
        n = len(members)
        bucket = batch.next_pow2(n)
        sel = jnp.asarray(list(members) + [members[0]] * (bucket - n))
        inst_s = jax.tree_util.tree_map(lambda x: x[sel], self.binst)
        carry_s = jax.tree_util.tree_map(lambda x: x[sel], self.carry)
        if bucket > n:
            pad = jnp.arange(bucket) >= n
            carry_s = carry_s._replace(done=carry_s.done | pad)
        am = None
        if app_mask is not None:
            am_np = np.asarray(app_mask, dtype=bool)
            am = jnp.asarray(np.concatenate(
                [am_np, np.repeat(am_np[:1], bucket - n, axis=0)], axis=0))

        state = {"carry": carry_s}

        def advance(length: int) -> tuple[bool, float]:
            state["carry"], _ = gp._scan_chunk_batched(
                inst_s, state["carry"], self._alpha, self._tol,
                self._patience, self._max_iters, None, None, length=length,
                solver=self.solver, blocked=self.blocked,
                accel=self._accel, app_mask=am, telemetry=self._telemetry)
            done = np.asarray(state["carry"].done)
            if bool(done.all()):
                return True, float("inf")
            running = ~done[:n]
            res = np.asarray(state["carry"].residual)[:n]
            probe = float(res[running].min()) if running.any() else float("inf")
            return False, probe

        with self._span(phase, members=list(members)):
            plateaued = self._chunk_schedule(advance, plateau_res=plateau_res,
                                             max_iters=max_iters)
        carry_s = state["carry"]
        upd = jnp.asarray(list(members))
        self.carry = jax.tree_util.tree_map(
            lambda full, part: full.at[upd].set(part[:n]),
            self.carry, carry_s)
        iters = np.asarray(carry_s.iters[:n]).copy()
        if self._telemetry is not None:
            tb_h = np.asarray(carry_s.tb)       # (bucket, R, W) one transfer
            for i, m in enumerate(members):
                self._drain_ring(m, tb_h[i], int(iters[i]), phase)
        self.total_iters += int(iters.sum())
        return iters, plateaued

    def _converge_one(self, b: int, app_mask: Optional[np.ndarray],
                      plateau_res: Optional[float],
                      max_iters: Optional[int] = None,
                      allowed=None,
                      phase: str = "solve",
                      ) -> tuple[np.ndarray, bool]:
        """Single-member convergence through the unbatched chunk program
        (bit-identical arithmetic to ``gp.solve``).  ``allowed`` carries
        optional (allowed_e, allowed_c) direction masks — the §17
        baseline-restricted rung."""
        inst_b = self._members[b]
        carry_b = jax.tree_util.tree_map(lambda x: x[b], self.carry)
        am = None if app_mask is None else jnp.asarray(
            np.asarray(app_mask, dtype=bool)[0])
        ae, ac = (None, None) if allowed is None else allowed

        state = {"carry": carry_b}

        def advance(length: int) -> tuple[bool, float]:
            state["carry"], _ = gp._scan_chunk(
                inst_b, state["carry"], self._alpha, self._tol,
                self._patience, self._max_iters, ae, ac, length=length,
                solver=self.solver, blocked=self.blocked,
                accel=self._accel, app_mask=am, telemetry=self._telemetry)
            return bool(state["carry"].done), float(state["carry"].residual)

        with self._span(phase, tid=b, member=b):
            plateaued = self._chunk_schedule(advance, plateau_res=plateau_res,
                                             max_iters=max_iters)
        carry_b = state["carry"]
        self._scatter_carry(b, carry_b)
        iters = np.asarray([int(carry_b.iters)], np.int32)
        self._drain_ring(b, np.asarray(carry_b.tb), int(iters[0]), phase)
        self.total_iters += int(iters.sum())
        return iters, plateaued
