"""OnlineSolver: the GP solver as a long-running service (DESIGN.md §16).

The paper's Section IV closes by noting the distributed algorithm "adapts
to changes in input rates and network topology, and can be implemented as
an online algorithm".  This module is that claim as a subsystem: a
device-resident engine that holds the *live* forwarding/offloading strategy
for a fleet of problem instances and re-converges incrementally as typed
events (``core/events.py``) stream in.

Architecture — everything rides the existing batched machinery:

  * **Fleet state** — members are padded to one envelope
    (``events.pad_fleet``, §9 invariants) and stacked into a single batched
    Instance pytree; the live solver state is one batched
    ``engine.ScanCarry`` whose ``phi`` is the fleet's current strategy.
  * **Event ingestion** — ``apply_event`` rewrites the member's instance
    *in place in the envelope* (no shape changes), so every re-convergence
    reuses the same compiled chunk programs as ``gp.solve_batched``.
  * **Warm start + phi repair** — re-convergence starts from the live
    strategy; after topology events ``traffic.repair_phi`` masks dead
    directions and reseeds emptied rows before the solver touches it.
  * **Skip gates** — two levels.  Fleet members an event did not touch
    never enter the device program (members are uncoupled).  Within the
    touched member, ``conditions.per_app_residual`` is the gate: only
    applications whose problem data changed, whose strategy carried mass on
    a failed link, or whose sufficiency residual exceeds ``gate_tol`` are
    unfrozen (``app_mask``); everyone else's strategy is provably optimal
    already (condition (6) per app) and is frozen — their flows still count
    in the shared F/G measurement, so the restricted solve is exact.  After
    convergence the gate re-checks *all* apps and unfreezes any that
    drifted (congestion moved under them); re-convergence repeats until the
    full fleet member satisfies the residual, so final costs match a cold
    solve.
  * **Acceleration carry (§15)** — across *small rate deltas* (factor in
    ``events.SMALL_RATE_WINDOW``) the Anderson window and adaptive stepsize
    survive the event (``engine.reset_carry(keep_window=True)``): the
    stored (x, f) pairs are stale but the scan body's safeguard costs every
    mix under the NEW instance, so descent is preserved and the window
    still cuts iterations.  Topology/app churn clears the window.

Example::

    >>> insts = [network.table_ii_instance("abilene", rate_scale=s)
    ...          for s in (0.5, 1.0)]
    >>> solver = OnlineSolver(insts, spare_apps=1, alpha=0.1, accel=True)
    >>> rep = solver.process(events.RateScale(member=0, factor=1.5, app=0))
    >>> rep.iterations < solver.cold_iters[0]          # doctest: +SKIP
    True

``benchmarks/online_bench.py`` drives a 50-event trace over the fig6
family and records cost parity (<= 1e-4) and the warm/cold iteration ratio
as BENCH_gp.json online rows; ``tests/test_online.py`` pins the semantics.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batch, conditions, engine, events, gp, traffic
from repro.core.network import Instance
from repro.core.traffic import Phi


@dataclasses.dataclass(frozen=True)
class EventReport:
    """What one event cost the service.

    ``iterations`` counts GP iterations actually committed for this event
    (0 when every live app passed the skip gate); ``solved_apps`` /
    ``skipped_apps`` split the member's live applications into gate-opened
    and gate-frozen; ``unfroze`` counts apps the post-convergence re-check
    promoted from frozen to solved (congestion drift); ``repaired`` /
    ``kept_window`` record the phi-repair and Anderson-carry decisions.
    """

    event: events.Event
    member: int
    iterations: int
    cost: float
    residual: float
    solved_apps: int
    skipped_apps: int
    unfroze: int
    repaired: bool
    kept_window: bool
    cold_restart: bool = False


class OnlineSolver:
    """Device-resident online GP service over a fleet of instances.

    Parameters mirror ``gp.solve`` (alpha/tol/patience/max_iters/solver/
    blocked/accel apply to every re-convergence).  ``spare_apps`` reserves
    dead application slots per member for :class:`events.AppArrival`;
    ``gate_tol`` (default: ``tol``) is the per-app residual threshold of
    the skip gate — apps below it are provably within tolerance of
    stationary and are frozen; ``carry_window=False`` disables the §15
    Anderson-window carry across small rate deltas (ablation hook).

    Construction cold-solves the whole fleet in one batched program;
    per-member cold iteration counts are kept in ``cold_iters`` as the
    warm-start baseline.  ``process`` ingests one event, ``step`` a list.
    """

    def __init__(
        self,
        insts: Sequence[Instance],
        *,
        spare_apps: int = 0,
        alpha: float = 0.02,
        tol: float = 1e-4,
        gate_tol: Optional[float] = None,
        max_iters: int = 400,
        patience: int = 40,
        solver: str = "auto",
        blocked: str = "bitset",
        accel=True,
        carry_window: bool = True,
        max_unfreeze_rounds: int = 4,
        plateau_res: Optional[float] = None,
    ):
        self._members = events.pad_fleet(insts, spare_apps=spare_apps)
        self.binst: Instance = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *self._members)
        self.B = len(self._members)
        self.tol = float(tol)
        self.gate_tol = float(tol if gate_tol is None else gate_tol)
        self.max_iters = int(max_iters)
        self.solver = solver
        self.blocked = blocked
        self.carry_window = bool(carry_window)
        self.max_unfreeze_rounds = int(max_unfreeze_rounds)
        # Warm-start plateau detector (see _converge): a repaired strategy
        # can itself be a *spurious* near-fixed point of the GP map — the
        # residual starts tiny but the ladder crawls on micro-improvements
        # for hundreds of iterations (healthy warm starts begin at residual
        # ~1e-1 and drop fast).  If after the first chunk the member is not
        # done yet its residual is already below this, restarting cold is
        # strictly faster AND lands on the same optimum as the cold
        # baseline, preserving cost parity.
        self.plateau_res = float(20 * tol if plateau_res is None else plateau_res)
        self._accel = engine.resolve_accel(accel)
        self._alpha = jnp.float32(alpha)
        self._tol = jnp.float32(tol)
        self._patience = jnp.int32(patience)
        self._max_iters = jnp.int32(max_iters)
        self._residual_fn = jax.jit(conditions.per_app_residual)

        phi0 = jax.vmap(gp.init_phi)(self.binst)
        self.carry: engine.ScanCarry = jax.vmap(
            lambda i, p: engine.init_carry(i, p, accel=self._accel)
        )(self.binst, phi0)

        self.total_iters = 0                       # all committed iterations
        self.reports: list[EventReport] = []
        self.cold_iters, _ = self._converge(list(range(self.B)))
        self.event_iters = 0                       # iterations after cold start

    # -- fleet state accessors ------------------------------------------

    def member(self, b: int) -> Instance:
        """Member ``b``'s current (padded) problem instance."""
        return self._members[b]

    def phi(self, b: int) -> Phi:
        """Member ``b``'s live strategy (padded to the fleet envelope)."""
        return jax.tree_util.tree_map(lambda x: x[b], self.carry.phi)

    def costs(self) -> np.ndarray:
        """(B,) current aggregate delay of every fleet member."""
        return np.asarray(self.carry.cost)

    def residuals(self) -> np.ndarray:
        """(B,) per-member sufficiency residual of the live strategies."""
        out = np.zeros(self.B, np.float32)
        for b in range(self.B):
            res = np.asarray(self._residual_fn(self._members[b], self.phi(b)))
            out[b] = res.max(initial=0.0)
        return out

    # -- event ingestion ------------------------------------------------

    def process(self, ev: events.Event) -> EventReport:
        """Ingest one event and re-converge its member incrementally."""
        b = ev.member
        inst_b, eff = events.apply_event(self._members[b], ev)
        self._members[b] = inst_b
        self.binst = jax.tree_util.tree_map(
            lambda full, x: full.at[b].set(x), self.binst, inst_b)

        phi_b = self.phi(b)
        touched = np.array(eff.touched, dtype=bool)
        repaired = False
        if eff.topology:
            # apps that routed over a now-dead link must re-solve even if
            # repair leaves their residual small (their mass was moved)
            for i, j in eff.dead_links:
                touched |= np.asarray(
                    phi_b.e[:, :, i, j].sum(axis=1)) > 1e-6
            phi_b = traffic.repair_phi(inst_b, phi_b, gp.init_phi(inst_b))
            repaired = True

        live = np.asarray(inst_b.stage_mask).any(axis=1)
        res = np.asarray(self._residual_fn(inst_b, phi_b))
        # a non-finite residual means the (repaired) strategy drives some
        # link past capacity — nothing about that app is provably stationary
        active = (touched | ~np.isfinite(res) | (res > self.gate_tol)) & live
        keep = (self.carry_window and eff.small and not eff.topology)

        carry_b = jax.tree_util.tree_map(lambda x: x[b], self.carry)
        carry_b = engine.reset_carry(inst_b, phi_b, carry_b,
                                     keep_window=keep, solver=self.solver)
        if not np.isfinite(float(carry_b.cost)):
            active = live.copy()       # over-capacity strategy: solve everyone
        if not active.any():
            # every live app is provably stationary at the new instance:
            # commit bookkeeping (cost under the new rates) and skip the solve
            carry_b = carry_b._replace(
                done=jnp.asarray(True),
                residual=jnp.float32(res.max(initial=0.0)))
            self._scatter_carry(b, carry_b)
            rep = EventReport(
                event=ev, member=b, iterations=0,
                cost=float(carry_b.cost),
                residual=float(res.max(initial=0.0)),
                solved_apps=0, skipped_apps=int(live.sum()),
                unfroze=0, repaired=repaired, kept_window=keep)
            self.reports.append(rep)
            return rep

        self._scatter_carry(b, carry_b)
        am = active
        iters_total = 0
        unfroze = 0
        cold_restart = False

        if isinstance(ev, (events.AppArrival, events.LinkUp)):
            # Expansion policy: restart cold, no warm round.  Arrivals and
            # restored links *expand the strategy space* — the incumbent
            # strategy is stationary for the smaller problem and carries
            # zero mass in the new directions (the new app's rows, the
            # revived link), so the GP map crawls into them one
            # alpha-limited step at a time while gp.init_phi simply
            # redistributes.  Warm rounds on these events measure slower
            # than the cold baseline itself; perturbation events (rates,
            # failures) are where warm starts pay.
            plateaued = True
        else:
            # warm round with the plateau probe: bail early if the repaired
            # strategy turns out to be a spurious near-fixed point
            it, plateaued = self._converge([b], app_mask=am[None, :],
                                           plateau_res=self.plateau_res)
            iters_total += int(it[0])
            if not np.isfinite(float(self.carry.cost[b])):
                # the repaired strategy exceeded some link capacity and the
                # GP map cannot descend from an infinite cost (marginals are
                # nan): a cold restart from gp.init_phi is the only sound
                # recovery — and it is exactly the cold baseline, so parity
                # is preserved
                plateaued = True
            elif eff.topology and int(it[0]) <= gp._CHUNK_MIN:
                # a repaired strategy that latches done within the first
                # chunk is suspect: mass was force-moved off dead links yet
                # the residual certificate fired almost immediately, which
                # in practice means a near-fixed point a hair above the
                # optimum (residual <= tol only bounds *stationarity*, not
                # the cost gap).  Restarting costs roughly the cold solve
                # and lands bit-identically on the cold baseline's answer.
                plateaued = True
        if plateaued:
            cold_restart = True
            carry_b = jax.tree_util.tree_map(lambda x: x[b], self.carry)
            carry_b = engine.reset_carry(inst_b, gp.init_phi(inst_b), carry_b,
                                         keep_window=False, solver=self.solver)
            self._scatter_carry(b, carry_b)
            am = live.copy()          # a cold start moves every live app
            it, _ = self._converge([b], app_mask=am[None, :])
            iters_total += int(it[0])

        res = np.asarray(self._residual_fn(inst_b, self.phi(b)))
        for _round in range(self.max_unfreeze_rounds):
            drifted = live & ~am & (~np.isfinite(res) | (res > self.gate_tol))
            if not drifted.any():
                break
            # congestion moved under gate-frozen apps: unfreeze and go again
            unfroze += int(drifted.sum())
            am = am | drifted
            carry_b = jax.tree_util.tree_map(lambda x: x[b], self.carry)
            carry_b = engine.reset_carry(inst_b, carry_b.phi, carry_b,
                                         keep_window=True, solver=self.solver)
            self._scatter_carry(b, carry_b)
            it, _ = self._converge([b], app_mask=am[None, :])
            iters_total += int(it[0])
            res = np.asarray(self._residual_fn(inst_b, self.phi(b)))

        self.event_iters += iters_total
        rep = EventReport(
            event=ev, member=b, iterations=iters_total,
            cost=float(self.carry.cost[b]),
            residual=float(res.max(initial=0.0)),
            solved_apps=int(am.sum()),
            skipped_apps=int((live & ~am).sum()),
            unfroze=unfroze, repaired=repaired, kept_window=keep,
            cold_restart=cold_restart)
        self.reports.append(rep)
        return rep

    def step(self, evs: Sequence[events.Event]) -> list[EventReport]:
        """Ingest a list of events in order (the trace-replay entry point)."""
        return [self.process(ev) for ev in evs]

    # -- internals ------------------------------------------------------

    def _scatter_carry(self, b: int, carry_b: engine.ScanCarry) -> None:
        self.carry = jax.tree_util.tree_map(
            lambda full, part: full.at[b].set(part), self.carry, carry_b)

    def _converge(self, members: Sequence[int],
                  app_mask: Optional[np.ndarray] = None,
                  plateau_res: Optional[float] = None,
                  ) -> tuple[np.ndarray, bool]:
        """Run the affected members to convergence through the batched
        chunk programs; returns (per-member committed iteration counts,
        plateau flag).

        Members are gathered into a power-of-two bucket (pad lanes
        duplicate member 0 but start ``done``), so event-time solves hit
        the same XLA cache entries regardless of how many members an event
        touched; the chunk schedule mirrors ``gp.solve_batched``.

        With ``plateau_res`` set, the run is probed once after the first
        chunk: if any member is still running but its (gate-masked)
        residual is already below ``plateau_res``, the warm start sits on a
        spurious near-fixed point of the GP map — further iterations crawl
        on micro-improvements — and the call returns early with the flag
        set so the caller can restart cold.

        A single member (every event — events touch exactly one member)
        runs through the *unbatched* ``gp._scan_chunk`` program — the same
        arithmetic as ``gp.solve`` — because the vmapped bucket-of-one
        program rounds differently and its GP trajectories can take ~1.7x
        the iterations to the same optimum (tie-breaks flip under the
        batched fusion).  The batched path serves the initial fleet solve.
        """
        if len(members) == 1:
            return self._converge_one(members[0], app_mask, plateau_res)
        n = len(members)
        bucket = batch.next_pow2(n)
        sel = jnp.asarray(list(members) + [members[0]] * (bucket - n))
        inst_s = jax.tree_util.tree_map(lambda x: x[sel], self.binst)
        carry_s = jax.tree_util.tree_map(lambda x: x[sel], self.carry)
        if bucket > n:
            pad = jnp.arange(bucket) >= n
            carry_s = carry_s._replace(done=carry_s.done | pad)
        am = None
        if app_mask is not None:
            am_np = np.asarray(app_mask, dtype=bool)
            am = jnp.asarray(np.concatenate(
                [am_np, np.repeat(am_np[:1], bucket - n, axis=0)], axis=0))

        steps, chunk = 0, gp._CHUNK_MIN
        plateaued = False
        while steps < self.max_iters:
            length = min(chunk, gp._prev_pow2(self.max_iters - steps))
            chunk = min(chunk * 2, gp._CHUNK_MAX)
            carry_s, _ = gp._scan_chunk_batched(
                inst_s, carry_s, self._alpha, self._tol, self._patience,
                self._max_iters, None, None, length=length,
                solver=self.solver, blocked=self.blocked,
                accel=self._accel, app_mask=am)
            steps += length
            done = np.asarray(carry_s.done)
            if bool(done.all()):
                break
            if plateau_res is not None:
                res = np.asarray(carry_s.residual)[:n]
                if bool((~done[:n] & (res <= plateau_res)).any()):
                    plateaued = True
                    break
                plateau_res = None     # probe only the first chunk

        upd = jnp.asarray(list(members))
        self.carry = jax.tree_util.tree_map(
            lambda full, part: full.at[upd].set(part[:n]),
            self.carry, carry_s)
        iters = np.asarray(carry_s.iters[:n]).copy()
        self.total_iters += int(iters.sum())
        return iters, plateaued

    def _converge_one(self, b: int, app_mask: Optional[np.ndarray],
                      plateau_res: Optional[float],
                      ) -> tuple[np.ndarray, bool]:
        """Single-member convergence through the unbatched chunk program
        (bit-identical arithmetic to ``gp.solve``)."""
        inst_b = self._members[b]
        carry_b = jax.tree_util.tree_map(lambda x: x[b], self.carry)
        am = None if app_mask is None else jnp.asarray(
            np.asarray(app_mask, dtype=bool)[0])

        steps, chunk = 0, gp._CHUNK_MIN
        plateaued = suspect = False
        while steps < self.max_iters:
            length = min(chunk, gp._prev_pow2(self.max_iters - steps))
            chunk = min(chunk * 2, gp._CHUNK_MAX)
            carry_b, _ = gp._scan_chunk(
                inst_b, carry_b, self._alpha, self._tol, self._patience,
                self._max_iters, None, None, length=length,
                solver=self.solver, blocked=self.blocked,
                accel=self._accel, app_mask=am)
            steps += length
            if bool(carry_b.done):
                break
            if suspect:
                # chunk 2 grace period expired without the done latch: this
                # is a crawl, not a fixed point about to latch
                plateaued = True
                break
            if plateau_res is not None:
                suspect = float(carry_b.residual) <= plateau_res
                plateau_res = None     # probe only the first chunk

        self._scatter_carry(b, carry_b)
        iters = np.asarray([int(carry_b.iters)], np.int32)
        self.total_iters += int(iters.sum())
        return iters, plateaued
