from repro.train.trainer import TrainState, loss_fn, make_train_step, train_loop  # noqa: F401
