"""Training loop: loss, train_step factory, and the host-side loop.

``make_train_step`` builds the jittable step used both by the CPU examples
(reduced models) and by the 512-device dry-run (full configs, lowered only).
The step is mesh-agnostic: sharding comes from the in/out shardings that
``launch/dryrun.py`` / ``launch/train.py`` attach via jax.jit.
"""

from __future__ import annotations

import time
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.transformer import Model
from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def loss_fn(model: Model, params, batch: dict):
    """Cross-entropy next-token / masked-prediction loss (+ MoE aux)."""
    logits, _, aux = model.apply(params, batch)
    targets = batch["targets"]
    V = logits.shape[-1]
    if model.cfg.frontend == "vision":
        # loss on text positions only (patch prefix carries no targets)
        logits = logits[:, -targets.shape[1]:]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    if model.cfg.frontend == "audio" and "mask" in batch:
        m = batch["mask"].astype(jnp.float32)
        loss = (nll * m).sum() / jnp.maximum(m.sum(), 1.0)
    else:
        loss = nll.mean()
    return loss + 0.01 * aux, (loss, aux)


def make_train_step(model: Model, *, peak_lr=3e-4, warmup=100, total=10_000,
                    weight_decay=0.1, moment_dtype=jnp.float32):
    def train_step(state: TrainState, batch: dict):
        (tot, (loss, aux)), grads = jax.value_and_grad(
            lambda p: loss_fn(model, p, batch), has_aux=True)(state.params)
        lr = cosine_schedule(state.opt.step + 1, peak_lr=peak_lr, warmup=warmup, total=total)
        new_params, new_opt, gnorm = adamw_update(
            state.params, grads, state.opt, lr=lr, weight_decay=weight_decay)
        metrics = {"loss": loss, "aux": aux, "grad_norm": gnorm, "lr": lr}
        return TrainState(params=new_params, opt=new_opt), metrics

    return train_step


def init_state(model: Model, key, moment_dtype=jnp.float32) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=adamw_init(params, moment_dtype))


def train_loop(model: Model, data_iter, *, steps: int, seed: int = 0,
               log_every: int = 10, state: Optional[TrainState] = None,
               checkpoint_dir: Optional[str] = None, ckpt_every: int = 0,
               **step_kwargs):
    """Host-side loop used by examples and launch/train.py."""
    if state is None:
        state = init_state(model, jax.random.PRNGKey(seed))
    step_fn = jax.jit(make_train_step(model, **step_kwargs))
    history = []
    t0 = time.time()
    for i, batch in zip(range(steps), data_iter):
        state, metrics = step_fn(state, batch)
        if i % log_every == 0 or i == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"], m["wall"] = i, time.time() - t0
            history.append(m)
            print(f"step {i:5d} loss {m['loss']:.4f} gnorm {m['grad_norm']:.3f} "
                  f"lr {m['lr']:.2e} t {m['wall']:.1f}s")
        if checkpoint_dir and ckpt_every and i and i % ckpt_every == 0:
            from repro.checkpoint import save_checkpoint
            save_checkpoint(checkpoint_dir, state, step=i)
    return state, history
