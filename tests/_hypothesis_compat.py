"""Property-testing shim: real ``hypothesis`` when installed, a tiny
deterministic fallback otherwise.

The container that runs tier-1 does not always ship ``hypothesis``; a hard
import aborts collection of the *whole* suite (``pytest -x``).  Test modules
import ``given / settings / strategies`` from here instead.  The fallback
implements exactly the API surface the suite uses:

  * ``strategies.floats(min, max)`` / ``strategies.integers(min, max)``
  * ``@settings(max_examples=N, ...)`` (other kwargs accepted and ignored)
  * ``@given(**kwargs)`` — runs the test ``max_examples`` times with values
    drawn from a per-test deterministic RNG; the first two examples pin all
    parameters at their lower/upper bounds to keep boundary coverage.

No shrinking, no example database — failures report the drawn values in the
assertion traceback, which is enough for a reproduction repo.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import inspect
    import zlib

    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, lo, hi, draw):
            self.lo, self.hi, self._draw = lo, hi, draw

        def draw(self, rng, example_idx):
            if example_idx == 0:
                return self.lo
            if example_idx == 1:
                return self.hi
            return self._draw(rng)

    class strategies:  # noqa: N801 - mimics the hypothesis module name
        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(
                float(min_value), float(max_value),
                lambda rng: float(rng.uniform(min_value, max_value)),
            )

        @staticmethod
        def integers(min_value=0, max_value=10, **_kw):
            return _Strategy(
                int(min_value), int(max_value),
                lambda rng: int(rng.integers(min_value, max_value + 1)),
            )

    def settings(max_examples=20, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strats):
        def deco(fn):
            n_examples = getattr(fn, "_max_examples", 20)
            seed = zlib.crc32(fn.__qualname__.encode())

            def wrapper(*args, **kwargs):
                rng = np.random.default_rng(seed)
                for i in range(n_examples):
                    drawn = {k: s.draw(rng, i) for k, s in strats.items()}
                    fn(*args, **drawn, **kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            # hide the given-supplied parameters from pytest's fixture
            # resolution (hypothesis does the same via its own wrapper)
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items() if name not in strats
            ])
            return wrapper

        return deco
