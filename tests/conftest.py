import gc
import os
import sys

import pytest

# Make `import repro` work regardless of how pytest is invoked.
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

# Keep CPU device count at 1 for tests (the 512-device override belongs ONLY
# to launch/dryrun.py, which is exercised via subprocesses).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Property tests must draw the same examples on every run and every machine
# (tier-1 regressions are diffed across commits).  When the real hypothesis
# is installed, register and load a derandomized profile; the fallback shim
# in tests/_hypothesis_compat.py is deterministic by construction.
try:
    from hypothesis import settings as _hyp_settings

    _hyp_settings.register_profile(
        "repro-deterministic", derandomize=True, deadline=None,
        print_blob=False)
    _hyp_settings.load_profile("repro-deterministic")
except ModuleNotFoundError:
    pass


@pytest.fixture(autouse=True, scope="module")
def _bound_jax_memory():
    """Drop jit/compile caches after every test module.

    The suite compiles hundreds of distinct programs (10 architectures x
    forward/train/decode x kernel sweeps); without this the accumulated
    executables exhaust host RAM late in the run and jaxlib aborts with a
    native bad_alloc."""
    yield
    import jax

    jax.clear_caches()
    gc.collect()
