"""Shared test utilities: small instances and random loop-free strategies."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import network
from repro.core.traffic import Phi, renormalize


def small_instances(seeds=(0,)):
    """A few small Table-II-style instances for fast tests."""
    out = []
    for s in seeds:
        out.append(network.table_ii_instance("abilene", seed=s))
        out.append(network.table_ii_instance("balanced-tree", seed=s))
    return out


def random_loopfree_phi(inst: network.Instance, seed: int = 0) -> Phi:
    """Sample a random feasible loop-free strategy.

    Loop-freedom by construction: draw a random node potential per stage and
    allow link fractions only 'downhill'; every node keeps some CPU mass
    (when allowed) so each row is normalizable.  Rows with no downhill
    neighbour at the final stage fall back to the shortest-path successor.
    """
    from repro.core import gp

    rng = np.random.default_rng(seed)
    A, K1, V = inst.A, inst.K1, inst.V
    adj = np.asarray(inst.adj)

    dist, _ = gp.expanded_shortest_path(inst)
    dist = np.asarray(dist)                                   # (A,K1,V)

    e = np.zeros((A, K1, V, V), dtype=np.float32)
    c = np.zeros((A, K1, V), dtype=np.float32)
    cpu_ok = np.asarray(inst.cpu_allowed())
    for a in range(A):
        for k in range(K1):
            if cpu_ok[a, k]:
                # intermediate stages: any random potential works because
                # the CPU direction always lets a stuck row terminate
                pot = rng.permutation(V).astype(float)
                downhill = adj & (pot[None, :] < pot[:, None])
                c[a, k] = rng.uniform(0.2, 1.0, V)
            else:
                # final stage: use the shortest-path cost-to-go as the
                # potential — every non-destination node has a strictly
                # downhill neighbour, so downhill routing reaches d_a
                pot = dist[a, k]
                downhill = adj & (pot[None, :] < pot[:, None] - 1e-9)
            e[a, k] = rng.uniform(0.1, 1.0, (V, V)) * downhill
    return renormalize(inst, Phi(e=jnp.asarray(e), c=jnp.asarray(c)))
