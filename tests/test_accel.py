"""§15 convergence-acceleration layer (engine.AccelConfig).

Covers the ISSUE-6 acceptance criteria:

  * cost parity — accelerated final costs match the plain solver on every
    Table II scenario (within convergence tolerance; acceleration changes
    where the iteration STOPS, never what it converges to);
  * residual-based stopping — the exact sufficiency residual stop and the
    phi-delta fixed-point stop land on the same cost;
  * Anderson safeguard — a forced cost-increasing mix falls back to the
    plain GP step (monotone descent survives a poisoned history), and a
    genuinely better mix is accepted;
  * iteration reduction — the accelerated fig5/fig6 families spend
    >= 1.5x fewer total GP iterations than the committed BENCH_gp.json
    plain rows at equal-or-lower per-member cost (slow tier);
  * sharded parity — accelerated 2-shard trajectories match the
    accelerated single-device ones <= 1e-4 (multi-device only);
  * AUTO_MIN_V derivation from committed gp_scaling rows.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks import common
from repro.core import compat, distributed, engine, gp, network, scenarios
from repro.core import traffic

SMALL = ["abilene", "balanced-tree", "connected-er", "fog", "lhc", "geant"]

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2, reason="needs >= 2 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")


def _mesh(n):
    return compat.make_mesh((n,), ("stage",))


def _rel_dev(a, b):
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    return float(np.max(np.abs(a - b) / np.maximum(np.abs(b), 1e-9)))


# ---------------------------------------------------------------- config


def test_resolve_accel_forms():
    assert engine.resolve_accel(None) is None
    assert engine.resolve_accel(False) is None
    assert engine.resolve_accel(True) is engine.DEFAULT_ACCEL
    assert engine.resolve_accel("default") is engine.DEFAULT_ACCEL
    cfg = engine.AccelConfig(anderson_m=5)
    assert engine.resolve_accel(cfg) is cfg
    with pytest.raises(TypeError):
        engine.resolve_accel({"anderson_m": 3})


def test_accel_off_is_bit_identical_to_legacy():
    inst = network.table_ii_instance("abilene", seed=0, rate_scale=2.0)
    a = gp.solve(inst, alpha=0.1, max_iters=60)
    b = gp.solve(inst, alpha=0.1, max_iters=60, accel=None)
    assert int(a.iterations) == int(b.iterations)
    assert np.array_equal(np.asarray(a.cost_history),
                          np.asarray(b.cost_history))


# ----------------------------------------------------------- cost parity


@pytest.mark.parametrize("name", SMALL)
def test_accel_cost_parity_table_ii(name):
    inst = network.table_ii_instance(name, seed=0, rate_scale=2.0)
    plain = gp.solve(inst, alpha=0.1, max_iters=600)
    acc = gp.solve(inst, alpha=0.1, max_iters=600, accel=True)
    # acceleration must not land on a worse operating point: equal within
    # the solver's own convergence tolerance (both runs stop at tol=1e-4)
    assert acc.final_cost <= plain.final_cost * (1 + 1e-4)


def test_adaptive_alpha_only_cost_parity():
    # the adaptive-stepsize mechanism alone (Anderson + residual stop off):
    # converges to the same operating point as the full 12-rung ladder
    inst = network.table_ii_instance("abilene", seed=0, rate_scale=2.0)
    acc = engine.AccelConfig(anderson_m=0, adaptive_alpha=True,
                             residual_stop=False)
    plain = gp.solve(inst, alpha=0.1, max_iters=600)
    ada = gp.solve(inst, alpha=0.1, max_iters=600, accel=acc)
    assert ada.final_cost <= plain.final_cost * (1 + 1e-4)


def test_accel_reduces_iterations_single_device():
    # the headline mechanism check on two Table II instances where the
    # plain ladder needs many iterations; the >= 1.5x family-level claim
    # is the slow-tier test below
    for name in ("abilene", "balanced-tree"):
        inst = network.table_ii_instance(name, seed=0, rate_scale=2.0)
        plain = gp.solve(inst, alpha=0.1, max_iters=600)
        acc = gp.solve(inst, alpha=0.1, max_iters=600, accel=True)
        assert int(acc.iterations) < int(plain.iterations)


# ------------------------------------------------------ stopping criteria


@pytest.mark.parametrize("name", ["abilene", "connected-er", "geant"])
def test_residual_stop_matches_phi_delta_stop(name):
    inst = network.table_ii_instance(name, seed=0, rate_scale=2.0)
    # residual latch only (phi-delta disabled via phi_tol < 0)
    res = gp.solve(inst, alpha=0.1, max_iters=600,
                   accel=engine.DEFAULT_ACCEL._replace(phi_tol=-1.0))
    # phi-delta latch only (residual tol disabled via tol < 0); phi_tol
    # tightened one decade so the fixed-point stop is as converged as the
    # tol=1e-4 residual stop — comparable stopping tightness is what makes
    # the 1e-5 cost-agreement contract meaningful
    phid = gp.solve(inst, alpha=0.1, max_iters=600, tol=-1.0,
                    accel=engine.DEFAULT_ACCEL._replace(phi_tol=1e-7))
    rel = abs(res.final_cost - phid.final_cost) / max(abs(res.final_cost),
                                                      1e-9)
    assert rel <= 1e-5


# ------------------------------------------------------ Anderson safeguard


def _poisoned_chunk(inst, phi_k, acc, slot_vec):
    """One accel iteration from ``phi_k`` with ``slot_vec`` planted as the
    sole Anderson history iterate (residual 0 => the mix lands ~on it)."""
    carry = engine.init_carry(inst, phi_k, accel=acc)
    carry = carry._replace(ax=carry.ax.at[-1].set(slot_vec),
                           ak=jnp.int32(1))
    out, _ = engine.scan_chunk(
        inst, carry, jnp.float32(0.1), jnp.float32(-1.0),
        jnp.int32(10 ** 6), jnp.int32(10 ** 6), None, None,
        length=1, accel=acc)
    return out


def test_anderson_safeguard_rejects_cost_increasing_mix():
    inst = network.table_ii_instance("abilene", seed=0, rate_scale=2.0)
    # descend for a while, then poison the history with the (expensive)
    # initial strategy: the single-slot mix reconstructs ~phi0, whose cost
    # is far above the current iterate => the safeguard must commit the
    # plain step instead
    phi0 = gp.init_phi(inst)
    phi_k = gp.solve_scan(inst, alpha=0.1, max_iters=30, tol=0.0,
                          patience=10 ** 6).phi
    acc = engine.DEFAULT_ACCEL._replace(phi_tol=-1.0)
    plain = engine.gp_step(inst, phi_k, 0.1, accel=acc)

    out = _poisoned_chunk(inst, phi_k, acc, engine._flat_phi(phi0))
    assert _rel_dev(engine._flat_phi(out.phi),
                    engine._flat_phi(plain.phi)) <= 1e-6
    assert float(out.cost) <= float(plain.cost) * (1 + 1e-6)


def test_anderson_accepts_cost_decreasing_mix():
    # positive control: plant the CONVERGED strategy in the history slot —
    # the mix reconstructs it, beats the plain step, and is accepted
    inst = network.table_ii_instance("abilene", seed=0, rate_scale=2.0)
    phi_star = gp.solve(inst, alpha=0.1, max_iters=600).phi
    cost_star = float(engine._strategy_cost(inst, phi_star, "auto", None))
    phi_k = gp.solve_scan(inst, alpha=0.1, max_iters=30, tol=0.0,
                          patience=10 ** 6).phi
    acc = engine.DEFAULT_ACCEL._replace(phi_tol=-1.0)
    plain = engine.gp_step(inst, phi_k, 0.1, accel=acc)

    out = _poisoned_chunk(inst, phi_k, acc, engine._flat_phi(phi_star))
    assert float(out.cost) < float(plain.cost)
    assert float(out.cost) <= cost_star * (1 + 1e-5)


# ------------------------------------------------- batched / sharded parity


def test_batched_accel_matches_serial_accel():
    kw = dict(alpha=0.1, max_iters=120, accel=True)
    sweep = scenarios.run_sweep(
        "seed-ensemble", sweep_kwargs={"scenario": "abilene", "n_seeds": 4},
        **kw)
    serial = scenarios.run_sweep_serial(
        "seed-ensemble", sweep_kwargs={"scenario": "abilene", "n_seeds": 4},
        **kw)
    for b, s in zip(sweep.results, serial.results):
        assert abs(b.final_cost - s.final_cost) \
            <= 1e-4 * max(abs(s.final_cost), 1e-9)


# pinned-iteration kwargs: every stop latch disabled (tol<0 kills the
# residual stop, phi_tol<0 the fixed-point latch, patience the stall one)
# so single-device and sharded runs commit exactly max_iters iterations
# and their trajectories compare elementwise
PIN = dict(alpha=0.1, max_iters=40, patience=10 ** 6, tol=-1.0,
           accel=engine.DEFAULT_ACCEL._replace(phi_tol=-1.0))


@multi_device
def test_sharded_accel_matches_single_device():
    inst = network.table_ii_instance("abilene", seed=0, rate_scale=2.0)
    phi0 = gp.init_phi(inst)
    ref = gp.solve(inst, phi0, **PIN)
    res = distributed.solve_sharded(inst, _mesh(2), phi0=phi0, **PIN)
    assert _rel_dev(res.cost_history, ref.cost_history) <= 1e-4
    assert abs(res.final_cost - ref.final_cost) \
        <= 1e-4 * abs(ref.final_cost)


@multi_device
def test_sharded_accel_four_shards():
    n = min(4, len(jax.devices()))
    inst = network.table_ii_instance("geant", seed=0, rate_scale=2.0)
    phi0 = gp.init_phi(inst)
    ref = gp.solve(inst, phi0, **PIN)
    res = distributed.solve_sharded(inst, _mesh(n), phi0=phi0, **PIN)
    assert _rel_dev(res.cost_history, ref.cost_history) <= 1e-4


# ------------------------------------------------ iteration-count acceptance


def _committed_iters(bench, scenario, solver):
    rows = common.load_rows(common.BENCH_PATH)
    for r in rows:
        if (r.get("bench"), r.get("scenario"),
                r.get("solver")) == (bench, scenario, solver):
            return int(r["iters"])
    return None


@pytest.mark.slow
def test_fig5_ensemble_iters_reduced_1p5x():
    committed = _committed_iters("fig5", "abilene-ensemble32", "GP-batched")
    if committed is None:
        pytest.skip("no committed fig5 GP-batched iters row")
    kw = dict(sweep_kwargs={"scenario": "abilene", "n_seeds": 32},
              alpha=0.1, max_iters=250)
    plain = scenarios.run_sweep("seed-ensemble", **kw)
    acc = scenarios.run_sweep("seed-ensemble", accel=True, **kw)
    total = sum(int(r.iterations) for r in acc.results)
    assert total * 1.5 <= committed, (total, committed)
    for a, p in zip(acc.results, plain.results):
        assert a.final_cost <= p.final_cost * (1 + 1e-4)


@pytest.mark.slow
def test_fig6_congestion_iters_reduced_1p5x():
    committed = _committed_iters("fig6", "abilene-rates", "GP-batched")
    if committed is None:
        pytest.skip("no committed fig6 GP-batched iters row")
    kw = dict(alpha=0.1, max_iters=300)
    plain = scenarios.run_sweep("fig6-congestion", **kw)
    acc = scenarios.run_sweep("fig6-congestion", accel=True, **kw)
    total = sum(int(r.iterations) for r in acc.results)
    assert total * 1.5 <= committed, (total, committed)
    for a, p in zip(acc.results, plain.results):
        assert a.final_cost <= p.final_cost * (1 + 1e-4)


# -------------------------------------------------------------- AUTO_MIN_V


def _scaling_row(V, speedup):
    return {"bench": "gp_scaling", "scenario": f"V{V}", "V": V,
            "solver": "batched_lu", "seconds": 1.0, "speedup": speedup}


def test_auto_min_v_interpolates_crossing():
    rows = [_scaling_row(20, 0.5), _scaling_row(40, 1.5)]
    # crossing at V = 20 + 0.5/1.0 * 20 = 30
    assert traffic._derive_auto_min_v(rows) == 30


def test_auto_min_v_edge_cases():
    assert traffic._derive_auto_min_v([]) == traffic._AUTO_MIN_V_FALLBACK
    # already >= 1 at the smallest measured size
    rows = [_scaling_row(10, 1.2), _scaling_row(40, 2.0)]
    assert traffic._derive_auto_min_v(rows) == 10
    # never crosses: fall back rather than extrapolate
    rows = [_scaling_row(10, 0.2), _scaling_row(40, 0.8)]
    assert traffic._derive_auto_min_v(rows) == traffic._AUTO_MIN_V_FALLBACK
    # non-scaling rows are ignored
    rows = [{"bench": "fig5", "V": 11, "solver": "GP", "speedup": 9.0}]
    assert traffic._derive_auto_min_v(rows) == traffic._AUTO_MIN_V_FALLBACK


def test_auto_min_v_module_constant_is_sane():
    assert 2 <= traffic.AUTO_MIN_V <= 512
