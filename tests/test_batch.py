"""Batched scenario engine: padding invariants and solve parity.

The two acceptance properties of the batch layer:
  (a) the device-resident scan (``solve_scan`` / ``vmap(solve_scan)``)
      reproduces the reference python-loop driver on Table II scenarios;
  (b) a padded multi-seed batch reproduces the individual serial solves.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import batch, gp, network, scenarios, traffic

SMALL_TABLE_II = ["abilene", "balanced-tree", "connected-er", "fog", "lhc", "geant"]


# ---------------------------------------------------------------------------
# (a) scan == loop == chunked solve
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["abilene", "balanced-tree", "fog"])
def test_solve_scan_matches_reference_loop(name):
    inst = network.table_ii_instance(name, seed=0, rate_scale=2.0)
    loop = gp.solve_loop(inst, alpha=0.1, max_iters=120)
    scan = gp.solve_scan(inst, alpha=0.1, max_iters=120)
    fast = gp.solve(inst, alpha=0.1, max_iters=120)
    assert int(scan.iterations) == loop.iterations == fast.iterations
    assert float(scan.cost) == pytest.approx(loop.final_cost, rel=1e-5)
    assert fast.final_cost == pytest.approx(loop.final_cost, rel=1e-5)
    # identical trajectories, not just identical endpoints
    n = loop.iterations
    np.testing.assert_allclose(
        np.asarray(scan.cost_history[: n + 1]),
        np.asarray(loop.cost_history), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(fast.cost_history), np.asarray(loop.cost_history), rtol=1e-6)


def test_scan_history_dense_contract():
    """Entries past ``iterations`` repeat the converged value."""
    inst = network.table_ii_instance("balanced-tree", seed=0)
    scan = gp.solve_scan(inst, alpha=0.1, max_iters=80)
    it = int(scan.iterations)
    ch = np.asarray(scan.cost_history)
    assert ch.shape == (81,)
    assert np.all(ch[it:] == ch[it])
    res = gp.GPResult(phi=scan.phi, cost_history=scan.cost_history,
                      residual_history=scan.residual_history, iterations=it)
    trimmed = res.trim()
    assert trimmed.cost_history.shape == (it + 1,)
    assert trimmed.residual_history.shape == (it,)
    assert trimmed.final_cost == pytest.approx(float(scan.cost), rel=1e-6)


# ---------------------------------------------------------------------------
# (b) padded batches reproduce serial solves
# ---------------------------------------------------------------------------

def test_vmap_solve_scan_padded_table_ii_batch():
    """vmap(solve_scan) over an 8-member padded batch spanning six Table II
    topologies matches serial gp.solve within 1e-5 (fixed iteration budget
    so both paths commit exactly the same number of steps)."""
    insts = [
        network.table_ii_instance(n, seed=s, rate_scale=1.5)
        for n in SMALL_TABLE_II
        for s in ((0, 1) if n in ("abilene", "geant") else (0,))
    ]
    assert len(insts) == 8
    binst = batch.pad_instances(insts)
    # tol < 0 disables the residual stop (a residual can hit exactly 0.0 in
    # one path and 1e-9 in the other); patience off => exactly 60 steps
    kw = dict(alpha=0.1, max_iters=60, tol=-1.0, patience=10**6)
    out = jax.vmap(lambda i: gp.solve_scan(i, **kw))(binst)
    for b, inst in enumerate(insts):
        ser = gp.solve(inst, **kw)
        assert float(out.cost[b]) == pytest.approx(ser.final_cost, rel=1e-5), b
        assert int(out.iterations[b]) == ser.iterations == 60


def test_padded_seed_ensemble_reproduces_individual_solves():
    """An 8-seed padded batch (solve_batched, with compaction) reproduces
    the 8 individual converged solves."""
    insts = [network.table_ii_instance("abilene", seed=s, rate_scale=2.0)
             for s in range(8)]
    binst = batch.pad_instances(insts)
    out = gp.solve_batched(binst, alpha=0.1, max_iters=200)
    for b, inst in enumerate(insts):
        ser = gp.solve(inst, alpha=0.1, max_iters=200)
        assert float(out.cost[b]) == pytest.approx(ser.final_cost, rel=1e-5), b


# ---------------------------------------------------------------------------
# padding invariants
# ---------------------------------------------------------------------------

def test_padding_preserves_cost_and_feasibility():
    """A padded instance yields the same objective for the (padded) optimal
    strategy, and padded rows carry no strategy mass."""
    inst = network.table_ii_instance("abilene", seed=0, rate_scale=2.0)
    res = gp.solve(inst, alpha=0.1, max_iters=100)
    V, A, K1 = inst.V + 5, inst.A + 2, inst.K1 + 1
    pinst = batch.pad_instance(inst, V, A, K1)
    pphi = batch.pad_phi(res.phi, V, A, K1, inst)
    c0 = float(traffic.total_cost(inst, res.phi))
    c1 = float(traffic.total_cost(pinst, pphi))
    assert c1 == pytest.approx(c0, rel=1e-5)
    assert float(traffic.feasibility_violation(pinst, pphi)) < 1e-4
    # dead apps/stages must stay degenerate under renormalization
    rphi = traffic.renormalize(pinst, pphi)
    assert float(jnp.abs(rphi.e[inst.A:]).max()) == 0.0
    assert float(jnp.abs(rphi.c[inst.A:]).max()) == 0.0
    assert float(jnp.abs(rphi.e[:, inst.K1:]).max()) == 0.0
    # one GP step on the padded instance keeps dead rows dead and stays valid
    state = gp.gp_step(pinst, rphi, 0.1)
    fl = traffic.flows(pinst, state.phi)
    assert bool(traffic.traffic_is_valid(pinst, fl.t))
    assert float(jnp.abs(state.phi.e[inst.A:]).max()) == 0.0


def test_pad_phi_roundtrip():
    inst = network.table_ii_instance("balanced-tree", seed=1)
    phi = gp.init_phi(inst)
    padded = batch.pad_phi(phi, inst.V + 3, inst.A + 1, inst.K1 + 2)
    back = batch.unpad_phi(padded, inst)
    np.testing.assert_array_equal(np.asarray(back.e), np.asarray(phi.e))
    np.testing.assert_array_equal(np.asarray(back.c), np.asarray(phi.c))


def test_pad_instances_rejects_mixed_cost_kinds():
    a = network.table_ii_instance("sw-linear", seed=0)
    b = network.table_ii_instance("abilene", seed=0)
    with pytest.raises(ValueError, match="cost famil"):
        batch.pad_instances([a, b])


# ---------------------------------------------------------------------------
# scenario layer
# ---------------------------------------------------------------------------

def test_scenario_registry_expands():
    for name in scenarios.SWEEPS:
        family = scenarios.expand(
            name, **({"n_seeds": 2} if name == "seed-ensemble" else {}))
        assert len(family) >= 2
        labels = [sc.label for sc in family]
        assert len(set(labels)) == len(labels)
    with pytest.raises(KeyError):
        scenarios.expand("no-such-sweep")


def test_run_sweep_chained_warm_start():
    """Warm-start chaining solves the identical problems: every member of a
    rate ladder descends from its (possibly inherited) start and lands at
    (or below) the cold-start optimum within tolerance."""
    skw = {"scales": (1.0, 1.5)}
    kw = dict(alpha=0.1, max_iters=120)
    cold = scenarios.run_sweep_serial("fig6-congestion", sweep_kwargs=skw, **kw)
    warm = scenarios.run_sweep_chained("fig6-congestion", sweep_kwargs=skw, **kw)
    assert len(warm.results) == 2
    # member 0 has no predecessor: identical cold solve
    assert warm.results[0].final_cost == pytest.approx(
        cold.results[0].final_cost, rel=1e-6)
    for c, w in zip(cold.results, warm.results):
        assert w.final_cost <= c.final_cost * 1.01
        ch = np.asarray(w.cost_history)
        assert ch[-1] <= ch[0] + 1e-6          # still a descent


def test_run_sweep_chained_shape_change_falls_back_cold():
    """A topology change mid-chain cannot inherit phi — it must cold-start,
    not crash or mis-shape."""
    fam = [
        network.table_ii_instance("abilene", seed=0, rate_scale=1.5),
        network.table_ii_instance("balanced-tree", seed=0, rate_scale=1.5),
    ]
    scens = [scenarios.Scenario(label=f"m{i}", instance=inst)
             for i, inst in enumerate(fam)]
    warm = scenarios.run_sweep_chained(scens, alpha=0.1, max_iters=30)
    ref = gp.solve(fam[1], alpha=0.1, max_iters=30)
    assert warm.results[1].final_cost == pytest.approx(ref.final_cost, rel=1e-6)


def test_run_sweep_chained_same_shape_different_dst_falls_back_cold():
    """Two instances can share (A, K1, V, V) — and even the graph — while
    disagreeing on destinations/chain structure (seed ensembles re-place
    the apps); inheriting phi across them aims mass at the wrong exits.
    The chain must detect the mismatch and cold-start, not inherit on
    shape equality alone."""
    fam = [network.table_ii_instance("abilene", seed=s, rate_scale=1.5)
           for s in (0, 1)]
    assert fam[0].adj.shape == fam[1].adj.shape
    assert not np.array_equal(np.asarray(fam[0].dst), np.asarray(fam[1].dst))
    scens = [scenarios.Scenario(label=f"ab{s}", instance=inst)
             for s, inst in enumerate(fam)]
    warm = scenarios.run_sweep_chained(scens, alpha=0.1, max_iters=25)
    ref = gp.solve(fam[1], alpha=0.1, max_iters=25)
    assert warm.results[1].final_cost == pytest.approx(ref.final_cost, rel=1e-6)
    assert np.isfinite(np.asarray(warm.results[1].cost_history)).all()


def test_run_sweep_groups_by_kind_and_size():
    """Mixed cost families and far-apart sizes split into separate batches
    but results stay aligned with the scenario list."""
    family = scenarios.expand("fig6-congestion", scales=(0.5, 1.0))
    sweep = scenarios.run_sweep(family, alpha=0.1, max_iters=40,
                                tol=-1.0, patience=10**6)
    assert sweep.n_batches == 1
    assert len(sweep.results) == 2
    for sc, res in zip(sweep.scenarios, sweep.results):
        ser = gp.solve(sc.instance, alpha=0.1, max_iters=40,
                       tol=-1.0, patience=10**6)
        assert res.final_cost == pytest.approx(ser.final_cost, rel=1e-5)
        assert res.phi.e.shape == ser.phi.e.shape
