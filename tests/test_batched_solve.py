"""Batched-LU kernel (kernels/batched_solve.py) vs oracles, and the GP
integration contract: shared stage factorization == the seed dense path.

Covers the PR's kernel deliverables:
  * parity vs ``vmap(jnp.linalg.solve)`` across dtypes and batch shapes,
    for both the reference (LAPACK) path and the Pallas interpret path;
  * a singular / near-singular member raises the per-member flag without
    poisoning the rest of the batch;
  * end-to-end ``gp.solve`` cost parity vs the seed per-stage solver.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gp, network, traffic
from repro.core.marginals import marginals
from repro.core.traffic import Phi, flows, stage_factors, traffic_is_valid
from repro.kernels import ops
from repro.kernels import batched_solve as bs


def _mk_systems(key, B, V, dtype=jnp.float32, spread=0.5):
    """Well-conditioned stage-like systems I - c*row-substochastic."""
    k1, k2 = jax.random.split(key)
    P = jax.random.uniform(k1, (B, V, V), dtype=jnp.float32)
    P = spread * P / jnp.sum(P, axis=-1, keepdims=True)
    mats = (jnp.eye(V) - P).astype(dtype)
    rhs = jax.random.uniform(k2, (B, V), dtype=jnp.float32).astype(dtype)
    return mats, rhs


def _oracle(mats, rhs, trans=0):
    m = mats.astype(jnp.float32)
    m = m.transpose(0, 2, 1) if trans else m
    return jnp.linalg.solve(m, rhs.astype(jnp.float32)[..., None])[..., 0]


@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["ref_lapack", "pallas_interpret"])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5), (jnp.bfloat16, 5e-2)])
@pytest.mark.parametrize("B,V", [(1, 3), (4, 11), (7, 33), (3, 100)])
def test_batched_solve_parity(B, V, dtype, tol, use_pallas):
    mats, rhs = _mk_systems(jax.random.PRNGKey(B * 1000 + V), B, V, dtype)
    want = _oracle(mats, rhs)
    x, resid = ops.batched_solve(mats, rhs, use_pallas=use_pallas)
    np.testing.assert_allclose(np.asarray(x), np.asarray(want),
                               atol=tol, rtol=tol)
    assert np.all(np.asarray(resid) < tol)


@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["ref_lapack", "pallas_interpret"])
@pytest.mark.parametrize("trans", [0, 1])
def test_factor_solve_trans(trans, use_pallas):
    mats, rhs = _mk_systems(jax.random.PRNGKey(7), 5, 37)
    fact = ops.batched_factor(mats, use_pallas=use_pallas)
    x = ops.batched_solve_factored(fact, rhs, trans=trans,
                                   use_pallas=use_pallas)
    np.testing.assert_allclose(np.asarray(x),
                               np.asarray(_oracle(mats, rhs, trans)),
                               atol=1e-5, rtol=1e-5)
    assert bool(jnp.all(fact.ok))


@pytest.mark.parametrize("trans", [0, 1])
def test_factors_are_path_portable(trans):
    """Pivoted reference factors solve correctly through the kernel path
    (perm is honored there) and vice versa within the kernel's M-matrix
    domain — mixing use_pallas between factor and solve is valid."""
    mats, rhs = _mk_systems(jax.random.PRNGKey(5), 4, 29)
    # force non-trivial pivoting for the reference factorization
    mats = mats[:, ::-1, :] + 0.0
    want = _oracle(mats, rhs, trans)
    fact = ops.batched_factor(mats, use_pallas=False)
    assert bool(jnp.any(fact.perm != jnp.arange(29)))
    x = ops.batched_solve_factored(fact, rhs, trans=trans, use_pallas=True)
    np.testing.assert_allclose(np.asarray(x), np.asarray(want),
                               atol=1e-5, rtol=1e-5)

    mats_dd, rhs_dd = _mk_systems(jax.random.PRNGKey(6), 4, 29)
    want_dd = _oracle(mats_dd, rhs_dd, trans)
    fact_p = ops.batched_factor(mats_dd, use_pallas=True)
    x2 = ops.batched_solve_factored(fact_p, rhs_dd, trans=trans,
                                    use_pallas=False)
    np.testing.assert_allclose(np.asarray(x2), np.asarray(want_dd),
                               atol=1e-5, rtol=1e-5)


def test_leading_batch_dims_and_vmap():
    """(A, K1, V, V) leading dims flatten/restore, and vmap composes."""
    mats, rhs = _mk_systems(jax.random.PRNGKey(3), 12, 9)
    mats4, rhs4 = mats.reshape(3, 4, 9, 9), rhs.reshape(3, 4, 9)
    fact = ops.batched_factor(mats4)
    assert fact.lu.shape == (3, 4, 9, 9) and fact.ok.shape == (3, 4)
    x = ops.batched_solve_factored(fact, rhs4)
    np.testing.assert_allclose(np.asarray(x.reshape(12, 9)),
                               np.asarray(_oracle(mats, rhs)), atol=1e-5)
    xv = jax.vmap(lambda m, b: ops.batched_solve(m, b)[0])(mats4, rhs4)
    np.testing.assert_allclose(np.asarray(xv), np.asarray(x), atol=1e-6)


@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["ref_lapack", "pallas_interpret"])
def test_singular_member_flags_without_poisoning(use_pallas):
    """One singular member -> its ok flag drops and its residual is inf,
    while every other member still solves to oracle accuracy."""
    mats, rhs = _mk_systems(jax.random.PRNGKey(11), 6, 23)
    bad = 2
    mats = mats.at[bad].set(mats[bad].at[:, 5].set(0.0).at[5, :].set(0.0))
    want = _oracle(mats, rhs)

    fact = ops.batched_factor(mats, use_pallas=use_pallas)
    ok = np.asarray(fact.ok)
    assert not ok[bad] and ok[np.arange(6) != bad].all()

    x, resid = ops.batched_solve(mats, rhs, use_pallas=use_pallas)
    resid = np.asarray(resid)
    assert not np.isfinite(resid[bad]) or resid[bad] > 1e3
    good = np.arange(6) != bad
    np.testing.assert_allclose(np.asarray(x)[good], np.asarray(want)[good],
                               atol=1e-5, rtol=1e-5)
    assert np.all(resid[good] < 1e-5)


@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["ref_lapack", "pallas_interpret"])
@pytest.mark.parametrize("trans,reverse,clamp",
                         [(1, False, False), (0, True, True)])
def test_fused_chain_solve_matches_per_stage_loop(trans, reverse, clamp,
                                                  use_pallas):
    """ops.fused_chain_solve == the per-stage batched_solve_factored loop
    it replaced, for both GP sweep shapes (traffic: trans=1 forward;
    marginals: trans=0 reverse clamped)."""
    Bf, K, V = 3, 5, 22
    keys = jax.random.split(jax.random.PRNGKey(K * V), 3)
    P = jax.random.uniform(keys[0], (Bf, K, V, V))
    mats = jnp.eye(V) - 0.5 * P / jnp.sum(P, axis=-1, keepdims=True)
    base = jax.random.uniform(keys[1], (Bf, K, V)) - (0.5 if clamp else 0.0)
    mult = jax.random.uniform(keys[2], (Bf, K, V))
    fact = ops.batched_factor(mats, use_pallas=use_pallas)

    carry = jnp.zeros((Bf, V))
    want = [None] * K
    for k in (range(K - 1, -1, -1) if reverse else range(K)):
        b = base[:, k] + mult[:, k] * carry
        fk = jax.tree_util.tree_map(lambda x: x[:, k], fact)
        x = ops.batched_solve_factored(fk, b, trans=trans,
                                       use_pallas=use_pallas)
        if clamp:
            x = jnp.maximum(x, 0.0)
        want[k] = x
        carry = x
    got = ops.fused_chain_solve(fact, base, mult, trans=trans,
                                reverse=reverse, clamp=clamp,
                                use_pallas=use_pallas)
    np.testing.assert_allclose(np.asarray(got), np.asarray(jnp.stack(want, 1)),
                               atol=1e-5, rtol=1e-5)


def test_stage_factors_serve_both_sweeps():
    """One ``stage_factors`` factorization reproduces BOTH the traffic
    (transposed) and marginal (plain) sweeps of the dense seed path."""
    inst = network.table_ii_instance("abilene", seed=0, rate_scale=2.0)
    phi = gp.init_phi(inst)
    fact = stage_factors(phi.e)
    assert bool(jnp.all(fact.ok))

    fl_lu = flows(inst, phi, fact, solver="batched_lu")
    fl_dense = flows(inst, phi, solver="dense")
    np.testing.assert_allclose(np.asarray(fl_lu.t), np.asarray(fl_dense.t),
                               atol=1e-5, rtol=1e-5)

    m_lu = marginals(inst, phi, fl_lu, fact, solver="batched_lu")
    m_dense = marginals(inst, phi, fl_dense, solver="dense")
    np.testing.assert_allclose(np.asarray(m_lu.pdt), np.asarray(m_dense.pdt),
                               atol=1e-5, rtol=1e-5)


def test_loopy_strategy_divergence_still_detected():
    """DESIGN.md §2 contract: a routing loop must keep tripping
    ``traffic_is_valid`` under the factored path (no exception, no silent
    garbage) — the per-solve-exception-free flag contract of §12."""
    inst = network.table_ii_instance("abilene", seed=0)
    phi = gp.init_phi(inst)
    adj = np.asarray(inst.adj)
    i = int(np.flatnonzero(adj.any(1))[0])
    j = int(np.flatnonzero(adj[i])[0])
    assert adj[j, i], "abilene links are bidirectional"
    e = np.zeros_like(np.asarray(phi.e))
    e[:, :, i, j] = 1.0
    e[:, :, j, i] = 1.0          # i <-> j cycle: I - Phi singular
    loopy = Phi(e=jnp.asarray(e), c=jnp.zeros_like(phi.c))

    fact = stage_factors(loopy.e)
    assert not bool(jnp.all(fact.ok))
    fl = flows(inst, loopy, fact, solver="batched_lu")
    assert not bool(traffic_is_valid(inst, fl.t))


def test_resolve_solver_policy():
    """"auto" is backend/size-aware and static; explicit choices pass
    through untouched."""
    assert traffic.resolve_solver("dense", 100) == "dense"
    assert traffic.resolve_solver("batched_lu", 4) == "batched_lu"
    big = traffic.resolve_solver("auto", traffic.AUTO_MIN_V)
    assert big == "batched_lu"
    if ops.INTERPRET:      # CPU: small instances keep the dense fast path
        assert traffic.resolve_solver("auto", 11) == "dense"
    else:                  # accelerator: always the kernel path
        assert traffic.resolve_solver("auto", 11) == "batched_lu"


def test_gp_step_dense_vs_batched_lu():
    """One full projection step agrees across solvers (same argmin rung)."""
    inst = network.table_ii_instance("geant", seed=0, rate_scale=2.0)
    phi = gp.init_phi(inst)
    s_lu = gp.gp_step(inst, phi, 0.1, solver="batched_lu")
    s_dense = gp.gp_step(inst, phi, 0.1, solver="dense")
    np.testing.assert_allclose(float(s_lu.cost), float(s_dense.cost),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(s_lu.phi.e),
                               np.asarray(s_dense.phi.e), atol=1e-4)


def test_gp_solve_end_to_end_cost_parity():
    """Whole-solve parity vs the seed path: final cost within 1e-5 rel."""
    inst = network.table_ii_instance("abilene", seed=0, rate_scale=2.0)
    r_lu = gp.solve(inst, alpha=0.1, max_iters=400, solver="batched_lu")
    r_dense = gp.solve(inst, alpha=0.1, max_iters=400, solver="dense")
    rel = abs(r_lu.final_cost - r_dense.final_cost) / abs(r_dense.final_cost)
    assert rel <= 1e-5, (r_lu.final_cost, r_dense.final_cost)


def test_pallas_blocked_path_crosses_panel_boundary():
    """V > NB exercises the panel Neumann sweep + MXU trailing update."""
    V = bs.DEFAULT_NB * 2 + 5
    mats, rhs = _mk_systems(jax.random.PRNGKey(42), 2, V)
    lu = bs.lu_factor(mats, interpret=True)
    x = bs.lu_solve(lu, rhs, interpret=True)
    np.testing.assert_allclose(np.asarray(x), np.asarray(_oracle(mats, rhs)),
                               atol=1e-5, rtol=1e-5)
    assert np.asarray(bs.factor_ok(lu)).all()
