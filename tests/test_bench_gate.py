"""benchmarks.common.bench_check — the CI perf regression gate.

Pure row-diff logic (no jax), so these run in milliseconds in the quick
tier while CI's bench-smoke job exercises the same code end-to-end against
the committed BENCH_gp.json.
"""

import json

from benchmarks import common


def _row(bench="kernel_bench", scenario="batched_lu:x", V=20,
         solver="batched_lu", **extra):
    row = {"bench": bench, "scenario": scenario, "V": V, "solver": solver}
    row.update(extra)
    return row


def test_gate_passes_within_budget():
    base = [_row(seconds=1.0e-3)]
    fresh = [_row(seconds=1.4e-3)]            # 1.4x < 1.5x
    assert common.bench_check(base, fresh) == []


def test_gate_fails_above_budget():
    base = [_row(seconds=1.0e-3)]
    fresh = [_row(seconds=1.6e-3)]            # 1.6x > 1.5x
    failures = common.bench_check(base, fresh)
    assert len(failures) == 1
    assert "batched_lu:x" in failures[0]


def test_gate_prefers_s_per_iter_over_seconds():
    # wall seconds regressed 10x but per-iteration cost is flat (the run
    # simply committed more iterations) — the TIME gate must not fire; the
    # 10x iteration blow-up is exactly what the ITERS gate exists to catch
    base = [_row(bench="fig6", seconds=1.0, iters=100, s_per_iter=1e-2)]
    fresh = [_row(bench="fig6", seconds=10.0, iters=1000, s_per_iter=1e-2)]
    failures = common.bench_check(base, fresh)
    assert len(failures) == 1
    assert "1000 iters" in failures[0]        # the iters gate, not the time one
    # modest iteration drift (1.15x < 1.2x) passes both gates
    fresh = [_row(bench="fig6", seconds=1.15, iters=115, s_per_iter=1e-2)]
    assert common.bench_check(base, fresh) == []


def test_iters_gate_fires_and_respects_floor():
    base = [_row(bench="fig6", seconds=1.0, iters=100, s_per_iter=1e-2)]
    fresh = [_row(bench="fig6", seconds=1.3, iters=130, s_per_iter=1e-2)]
    failures = common.bench_check(base, fresh)        # 1.3x > 1.2x
    assert len(failures) == 1 and "130 iters" in failures[0]
    # trivially small counts are exempt (5 -> 7 is 1.4x but sub-floor)
    base = [_row(bench="fig6", seconds=1.0, iters=5, s_per_iter=0.2)]
    fresh = [_row(bench="fig6", seconds=1.0, iters=7, s_per_iter=0.14)]
    assert common.bench_check(base, fresh) == []
    # rows that gained/lost the iters field are schema drift, not failures
    base = [_row(bench="fig6", seconds=1.0)]
    fresh = [_row(bench="fig6", seconds=1.0, iters=900, s_per_iter=1e-3)]
    assert common.bench_check(base, fresh) == []


def test_delta_table_reports_both_metrics():
    base = [_row(bench="fig6", seconds=1.0, iters=100, s_per_iter=1e-2),
            _row(scenario="timeonly", seconds=2.0)]
    fresh = [_row(bench="fig6", seconds=0.5, iters=50, s_per_iter=1e-2),
             _row(scenario="timeonly", seconds=1.0),
             _row(scenario="unmatched", seconds=9.9)]
    lines = common.delta_table(base, fresh)
    assert len(lines) == 2                    # unmatched rows are skipped
    assert "iters 50/100 (0.50x)" in lines[0]
    assert lines[1].endswith("iters -")       # pair without iters


def test_gate_ignores_noise_floor_and_unmatched_rows():
    base = [_row(scenario="tiny", seconds=5e-5),
            _row(scenario="gone", seconds=1.0)]
    fresh = [_row(scenario="tiny", seconds=1.9e-4),   # 3.8x but sub-floor
             _row(scenario="new-row", seconds=9.9)]   # no baseline -> skip
    assert common.bench_check(base, fresh) == []


def test_gate_skips_schema_drift_pairs():
    # baseline recorded without iters, fresh gained s_per_iter: the shared
    # field is `seconds`, so the 10x wall regression must still fire...
    base = [_row(seconds=1.2)]
    fresh = [_row(seconds=12.0, iters=120, s_per_iter=0.1)]
    assert len(common.bench_check(base, fresh)) == 1
    # ...and two rows sharing NO metric field are skipped, not compared
    assert common.bench_check([_row(other=1)], [_row(s_per_iter=9.0)]) == []


def test_load_rows_tolerates_non_dict_json(tmp_path):
    p = tmp_path / "weird.json"
    p.write_text("[]")                 # valid JSON, wrong top-level type
    assert common.load_rows(str(p)) == []
    p.write_text("not json at all")
    assert common.load_rows(str(p)) == []


def test_gate_keyed_by_full_tuple():
    # same scenario, different solver => different measurement, no pairing
    base = [_row(solver="dense", seconds=1e-3)]
    fresh = [_row(solver="batched_lu", seconds=9e-3)]
    assert common.bench_check(base, fresh) == []


def test_check_main_round_trip(tmp_path):
    baseline = tmp_path / "baseline.json"
    fresh = tmp_path / "fresh.json"
    baseline.write_text(json.dumps({"rows": [_row(seconds=1e-3)]}))
    fresh.write_text(json.dumps({"rows": [_row(seconds=1e-3)]}))
    assert common._check_main(["--check", str(baseline),
                               "--fresh", str(fresh)]) == 0
    fresh.write_text(json.dumps({"rows": [_row(seconds=9e-3)]}))
    assert common._check_main(["--check", str(baseline),
                               "--fresh", str(fresh)]) == 1
    # empty/missing baseline: nothing to compare, gate stays green
    assert common._check_main(["--check", str(tmp_path / "missing.json"),
                               "--fresh", str(fresh)]) == 0
