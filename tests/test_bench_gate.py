"""benchmarks.common.bench_check — the CI perf regression gate.

Pure row-diff logic (no jax), so these run in milliseconds in the quick
tier while CI's bench-smoke job exercises the same code end-to-end against
the committed BENCH_gp.json.
"""

import json

from benchmarks import common


def _row(bench="kernel_bench", scenario="batched_lu:x", V=20,
         solver="batched_lu", **extra):
    row = {"bench": bench, "scenario": scenario, "V": V, "solver": solver}
    row.update(extra)
    return row


def test_gate_passes_within_budget():
    base = [_row(seconds=1.0e-3)]
    fresh = [_row(seconds=1.4e-3)]            # 1.4x < 1.5x
    assert common.bench_check(base, fresh) == []


def test_gate_fails_above_budget():
    base = [_row(seconds=1.0e-3)]
    fresh = [_row(seconds=1.6e-3)]            # 1.6x > 1.5x
    failures = common.bench_check(base, fresh)
    assert len(failures) == 1
    assert "batched_lu:x" in failures[0]


def test_gate_prefers_s_per_iter_over_seconds():
    # wall seconds regressed 10x but per-iteration cost is flat (the run
    # simply committed more iterations) — the gate must not fire
    base = [_row(bench="fig6", seconds=1.0, iters=100, s_per_iter=1e-2)]
    fresh = [_row(bench="fig6", seconds=10.0, iters=1000, s_per_iter=1e-2)]
    assert common.bench_check(base, fresh) == []


def test_gate_ignores_noise_floor_and_unmatched_rows():
    base = [_row(scenario="tiny", seconds=5e-5),
            _row(scenario="gone", seconds=1.0)]
    fresh = [_row(scenario="tiny", seconds=1.9e-4),   # 3.8x but sub-floor
             _row(scenario="new-row", seconds=9.9)]   # no baseline -> skip
    assert common.bench_check(base, fresh) == []


def test_gate_skips_schema_drift_pairs():
    # baseline recorded without iters, fresh gained s_per_iter: the shared
    # field is `seconds`, so the 10x wall regression must still fire...
    base = [_row(seconds=1.2)]
    fresh = [_row(seconds=12.0, iters=120, s_per_iter=0.1)]
    assert len(common.bench_check(base, fresh)) == 1
    # ...and two rows sharing NO metric field are skipped, not compared
    assert common.bench_check([_row(other=1)], [_row(s_per_iter=9.0)]) == []


def test_load_rows_tolerates_non_dict_json(tmp_path):
    p = tmp_path / "weird.json"
    p.write_text("[]")                 # valid JSON, wrong top-level type
    assert common.load_rows(str(p)) == []
    p.write_text("not json at all")
    assert common.load_rows(str(p)) == []


def test_gate_keyed_by_full_tuple():
    # same scenario, different solver => different measurement, no pairing
    base = [_row(solver="dense", seconds=1e-3)]
    fresh = [_row(solver="batched_lu", seconds=9e-3)]
    assert common.bench_check(base, fresh) == []


def test_check_main_round_trip(tmp_path):
    baseline = tmp_path / "baseline.json"
    fresh = tmp_path / "fresh.json"
    baseline.write_text(json.dumps({"rows": [_row(seconds=1e-3)]}))
    fresh.write_text(json.dumps({"rows": [_row(seconds=1e-3)]}))
    assert common._check_main(["--check", str(baseline),
                               "--fresh", str(fresh)]) == 0
    fresh.write_text(json.dumps({"rows": [_row(seconds=9e-3)]}))
    assert common._check_main(["--check", str(baseline),
                               "--fresh", str(fresh)]) == 1
    # empty/missing baseline: nothing to compare, gate stays green
    assert common._check_main(["--check", str(tmp_path / "missing.json"),
                               "--fresh", str(fresh)]) == 0
