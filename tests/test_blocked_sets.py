"""Bitset blocked-set kernel parity + batched-baseline parity.

Acceptance properties of PR 3's hot-path fusion:
  (a) the bit-packed tagged-node kernel (kernels/blocked_sets.py, both the
      packed-jnp and the interpret-mode Pallas path) equals the seed's
      dense V-sweep scan *bit for bit* — on random routing matrices, on
      random feasible strategies (which carry cycles and many improper
      links), and on congested mid-solve GP iterates;
  (b) the batched SPOC/LCOF baselines (mask constructors vmapped over
      padded families) reproduce the serial baselines on the Table II
      scenarios within 1e-4.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, gp, marginals, network, scenarios, traffic
from repro.kernels import blocked_sets as bset
from repro.kernels import ops


def _assert_tagged_parity(route, improper):
    ref = np.asarray(bset.tagged_scan_dense(route, improper))
    np.testing.assert_array_equal(
        np.asarray(ops.blocked_tagged(route, improper)), ref)
    np.testing.assert_array_equal(
        np.asarray(ops.blocked_tagged(route, improper, use_pallas=True)), ref)


# ---------------------------------------------------------------------------
# (a) kernel parity
# ---------------------------------------------------------------------------

def test_pack_unpack_roundtrip():
    x = jax.random.uniform(jax.random.PRNGKey(0), (3, 70)) < 0.5
    np.testing.assert_array_equal(
        np.asarray(bset.unpack_bits(bset.pack_bits(x), 70)), np.asarray(x))
    assert bset.pack_bits(x).dtype == jnp.uint32
    assert bset.pack_bits(x).shape == (3, 3)          # ceil(70 / 32)


@pytest.mark.parametrize("V", [5, 31, 32, 33, 100])
def test_bitset_matches_dense_scan_random(V):
    """Random sparse routing matrices, including word-boundary sizes."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(V))
    route = jax.random.uniform(k1, (6, V, V)) < 0.15
    improper = route & (jax.random.uniform(k2, (6, V, V)) < 0.3)
    assert bool(improper.any())
    _assert_tagged_parity(route, improper)


def test_bitset_matches_dense_scan_dense_cyclic():
    """Fully dense route graph (every node reaches every cycle) — the
    propagation worst case, and a non-DAG input the monotone fixed point
    still covers."""
    V = 40
    route = ~jnp.eye(V, dtype=bool)[None]
    improper = jnp.zeros_like(route).at[0, 3, 7].set(True)
    _assert_tagged_parity(route, improper)
    # everything reaching node 3 (here: all nodes) must be tagged
    assert bool(bset.tagged_scan_dense(route, improper).all())


@pytest.mark.parametrize("name", ["abilene", "geant"])
def test_blocked_sets_parity_on_random_strategies(name):
    """Random feasible strategies carry cycles and many improper links —
    the regime where tagging actually propagates."""
    inst = network.table_ii_instance(name, seed=0, rate_scale=2.0)
    e = jax.random.uniform(
        jax.random.PRNGKey(7), (inst.A, inst.K1, inst.V, inst.V)
    ) * inst.adj[None, None]
    c = jax.random.uniform(jax.random.PRNGKey(8), (inst.A, inst.K1, inst.V))
    phi = traffic.renormalize(inst, traffic.Phi(e=e, c=c))
    m = marginals.marginals(inst, phi)
    route = phi.e > 0.0
    worse = m.pdt[:, :, None, :] > m.pdt[:, :, :, None] + 1e-7
    assert bool((route & worse).any())        # improper links present
    b_bit = gp.blocked_sets(inst, phi, m.pdt, method="bitset")
    b_scan = gp.blocked_sets(inst, phi, m.pdt, method="scan")
    np.testing.assert_array_equal(np.asarray(b_bit), np.asarray(b_scan))


def test_blocked_sets_parity_on_congested_midsolve_iterate():
    """A true mid-solve iterate (congested Abilene, aggressive stepsize)
    where improper links appear transiently."""
    inst = network.table_ii_instance("abilene", seed=0, rate_scale=3.5)
    res = gp.solve(inst, alpha=0.3, max_iters=2, patience=10**6, tol=0.0)
    m = marginals.marginals(inst, res.phi)
    route = res.phi.e > 0.0
    worse = m.pdt[:, :, None, :] > m.pdt[:, :, :, None] + 1e-7
    assert bool((route & worse).any())        # the iterate is congested
    b_bit = gp.blocked_sets(inst, res.phi, m.pdt, method="bitset")
    b_scan = gp.blocked_sets(inst, res.phi, m.pdt, method="scan")
    np.testing.assert_array_equal(np.asarray(b_bit), np.asarray(b_scan))


def test_gp_step_invariant_to_blocked_method(monkeypatch):
    """End-to-end drop-in swap: eager gp_step trajectories are identical
    whether blocked_sets routes through the bitset kernel or the dense
    scan (the eager path sidesteps jit caches, so the monkeypatch is
    guaranteed to take effect)."""
    inst = network.table_ii_instance("abilene", seed=0, rate_scale=3.0)

    def run_steps():
        phi = gp.init_phi(inst)
        costs = []
        for _ in range(3):
            state = gp.gp_step(inst, phi, 0.2)
            phi = state.phi
            costs.append(float(state.cost))
        return phi, costs

    phi_bit, costs_bit = run_steps()
    monkeypatch.setattr(
        ops, "blocked_tagged",
        lambda route, improper, **kw: bset.tagged_scan_dense(route, improper))
    phi_scan, costs_scan = run_steps()
    assert costs_bit == costs_scan
    np.testing.assert_array_equal(np.asarray(phi_bit.e), np.asarray(phi_scan.e))


# ---------------------------------------------------------------------------
# (b) batched baselines == serial baselines
# ---------------------------------------------------------------------------

_KW = dict(alpha=0.1, max_iters=30, tol=-1.0, patience=10**6)


def _fig5_scenarios(names):
    return [sc for sc in scenarios.expand("fig5") if sc.label in names]


@pytest.mark.parametrize("solver", ["SPOC", "LCOF"])
def test_batched_baselines_match_serial_small_table_ii(solver):
    fam = _fig5_scenarios(scenarios.SMALL_TABLE_II)
    assert len(fam) == 6
    masks_fn = baselines.BASELINE_MASKS[solver]
    bat = scenarios.run_sweep(fam, masks_fn=masks_fn, **_KW)
    ser = scenarios.run_sweep_serial(fam, masks_fn=masks_fn, **_KW)
    for sc, b, s in zip(fam, bat.results, ser.results):
        rel = abs(b.final_cost - s.final_cost) / max(abs(s.final_cost), 1e-9)
        assert rel <= 1e-4, (solver, sc.label, b.final_cost, s.final_cost)


@pytest.mark.slow
@pytest.mark.parametrize("solver", ["SPOC", "LCOF"])
def test_batched_baselines_match_serial_small_world(solver):
    """The V=100 pair (sw-linear, sw-queue) — separate cost families, so
    run_sweep puts each in its own padded batch."""
    fam = _fig5_scenarios(("sw-linear", "sw-queue"))
    kw = dict(_KW, max_iters=12)
    masks_fn = baselines.BASELINE_MASKS[solver]
    bat = scenarios.run_sweep(fam, masks_fn=masks_fn, **kw)
    ser = scenarios.run_sweep_serial(fam, masks_fn=masks_fn, **kw)
    assert bat.n_batches == 2
    for sc, b, s in zip(fam, bat.results, ser.results):
        rel = abs(b.final_cost - s.final_cost) / max(abs(s.final_cost), 1e-9)
        assert rel <= 1e-4, (solver, sc.label, b.final_cost, s.final_cost)


def test_spoc_all_true_allowed_c_equals_pre_refactor_none():
    """The mask refactor's one behavioral delta: serial SPOC used to pass
    ``allowed_c=None`` (unrestricted); it now passes the all-True array
    from ``spoc_masks`` so the restriction batches.  The two must produce
    identical solves — this pins the pre-refactor behavior."""
    inst = network.table_ii_instance("abilene", seed=0, rate_scale=2.0)
    ae, ac, phi0 = baselines.spoc_masks(inst)
    assert bool(ac.all())
    with_none = gp.solve(inst, phi0, allowed_e=ae, allowed_c=None, **_KW)
    with_mask = gp.solve(inst, phi0, allowed_e=ae, allowed_c=ac, **_KW)
    assert with_mask.iterations == with_none.iterations
    np.testing.assert_allclose(np.asarray(with_mask.cost_history),
                               np.asarray(with_none.cost_history), rtol=1e-6)
