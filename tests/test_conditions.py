"""Proposition 1 / Theorem 1: the Fig. 4 degenerate example.

Construct the paper's counterexample network where a strategy satisfies the
KKT necessary condition (5) yet is arbitrarily suboptimal, and verify:
  * the bad strategy passes the KKT check but fails the sufficiency check,
  * GP started *from the bad strategy* escapes to the global optimum,
  * the optimum satisfies both conditions.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import conditions, gp, network, traffic

RHO = 0.3  # the path cost; direct-link cost is 1.  D(phi*)/D(phi_bad) = rho.


def fig4_instance() -> network.Instance:
    """Nodes 0-1-2-3 in a line plus a direct link 0->3.

    One application, |T_a| = 1, destination node 3, data input at node 0.
    Computation is free at node 3 and prohibitive elsewhere; all costs
    linear.  The line links cost rho/3 each, the direct link costs 1.
    """
    V = 4
    adj = np.zeros((V, V), bool)
    for u, v in [(0, 1), (1, 2), (2, 3), (0, 3)]:
        adj[u, v] = adj[v, u] = True
    lp = np.zeros((V, V))
    for u, v in [(0, 1), (1, 2), (2, 3)]:
        lp[u, v] = lp[v, u] = RHO / 3
    lp[0, 3] = lp[3, 0] = 1.0
    return network.Instance(
        adj=jnp.asarray(adj),
        link_param=jnp.asarray(lp, dtype=jnp.float32),
        link_kind=network.LINEAR,
        comp_param=jnp.asarray([1e4, 1e4, 1e4, 1e-6], dtype=jnp.float32),
        comp_kind=network.LINEAR,
        L=jnp.asarray([[1.0, 1.0]], dtype=jnp.float32),
        w=jnp.asarray([[1.0, 0.0]], dtype=jnp.float32),
        wnode=jnp.ones(V, dtype=jnp.float32),
        r=jnp.asarray([[1.0, 0.0, 0.0, 0.0]], dtype=jnp.float32),
        dst=jnp.asarray([3]),
        n_tasks=jnp.asarray([1]),
        stage_mask=jnp.ones((1, 2), bool),
    )


def bad_phi(inst) -> traffic.Phi:
    """The Fig. 4 KKT-satisfying strategy: all data on the direct link.

    Nodes 1, 2 carry zero traffic and point 'backwards', which makes (5)
    hold vacuously there while delta would reveal the cheap path.
    """
    e = np.zeros((1, 2, 4, 4), dtype=np.float32)
    c = np.zeros((1, 2, 4), dtype=np.float32)
    # stage 0 (data): node 0 -> direct link; 1 -> 0; 2 -> 1; 3 computes
    e[0, 0, 0, 3] = 1.0
    e[0, 0, 1, 0] = 1.0
    e[0, 0, 2, 1] = 1.0
    c[0, 0, 3] = 1.0
    # stage 1 (results): destination is 3; other nodes point toward 3
    e[0, 1, 0, 3] = 1.0
    e[0, 1, 1, 2] = 1.0
    e[0, 1, 2, 3] = 1.0
    return traffic.Phi(e=jnp.asarray(e), c=jnp.asarray(c))


def test_bad_phi_is_feasible_and_costs_one():
    inst = fig4_instance()
    phi = bad_phi(inst)
    assert float(traffic.feasibility_violation(inst, phi)) < 1e-6
    assert float(traffic.total_cost(inst, phi)) == pytest.approx(1.0, rel=1e-3)


def test_kkt_holds_but_sufficiency_fails():
    inst = fig4_instance()
    phi = bad_phi(inst)
    assert float(conditions.kkt_residual(inst, phi)) <= 1e-4        # (5) holds
    assert float(conditions.sufficiency_residual(inst, phi)) > 0.1  # (6) fails


def test_gp_escapes_degenerate_point_to_global_optimum():
    inst = fig4_instance()
    res = gp.solve(inst, bad_phi(inst), alpha=0.2, max_iters=300)
    # optimum: route 0->1->2->3 (cost rho), compute at 3 (free)
    assert res.final_cost == pytest.approx(RHO, rel=0.02)
    assert float(conditions.sufficiency_residual(inst, res.phi)) < 1e-2
    assert float(conditions.kkt_residual(inst, res.phi)) < 1e-2


def test_ratio_matches_proposition_1():
    """D(phi*) / D(phi_bad) == rho, for arbitrary rho."""
    inst = fig4_instance()
    bad_cost = float(traffic.total_cost(inst, bad_phi(inst)))
    opt = gp.solve(inst, bad_phi(inst), alpha=0.2, max_iters=300)
    assert opt.final_cost / bad_cost == pytest.approx(RHO, rel=0.03)
