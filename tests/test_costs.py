"""Property tests for the cost families (Section II requirements)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, strategies as st

from repro.core import costs


@pytest.mark.parametrize("kind", [costs.LINEAR, costs.QUEUE])
@given(cap=st.floats(0.5, 100.0), f=st.floats(0.0, 200.0))
@settings(max_examples=60, deadline=None)
def test_cost_zero_nonneg_increasing(kind, cap, f):
    cap_a = jnp.float32(cap)
    assert float(costs.cost(kind, jnp.float32(0.0), cap_a)) == 0.0
    c = float(costs.cost(kind, jnp.float32(f), cap_a))
    assert np.isfinite(c) and c >= 0.0
    m = float(costs.marginal(kind, jnp.float32(f), cap_a))
    assert np.isfinite(m) and m > 0.0


@pytest.mark.parametrize("kind", [costs.LINEAR, costs.QUEUE])
@given(cap=st.floats(0.5, 100.0), f1=st.floats(0.0, 150.0), df=st.floats(0.01, 50.0))
@settings(max_examples=60, deadline=None)
def test_cost_convex_monotone(kind, cap, f1, df):
    cap_a = jnp.float32(cap)
    c1 = float(costs.cost(kind, jnp.float32(f1), cap_a))
    c2 = float(costs.cost(kind, jnp.float32(f1 + df), cap_a))
    assert c2 >= c1 - 1e-5 * max(1.0, abs(c1))          # increasing
    m1 = float(costs.marginal(kind, jnp.float32(f1), cap_a))
    m2 = float(costs.marginal(kind, jnp.float32(f1 + df), cap_a))
    assert m2 >= m1 - 1e-4 * max(1.0, m1)               # convex (D' increasing)


@pytest.mark.parametrize("kind", [costs.LINEAR, costs.QUEUE])
@given(cap=st.floats(0.5, 50.0), f=st.floats(0.001, 120.0))
@settings(max_examples=60, deadline=None)
def test_marginal_matches_autodiff(kind, cap, f):
    cap_a = jnp.float32(cap)
    g = float(jax.grad(lambda x: costs.cost(kind, x, cap_a))(jnp.float32(f)))
    m = float(costs.marginal(kind, jnp.float32(f), cap_a))
    assert g == pytest.approx(m, rel=2e-3, abs=1e-5)


def test_queue_matches_mm1_inside_capacity():
    """Below the knee the queue cost is exactly F/(cap-F) (M/M/1)."""
    cap = jnp.float32(10.0)
    for f in [0.0, 1.0, 5.0, 9.0, 9.7]:
        expect = f / (10.0 - f)
        got = float(costs.cost(costs.QUEUE, jnp.float32(f), cap))
        assert got == pytest.approx(expect, rel=1e-5)


def test_queue_extension_is_c1_at_knee():
    cap = jnp.float32(10.0)
    knee = 0.98 * 10.0
    below = float(costs.marginal(costs.QUEUE, jnp.float32(knee - 1e-4), cap))
    above = float(costs.marginal(costs.QUEUE, jnp.float32(knee + 1e-4), cap))
    assert above == pytest.approx(below, rel=1e-2)
