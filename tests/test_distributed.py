"""shard_map GP: sharded solve must match the single-device solve."""

import numpy as np
import pytest

from repro.core import compat, distributed, gp, network


def _mesh1():
    return compat.make_mesh((1,), ("stage",))


def test_sharded_matches_unsharded_on_single_device():
    inst = network.table_ii_instance("abilene", seed=0)
    phi0 = gp.init_phi(inst)
    mesh = _mesh1()
    res_s = distributed.solve_sharded(inst, mesh, alpha=0.05, max_iters=60, phi0=phi0)
    # reference: plain gp_step WITHOUT the stepsize ladder, same alpha
    phi = phi0
    for _ in range(60):
        # emulate fixed-alpha by restricting the ladder to one rung
        state = gp.gp_step(inst, phi, 0.05)
        phi = state.phi
    # both must be descents from the same start; costs should be close
    from repro.core.traffic import total_cost

    c_ref = float(total_cost(inst, phi))
    c_shard = res_s.cost_history[-1]
    assert np.isfinite(c_shard)
    assert c_shard <= res_s.cost_history[0] + 1e-5          # sharded descends
    assert c_shard <= c_ref * 1.10                          # and is competitive


def test_sharded_pads_applications():
    inst = network.table_ii_instance("abilene", seed=0)   # A=3
    padded, A = distributed._pad_apps(inst, 2)
    assert A == 3 and padded.A == 4
    assert float(padded.r[3].sum()) == 0.0
    mesh = _mesh1()
    res = distributed.solve_sharded(inst, mesh, alpha=0.05, max_iters=20)
    assert res.phi.e.shape[0] == 3
