"""Unified step engine under shard_map: the sharded solve must reproduce
the single-device solve near-exactly (one shared step core, DESIGN.md §14).

The multi-shard cases skip on a 1-device host; CI runs this module a second
time under ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` so the
≥2-shard parity acceptance actually executes (ci.yml "Distributed quick
tier").
"""

import inspect

import jax
import numpy as np
import pytest

from repro.core import compat, distributed, gp, network, scenarios

# Fixed-length budget: patience/tol stops are bit-sensitive to fp drift in
# the stall counter, so parity tests pin the iteration count and compare
# whole trajectories instead.
KW = dict(alpha=0.1, max_iters=40, patience=10**6, tol=0.0)

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >=2 devices (XLA_FLAGS=--xla_force_host_platform_device_count=4)",
)


def _mesh(n):
    return compat.make_mesh((n,), ("stage",))


def _rel_dev(a, b):
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    return float(np.max(np.abs(a - b) / np.maximum(np.abs(a), 1e-9)))


def test_sharded_matches_solve_single_shard():
    """1 shard: identical engine, identity collectives — exact trajectories."""
    inst = network.table_ii_instance("abilene", seed=0, rate_scale=2.0)
    phi0 = gp.init_phi(inst)
    ref = gp.solve(inst, phi0, **KW)
    res = distributed.solve_sharded(inst, _mesh(1), phi0=phi0, **KW)
    assert int(res.iterations) == int(ref.iterations) == 40
    assert _rel_dev(ref.cost_history, res.cost_history) <= 1e-6
    np.testing.assert_allclose(np.asarray(res.phi.e), np.asarray(ref.phi.e),
                               atol=1e-6)


@multi_device
def test_sharded_matches_solve_two_shards():
    """The acceptance criterion: >=2 app shards, cost histories <= 1e-4
    (the only cross-shard fp difference is the psum partial-sum order)."""
    inst = network.table_ii_instance("abilene", seed=0, rate_scale=2.0)
    phi0 = gp.init_phi(inst)
    ref = gp.solve(inst, phi0, **KW)
    res = distributed.solve_sharded(inst, _mesh(2), phi0=phi0, **KW)
    assert int(res.iterations) == int(ref.iterations) == 40
    assert _rel_dev(ref.cost_history, res.cost_history) <= 1e-4
    # phi itself may drift along equal-cost (flat) directions as the psum
    # partial-sum order perturbs ladder near-ties; what must match is the
    # cost the strategy induces.
    from repro.core.traffic import total_cost

    c_ref = float(total_cost(inst, ref.phi))
    c_res = float(total_cost(inst, res.phi))
    assert c_res == pytest.approx(c_ref, rel=1e-4)


@multi_device
def test_sharded_solver_dispatch_two_shards():
    """solver=/blocked= dispatch reaches the mesh path: the batched-LU +
    bitset program matches the dense + scan reference program."""
    inst = network.table_ii_instance("abilene", seed=0, rate_scale=2.0)
    phi0 = gp.init_phi(inst)
    kw = dict(alpha=0.1, max_iters=15, patience=10**6, tol=0.0)
    mesh = _mesh(2)
    fused = distributed.solve_sharded(inst, mesh, phi0=phi0,
                                      solver="batched_lu", blocked="bitset",
                                      **kw)
    dense = distributed.solve_sharded(inst, mesh, phi0=phi0,
                                      solver="dense", blocked="scan", **kw)
    assert _rel_dev(dense.cost_history, fused.cost_history) <= 1e-4


def test_sharded_pads_applications():
    """App padding to the shard count keeps dead apps degenerate and the
    solution identical to the unpadded single-device solve."""
    inst = network.table_ii_instance("abilene", seed=0)   # A=3
    padded, A = distributed._pad_apps(inst, 2)
    assert A == 3 and padded.A == 4
    assert float(padded.r[3].sum()) == 0.0
    assert not bool(np.asarray(padded.stage_mask[3]).any())
    # the non-multiple A still solves (1 shard here; 2-shard parity above
    # exercises the padded lanes on a real mesh) and phi is un-padded
    res = distributed.solve_sharded(inst, _mesh(1), alpha=0.05, max_iters=20)
    assert res.phi.e.shape[0] == 3


@multi_device
def test_sharded_pads_applications_two_shards():
    """A=3 padded to 4 across 2 shards: the dead app contributes nothing."""
    inst = network.table_ii_instance("abilene", seed=0, rate_scale=2.0)
    ref = gp.solve(inst, gp.init_phi(inst), **KW)
    res = distributed.solve_sharded(inst, _mesh(2),
                                    phi0=gp.init_phi(inst), **KW)
    assert res.phi.e.shape[0] == inst.A
    assert _rel_dev(ref.cost_history, res.cost_history) <= 1e-4


def test_run_sweep_mesh_matches_plain():
    """Mesh-composed sweep (vmap-of-shard_map) == plain batched sweep."""
    n = min(len(jax.devices()), 2)
    skw = {"scenario": "abilene", "n_seeds": 3, "rate_scale": 2.0}
    plain = scenarios.run_sweep("seed-ensemble", sweep_kwargs=skw, **KW)
    meshed = scenarios.run_sweep("seed-ensemble", sweep_kwargs=skw,
                                 mesh=_mesh(n), **KW)
    assert len(meshed.results) == 3
    for a, b in zip(plain.results, meshed.results):
        assert b.final_cost == pytest.approx(a.final_cost, rel=1e-4)
        assert b.phi.e.shape == a.phi.e.shape


def test_distributed_has_no_inline_step_math():
    """The module is a mesh adapter only: every piece of GP-step math
    (marginals, blocked sets, projection, renormalize, collectives) lives
    in the shared engine.  Checked over the actual code identifiers (names
    and attribute accesses), not docstrings."""
    import ast

    tree = ast.parse(inspect.getsource(distributed))
    idents = {n.id for n in ast.walk(tree) if isinstance(n, ast.Name)}
    idents |= {n.attr for n in ast.walk(tree) if isinstance(n, ast.Attribute)}
    for token in ("pdt_recursion", "renormalize", "blocked_sets", "psum",
                  "pmax", "marginals", "stage_traffic", "gp_step",
                  "delta_e", "delta_c"):
        assert token not in idents, f"inline step math leaked back: {token}"
