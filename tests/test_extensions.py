"""Beyond-paper extensions: quasi-Newton GP scaling, expert-parallel MoE,
blockwise attention equivalence at the model level."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import compat, conditions, gp, network
from repro.models import moe, moe_ep
from repro.models.transformer import Model


def test_scaled_gp_converges_no_slower_under_congestion():
    inst = network.table_ii_instance("abilene", seed=0, rate_scale=2.5)
    plain = gp.solve(inst, alpha=0.1, max_iters=250)
    scaled = gp.solve(inst, alpha=0.1, max_iters=250, scaled=True)
    assert scaled.final_cost <= plain.final_cost * 1.05
    assert np.isfinite(scaled.final_cost)


def test_scaled_gp_reaches_sufficiency():
    inst = network.table_ii_instance("balanced-tree", seed=1)
    res = gp.solve(inst, alpha=0.1, max_iters=400, scaled=True)
    r = float(conditions.sufficiency_residual(inst, res.phi, active_eps=1e-3))
    assert r < 0.05 * max(1.0, res.final_cost)


def test_moe_ep_matches_gspmd_moe_single_device():
    """shard_map expert-parallel MoE == dense-dispatch MoE on a 1x1 mesh."""
    cfg = configs.get("mixtral-8x22b", reduced=True)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    p = moe.init(jax.random.PRNGKey(0), cfg)
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    ref, aux_ref = moe.apply(p, cfg, x)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    out, aux = moe_ep.apply_ep(p, cfg, x, mesh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-4, rtol=2e-4)


def test_blockwise_attention_model_equivalence():
    cfg = configs.get("phi4-mini-3.8b", reduced=True)
    m0, m1 = Model(cfg), Model(cfg, attn_impl="blockwise")
    p = m0.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 96), 0, cfg.vocab)
    l0, _, _ = m0.apply(p, {"tokens": toks})
    l1, _, _ = m1.apply(p, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), atol=2e-4)


def test_expert_axis_constraint_is_noop_without_mesh():
    """expert_axis=None path must be byte-identical; with axis but no mesh
    the constraint is what would fail — we only assert the None path."""
    cfg = configs.get("mixtral-8x22b", reduced=True)
    p = moe.init(jax.random.PRNGKey(0), cfg)
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    a, _ = moe.apply(p, cfg, x, expert_axis=None)
    b, _ = moe.apply(p, cfg, x)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
