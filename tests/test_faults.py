"""Fault-tolerance regression: chaos traces, injection, the §17 ladder.

Pins the adversarial layer (DESIGN.md §17) end to end:

  * ``faults.chaos_trace`` is deterministic in its seed, replays cleanly,
    and never strands a member without a live application;
  * ``faults.FaultInjector`` produces both corruption modes
    deterministically, and the online service *recovers* from each
    (cold-restart path for NaN carries, debug-mode invariant screening for
    de-normalized rows) — served state ends finite and non-corrupt;
  * hostile events degrade, never diverge: isolating a destination sheds
    its chains via ``apply_event`` instead of poisoning the instance, and
    structurally invalid events raise;
  * a member pinned at an impossible iteration budget climbs the full
    escalation ladder down to the SPOC/LCOF baseline-mask floor and still
    serves a feasible finite strategy.
"""

import numpy as np
import pytest

from repro.core import engine, events, faults, gp, network, traffic
from repro.serve import OnlineSolver

ALPHA, TOL = 0.1, 1e-4


def _inst(scale=1.0):
    return network.table_ii_instance("abilene", seed=0, rate_scale=scale)


def _carry(inst):
    phi0 = gp.init_phi(inst)
    return engine.init_carry(inst, phi0, accel=engine.resolve_accel(True))


# -- chaos traces -----------------------------------------------------------


@pytest.mark.slow
def test_chaos_trace_deterministic_and_survivable():
    members = events.pad_fleet([_inst(0.5), _inst(1.0)], spare_apps=1)
    s1 = faults.chaos_trace(members, n_events=40, seed=5)
    s2 = faults.chaos_trace(members, n_events=40, seed=5)
    assert s1 == s2

    flat = [ev for batch in s1 for ev in batch]
    assert 0 < len(flat) <= 40         # invalidated recoveries may drop
    assert any(len(batch) > 1 for batch in s1)   # storms batch events

    # every batch replays cleanly and no member ever loses its last chain
    state = list(members)
    for batch in s1:
        for ev in batch:
            state[ev.member], _ = events.apply_event(state[ev.member], ev)
        for m in state:
            assert bool(np.asarray(m.stage_mask).any())
    # surge recoveries flushed: rates end finite (stable region)
    for m in state:
        assert np.isfinite(np.asarray(m.r)).all()


# -- fault injection --------------------------------------------------------


def test_fault_injector_modes_and_determinism():
    inst = _inst()
    carry = _carry(inst)
    inj = faults.FaultInjector(seed=0, p_inject=1.0)
    seen = {}
    for t in range(8):
        corrupted, mode = inj.maybe_corrupt(carry, member=0, event_index=t)
        assert mode is not None        # p_inject=1 always fires
        seen.setdefault(mode, corrupted)
    assert set(seen) == set(faults.FaultInjector.MODES)

    nanc = seen["nan_carry"]
    assert not np.isfinite(np.asarray(nanc.phi.e)).all()
    assert not np.isfinite(float(nanc.cost))

    den = seen["denorm_phi"]          # finite but simplex-violating
    assert np.isfinite(np.asarray(den.phi.e)).all()
    sv = traffic.strategy_violations(inst, den.phi)
    assert float(sv.simplex) > 1e-3

    inj2 = faults.FaultInjector(seed=0, p_inject=1.0)
    for t in range(8):
        inj2.maybe_corrupt(carry, member=0, event_index=t)
    assert inj2.log == inj.log        # schedule is pure in the seed

    with pytest.raises(ValueError):
        faults.FaultInjector(modes=("rowhammer",))


@pytest.mark.parametrize("mode", faults.FaultInjector.MODES)
def test_online_service_recovers_from_injection(mode):
    inj = faults.FaultInjector(seed=0, p_inject=1.0, modes=(mode,))
    solver = OnlineSolver([_inst(0.5)], alpha=ALPHA, tol=TOL, accel=True,
                          debug=True, fault_injector=inj)
    rep = solver.process(events.RateScale(member=0, factor=1.2, app=0))
    assert rep.injected == mode
    assert np.isfinite(rep.cost)
    health = solver.verify_member(0)
    assert not health.corrupt, health


# -- hostile events: degrade, never diverge ---------------------------------


def test_isolating_a_destination_sheds_its_chains():
    (m,) = events.pad_fleet([_inst()], spare_apps=1)
    d = int(np.asarray(m.dst)[0])
    shed = []
    for v in np.flatnonzero(np.asarray(m.adj)[:, d]):
        adj = np.asarray(m.adj)
        if not (adj[v].any() or adj[:, v].any()):
            continue                   # already taken down
        m, eff = events.apply_event(m, events.NodeDown(member=0, node=int(v)))
        shed += list(eff.shed)
    # app 0's destination lost every in-edge: the chain departed (either
    # shed as unreachable or gone with a failed node it was destined to)
    assert not bool(np.asarray(m.stage_mask)[0].any())
    assert float(np.asarray(m.r)[0].max()) == 0.0
    assert np.isfinite(np.asarray(m.r)).all()
    assert np.isfinite(np.asarray(m.link_param)).all()
    # admission control now rejects arrivals aimed at the dead destination
    spare = int(np.flatnonzero(~np.asarray(m.stage_mask).any(axis=1))[0])
    with pytest.raises(ValueError):
        events.apply_event(m, events.AppArrival(
            member=0, app=spare, dst=d, rates=((1, 0.4),)))


def test_hostile_events_raise_loudly():
    (m,) = events.pad_fleet([_inst()], spare_apps=0)
    live = np.asarray(m.adj)
    i, j = (int(x) for x in np.argwhere(live)[0])
    with pytest.raises(ValueError):    # LinkUp on a live edge
        events.apply_event(m, events.LinkUp(member=0, i=i, j=j, capacity=1.0))
    with pytest.raises(ValueError):    # arrival overflows the envelope
        events.apply_event(m, events.AppArrival(
            member=0, app=m.A, dst=0, rates=((1, 0.1),)))
    with pytest.raises(ValueError):    # no dead slot to arrive into
        events.apply_event(m, events.AppArrival(
            member=0, app=0, dst=0, rates=((1, 0.1),)))
    for bad in (float("nan"), float("inf"), 0.0, -1.0):
        with pytest.raises(ValueError):
            events.apply_event(m, events.RateScale(member=0, factor=bad))
    with pytest.raises(ValueError):    # out-of-range node index
        events.apply_event(m, events.NodeDown(member=0, node=m.V))


# -- the escalation ladder --------------------------------------------------


def test_impossible_budget_falls_back_to_baseline_mask():
    solver = OnlineSolver([_inst()], alpha=ALPHA, tol=1e-12, max_iters=4,
                          accel=True)
    rep = solver.process(events.RateScale(member=0, factor=2.0, app=0))
    # the watchdog climbed past the GP rungs to the baseline-mask floor
    assert any(r.startswith("baseline:") for r in rep.rungs), rep.rungs
    assert any(k.startswith("baseline:") for k in solver.ladder_hits)
    assert "warm" in rep.rungs
    # best-effort service, but never corrupt and never above the incumbent
    assert not rep.converged
    assert np.isfinite(rep.cost)
    if np.isfinite(rep.incumbent_cost):
        assert rep.cost <= rep.incumbent_cost * (1 + 2e-4)
    assert not solver.verify_member(0).corrupt
