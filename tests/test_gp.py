"""Algorithm 1 behaviour: descent, convergence, loop-freedom, adaptivity,
and dominance over the Section V baselines."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, strategies as st

from repro.core import baselines, conditions, gp, network, traffic
from tests.helpers import random_loopfree_phi, small_instances


def test_descent_is_monotone():
    inst = network.table_ii_instance("abilene", seed=0, rate_scale=2.0)
    res = gp.solve(inst, alpha=0.1, max_iters=150)
    hist = np.asarray(res.cost_history)
    assert np.all(np.diff(hist) <= 1e-4 * np.maximum(hist[:-1], 1.0))


@pytest.mark.parametrize("scenario", ["abilene", "balanced-tree"])
def test_converges_to_sufficiency(scenario):
    inst = network.table_ii_instance(scenario, seed=0)
    res = gp.solve(inst, alpha=0.1, max_iters=500)
    assert float(conditions.sufficiency_residual(inst, res.phi, active_eps=1e-3)) < 5e-2


@pytest.mark.parametrize("scenario", ["abilene", "balanced-tree", "fog"])
@pytest.mark.parametrize("scale", [1.0, 2.0])
def test_gp_beats_baselines(scenario, scale):
    inst = network.table_ii_instance(scenario, seed=0, rate_scale=scale)
    res = gp.solve(inst, alpha=0.1, max_iters=400)
    for name, fn in baselines.ALL_BASELINES.items():
        if name == "LPR-SC":
            b = fn(inst)
        else:
            b = fn(inst, alpha=0.1, max_iters=250)
        assert res.final_cost <= b.final_cost * 1.02, (name, res.final_cost, b.final_cost)


@given(seed=st.integers(0, 500))
@settings(max_examples=8, deadline=None)
def test_iterates_stay_loopfree_and_feasible(seed):
    """The blocked-set mechanism preserves loop-freedom from any loop-free
    start (the paper's key invariant)."""
    inst = small_instances()[0]
    phi = random_loopfree_phi(inst, seed)
    for _ in range(15):
        state = gp.gp_step(inst, phi, 0.2)
        phi = state.phi
        fl = traffic.flows(inst, phi)
        assert bool(traffic.traffic_is_valid(inst, fl.t))
        assert float(traffic.feasibility_violation(inst, phi)) < 1e-4


def test_adapts_to_input_rate_change():
    """Online adaptivity: after r_i(a) changes, continuing from the current
    phi re-converges (no restart needed)."""
    inst = network.table_ii_instance("abilene", seed=0)
    res1 = gp.solve(inst, alpha=0.1, max_iters=300)
    inst2 = dataclasses.replace(inst, r=inst.r * 2.5)
    res2 = gp.solve(inst2, phi0=res1.phi, alpha=0.1, max_iters=300)
    fresh = gp.solve(inst2, alpha=0.1, max_iters=300)
    assert res2.final_cost <= fresh.final_cost * 1.05
    # residual threshold is scale-aware: marginals grow with congestion
    res = float(conditions.sufficiency_residual(inst2, res2.phi, active_eps=1e-3))
    assert res < 0.05 * max(1.0, res2.final_cost)


def test_adapts_to_link_removal():
    """Topology change: removing a link, the strategy re-normalizes and GP
    re-converges on the reduced graph."""
    inst = network.table_ii_instance("abilene", seed=0)
    res1 = gp.solve(inst, alpha=0.1, max_iters=300)
    adj = np.asarray(inst.adj).copy()
    links = np.argwhere(adj)
    i, j = links[0]
    adj[i, j] = False
    lp = np.asarray(inst.link_param).copy()
    lp[i, j] = 0.0
    inst2 = dataclasses.replace(
        inst, adj=jnp.asarray(adj), link_param=jnp.asarray(lp)
    )
    phi0 = traffic.renormalize(inst2, res1.phi)
    # the removed link's mass may leave a row empty; re-seed those rows
    tot = phi0.e.sum(-1) + phi0.c
    empty = (tot < 0.5) & ~inst2.degenerate_mask()
    if bool(empty.any()):
        sp = gp.init_phi(inst2)
        phi0 = traffic.Phi(
            e=jnp.where(empty[..., None], sp.e, phi0.e),
            c=jnp.where(empty, sp.c, phi0.c),
        )
    res2 = gp.solve(inst2, phi0=phi0, alpha=0.1, max_iters=300)
    assert np.isfinite(res2.final_cost)
    assert float(conditions.sufficiency_residual(inst2, res2.phi, active_eps=1e-3)) < 0.1


def test_multi_source_applications():
    """The paper allows multiple data sources per application (footnote 1)."""
    inst = network.table_ii_instance("geant", seed=2)
    assert int((np.asarray(inst.r) > 0).sum(axis=1).max()) >= 2
    res = gp.solve(inst, alpha=0.1, max_iters=200)
    assert np.isfinite(res.final_cost)
