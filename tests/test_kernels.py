"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode).

Per the deliverable: every kernel is swept over shapes and dtypes and
asserted allclose against its ref.py oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops, ref

# Kernel shape/dtype sweeps dominate suite wall clock; CI runs them in the
# slow tier (see README "Test tiers").
pytestmark = pytest.mark.slow


def _mk_qkv(key, B, S, H, KV, hd, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd)).astype(dtype)
    return q, k, v


def _ref_bshd(q, k, v, **kw):
    t = lambda x: x.transpose(0, 2, 1, 3)
    return t(ref.flash_attention(t(q), t(k), t(v), **kw))


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 128, 1, 1, 64),      # minimal
    (2, 256, 4, 2, 64),      # GQA rep=2
    (1, 384, 8, 1, 128),     # MQA, unaligned S (384=3x128)
    (1, 130, 4, 4, 64),      # padding path (S not multiple of block)
])
def test_flash_attention_shapes_dtypes(B, S, H, KV, hd, dtype, tol):
    q, k, v = _mk_qkv(jax.random.PRNGKey(0), B, S, H, KV, hd, dtype)
    out = ops.flash_attention(q, k, v, causal=True)
    want = _ref_bshd(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [32, 128, None])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_masks(window, causal):
    q, k, v = _mk_qkv(jax.random.PRNGKey(1), 2, 256, 4, 2, 64, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, window=window)
    want = _ref_bshd(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@given(S=st.integers(2, 12), V=st.integers(3, 150))
@settings(max_examples=12, deadline=None)
def test_chain_propagate_sweep(S, V):
    key = jax.random.PRNGKey(S * 1000 + V)
    ks = jax.random.split(key, 3)
    M = jax.random.uniform(ks[0], (S, V, V)) * 0.2
    src = jax.random.uniform(ks[1], (S, V))
    t = jax.random.uniform(ks[2], (S, V))
    np.testing.assert_allclose(
        np.asarray(ops.propagate_step(t, M, src)),
        np.asarray(ref.propagate_step(t, M, src)), atol=1e-5, rtol=1e-5)


def test_chain_fixed_point_matches_traffic_solver():
    """The kernel's Neumann fixed point equals the dense linear solve used
    by core.traffic — i.e. the kernel really is the paper's hot loop."""
    from repro.core import network, gp, traffic
    inst = network.table_ii_instance("abilene", seed=0)
    phi = gp.init_phi(inst)
    fl = traffic.flows(inst, phi)
    A, K1, V = inst.A, inst.K1, inst.V
    # stage 0 of each app: t = Phi^T t + r  ->  row-vector form t = t M + r
    M = phi.e[:, 0]                                  # (A, V, V); M[i,j]=phi_ij
    src = inst.r                                     # (A, V)
    t_kernel = ops.solve_fixed_point(M, src, sweeps=V)
    np.testing.assert_allclose(np.asarray(t_kernel), np.asarray(fl.t[:, 0]),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4), (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("Q,H,P,N", [(128, 2, 32, 16), (64, 1, 64, 32), (128, 4, 64, 128)])
def test_ssd_chunk_shapes_dtypes(Q, H, P, N, dtype, tol):
    Bz, nc = 1, 2
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    xh = jax.random.normal(ks[0], (Bz, nc, Q, H, P)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bz, nc, Q, H)))
    A = -jnp.exp(0.2 * jax.random.normal(ks[2], (H,)))
    cum = jnp.cumsum(dt * A[None, None, None], axis=2)
    BH = (0.3 * jax.random.normal(ks[3], (Bz, nc, Q, H, N))).astype(dtype)
    CH = (0.3 * jax.random.normal(jax.random.PRNGKey(9), (Bz, nc, Q, H, N))).astype(dtype)
    y, stt = ops.ssd_chunk(xh, dt, None, cum, BH, CH)
    yr, str_ = ref.ssd_chunk(xh, dt, cum, BH, CH)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(stt), np.asarray(str_), atol=tol, rtol=tol)


def test_ssm_model_path_with_kernel_matches_jnp():
    """models.ssm.ssd_chunked(use_kernel=True) == use_kernel=False."""
    from repro.models import ssm
    B, S, H, P, G, N = 1, 256, 4, 32, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    xh = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(0.2 * jax.random.normal(ks[2], (H,)))
    Bc = 0.3 * jax.random.normal(ks[3], (B, S, G, N))
    Cc = 0.3 * jax.random.normal(ks[4], (B, S, G, N))
    y0, h0 = ssm.ssd_chunked(xh, dt, A, Bc, Cc, use_kernel=False)
    y1, h1 = ssm.ssd_chunked(xh, dt, A, Bc, Cc, use_kernel=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(h0), np.asarray(h1), atol=2e-4, rtol=2e-4)


def test_attention_model_path_with_kernel_matches_jnp():
    """models.attention.sdpa(use_kernel=True) == pure jnp path."""
    from repro.models import attention
    B, S, H, KV, hd = 1, 256, 4, 2, 64
    q, k, v = _mk_qkv(jax.random.PRNGKey(2), B, S, H, KV, hd, jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    out0 = attention.sdpa(q, k, v, q_pos=pos, kv_pos=pos, causal=True)
    out1 = attention.sdpa(q, k, v, q_pos=pos, kv_pos=pos, causal=True,
                          use_kernel=True)
    np.testing.assert_allclose(np.asarray(out0), np.asarray(out1), atol=2e-5)
