"""Launch-layer tests: mesh construction, specs, and a subprocess dry-run
on a small fake-device mesh (the 512-device override must never leak into
this test process — see conftest)."""

import json
import os
import subprocess
import sys
import tempfile

import pytest

from repro import configs
from repro.launch.specs import INPUT_SHAPES, input_specs

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_input_shapes_match_assignment():
    assert INPUT_SHAPES["train_4k"] == (4096, 256, "train")
    assert INPUT_SHAPES["prefill_32k"] == (32768, 32, "prefill")
    assert INPUT_SHAPES["decode_32k"] == (32768, 128, "decode")
    assert INPUT_SHAPES["long_500k"] == (524288, 1, "decode")


def test_input_specs_modalities():
    lm = input_specs(configs.get("tinyllama-1.1b"), 4, 128, mode="train")
    assert set(lm) == {"tokens", "targets"} and lm["tokens"].shape == (4, 128)
    au = input_specs(configs.get("hubert-xlarge"), 4, 128, mode="train")
    assert set(au) == {"embeds", "mask", "targets"}
    assert au["embeds"].shape == (4, 128, 1280)
    vl = input_specs(configs.get("llava-next-34b"), 2, 4096, mode="train")
    assert set(vl) == {"patches", "tokens", "targets"}
    assert vl["patches"].shape[1] + vl["tokens"].shape[1] == 4096
    de = input_specs(configs.get("tinyllama-1.1b"), 8, 32768, mode="decode")
    assert de["tokens"].shape == (8, 1)


def test_decode_specs_reject_encoder_only():
    with pytest.raises(AssertionError):
        input_specs(configs.get("hubert-xlarge"), 4, 128, mode="decode")


def test_dryrun_plan_skips():
    # import without triggering jax device lock problems: dryrun sets
    # XLA_FLAGS at import, which is fine inside this process only if jax
    # is already initialized; run the plan logic via subprocess instead.
    code = (
        "import os; os.environ['REPRO_DRYRUN_DEVICES']='1';"
        "from repro.launch.dryrun import plan;"
        "print('A:', plan('hubert-xlarge','decode_32k')[2]);"
        "print('B:', plan('phi4-mini-3.8b','long_500k')[2]);"
        "print('C:', repr(plan('mamba2-780m','long_500k')[2]))"
    )
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        check=True).stdout
    assert "A: encoder-only" in out
    assert "SWA serving variant" in out
    assert "C: ''" in out


@pytest.mark.slow
def test_subprocess_mini_dryrun():
    """The dry-run lowers+compiles on an 8-fake-device mesh in a subprocess
    (arch x all shapes), writing valid JSON records."""
    with tempfile.TemporaryDirectory() as td:
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", "tinyllama-1.1b", "--shape", "all",
             "--mesh", "mini", "--out", td],
            capture_output=True, text=True, timeout=900,
            env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src"),
                 "REPRO_DRYRUN_DEVICES": "8"},
        )
        assert proc.returncode == 0, proc.stdout + proc.stderr
        recs = [json.load(open(os.path.join(td, f))) for f in os.listdir(td)]
        assert len(recs) == 4
        for r in recs:
            assert "error" not in r, r
            if "skipped" in r:
                continue
            assert r["cost_extrapolated"]["flops"] > 0
            assert r["memory"]["argument_bytes"] > 0


def test_mesh_factories_are_lazy():
    """Importing launch.mesh must not initialize jax devices."""
    code = (
        "import sys; import repro.launch.mesh as m;"
        "assert 'jax' in sys.modules;"
        "import jax; assert not jax._src.api._backend_lock.locked() "
        "if hasattr(jax._src.api,'_backend_lock') else True;"
        "print('ok')"
    )
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        check=True).stdout
    assert "ok" in out
