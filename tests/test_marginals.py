"""Validate closed-form marginals (eqs. 3-4) against autodiff and FD.

This is the central theory check: the paper's distributed marginal-cost
broadcast must compute exactly dD/dphi — otherwise nothing downstream
(conditions, GP, Theorem 1) holds.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, strategies as st

import repro.core.marginals as M
from repro.core import network, traffic
from tests.helpers import random_loopfree_phi, small_instances


def _autodiff_grads(inst, phi):
    fn = lambda e, c: traffic.total_cost(inst, traffic.Phi(e, c))
    return jax.grad(fn, argnums=(0, 1))(phi.e, phi.c)


@pytest.mark.parametrize("inst", small_instances(seeds=(0, 1)),
                         ids=["abilene0", "tree0", "abilene1", "tree1"])
@given(seed=st.integers(0, 10_000))
@settings(max_examples=10, deadline=None, derandomize=True)
def test_closed_form_equals_autodiff(inst, seed):
    phi = random_loopfree_phi(inst, seed)
    ge, gc = M.dD_dphi(inst, phi)
    age, agc = _autodiff_grads(inst, phi)
    # relative tolerance: float32 noise amplifies near queue knees
    # (D'' ~ 1/(cap-F)^3, so a 1-ulp flow difference moves the marginal by
    # orders of magnitude more); the closed form is exact in exact
    # arithmetic (verified against finite differences below).  Scale by the
    # LARGEST marginal so saturated instances don't fail on f32 noise.
    scale = max(1.0, float(jnp.max(jnp.abs(age))),
                float(jnp.max(jnp.abs(agc))))
    err_e = float(jnp.max(jnp.abs(jnp.where(inst.adj[None, None], ge - age, 0.0))))
    err_c = float(jnp.max(jnp.abs(jnp.where(inst.cpu_allowed()[:, :, None], gc - agc, 0.0))))
    assert err_e / scale < 5e-3
    assert err_c / scale < 5e-3


def test_closed_form_matches_finite_difference():
    inst = small_instances()[0]
    phi = random_loopfree_phi(inst, 42)
    ge, _ = M.dD_dphi(inst, phi)
    rng = np.random.default_rng(0)
    adj = np.asarray(inst.adj)
    links = np.argwhere(adj)
    cost0 = float(traffic.total_cost(inst, phi))
    for _ in range(5):
        i, j = links[rng.integers(len(links))]
        a = rng.integers(inst.A)
        k = rng.integers(inst.K1)
        eps = 1e-3
        e2 = phi.e.at[a, k, i, j].add(eps)
        cost1 = float(traffic.total_cost(inst, traffic.Phi(e2, phi.c)))
        fd = (cost1 - cost0) / eps
        assert fd == pytest.approx(float(ge[a, k, i, j]), rel=0.05, abs=5e-3)


def test_pdt_zero_at_destination_final_stage():
    """dD/dt_{d_a}(a, K_a) == 0 — final results exit for free."""
    for inst in small_instances():
        phi = random_loopfree_phi(inst, 5)
        m = M.marginals(inst, phi)
        for a in range(inst.A):
            d = int(inst.dst[a])
            k = int(inst.n_tasks[a])
            assert float(m.pdt[a, k, d]) == pytest.approx(0.0, abs=1e-6)


def test_pdt_decreases_downstream_at_optimum():
    """At a (6)-satisfying point, pdt decreases along any flow path."""
    from repro.core import gp

    inst = network.table_ii_instance("abilene", seed=1)
    res = gp.solve(inst, alpha=0.1, max_iters=300)
    m = M.marginals(inst, res.phi)
    pdt = np.asarray(m.pdt)
    e = np.asarray(res.phi.e)
    viol = 0
    for a in range(inst.A):
        for k in range(inst.K1):
            carried = np.argwhere(e[a, k] > 1e-3)
            for i, j in carried:
                if pdt[a, k, j] > pdt[a, k, i] + 1e-2:
                    viol += 1
    assert viol == 0
