"""Model behaviour tests: decode==teacher-forcing, MLA absorption, SSD
chunking vs naive recurrence, MoE routing properties, serving engine."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import mla, moe, ssm
from repro.models.transformer import make_model

# Per-architecture behaviour sweeps compile hundreds of programs; CI runs
# them in the slow tier (see README "Test tiers").
pytestmark = pytest.mark.slow


def _ample_capacity(cfg):
    if cfg.moe is None:
        return cfg
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))


DECODE_ARCHS = [
    "tinyllama-1.1b", "gemma2-9b", "mixtral-8x22b", "deepseek-v3-671b",
    "mamba2-780m", "jamba-v0.1-52b", "phi4-mini-3.8b",
]


@pytest.mark.parametrize("name", DECODE_ARCHS)
def test_decode_matches_teacher_forcing(name):
    """KV-cache decode produces the same logits as a full forward pass.

    MoE capacity is made ample so token-drop nondeterminism across batch
    shapes does not enter (dropping is tested separately)."""
    cfg = _ample_capacity(configs.get(name, reduced=True))
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full, _, _ = model.apply(params, {"tokens": toks})
    cache = model.init_cache(B, max_len=S + 4, dtype=jnp.float32)
    pre, cache, _ = model.apply(params, {"tokens": toks}, cache=cache,
                                cache_index=jnp.int32(0))
    np.testing.assert_allclose(np.asarray(pre), np.asarray(full), atol=2e-4)
    nxt = jnp.argmax(full[:, -1:], -1).astype(jnp.int32)
    dec, cache, _ = model.apply(params, {"tokens": nxt}, cache=cache,
                                cache_index=jnp.int32(S))
    ref, _, _ = model.apply(params, {"tokens": jnp.concatenate([toks, nxt], 1)})
    np.testing.assert_allclose(np.asarray(dec[:, -1]), np.asarray(ref[:, -1]), atol=2e-3)


def test_mla_absorbed_decode_equals_train_path():
    cfg = configs.get("deepseek-v3-671b", reduced=True)
    p = mla.init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    full, _ = mla.apply(p, cfg, x, positions=pos)
    cache = mla.init_cache(cfg, B, S, dtype=jnp.float32)
    absorbed, _ = mla.apply(p, cfg, x, positions=pos, cache=cache,
                            cache_index=jnp.int32(0))
    np.testing.assert_allclose(np.asarray(absorbed), np.asarray(full), atol=1e-4)


def test_mla_cache_is_compressed():
    """MLA's cache must be rank-(c_kv+rope) per token, not per-head KV."""
    cfg = configs.get("deepseek-v3-671b")
    cache = mla.init_cache(cfg, batch=1, max_len=8)
    per_tok = sum(np.prod(c.shape[2:]) for c in cache)
    full_kv = 2 * cfg.n_heads * cfg.hd
    assert per_tok == cfg.mla.kv_lora_rank + cfg.mla.rope_head_dim   # 576
    assert per_tok < full_kv / 50                                    # ~57x smaller


def test_ssd_chunked_equals_naive_recurrence():
    """The chunked SSD matmul form equals the token-by-token recurrence."""
    B, S, H, P, G, N = 2, 64, 4, 8, 2, 16
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    xh = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bc = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
    Cc = jax.random.normal(ks[4], (B, S, G, N)) * 0.3
    y_chunk, h_chunk = ssm.ssd_chunked(xh, dt, A, Bc, Cc)

    rep = H // G
    BH = jnp.repeat(Bc, rep, axis=2)
    CH = jnp.repeat(Cc, rep, axis=2)
    h = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        decay = jnp.exp(dt[:, t] * A[None, :])
        h = h * decay[..., None, None] + jnp.einsum(
            "bh,bhn,bhp->bhpn", dt[:, t], BH[:, t], xh[:, t])
        ys.append(jnp.einsum("bhn,bhpn->bhp", CH[:, t], h))
    y_ref = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_ref),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h),
                               rtol=2e-3, atol=2e-3)


def test_ssm_decode_continues_prefill_state():
    cfg = configs.get("mamba2-780m", reduced=True)
    p = ssm.init(jax.random.PRNGKey(0), cfg)
    B, S = 1, 32
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (B, S + 1, cfg.d_model))
    full, _ = ssm.apply(p, cfg, x)
    cache = ssm.init_cache(cfg, B, dtype=jnp.float32)
    pre, cache = ssm.apply(p, cfg, x[:, :S], cache=cache)
    dec, _ = ssm.apply(p, cfg, x[:, S:], cache=cache)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, S:]),
                               rtol=1e-3, atol=1e-3)


def test_moe_routing_properties():
    cfg = configs.get("mixtral-8x22b", reduced=True)
    p = moe.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
    gw, ids, aux, probs = moe.route(p.router, x, cfg.moe.top_k)
    assert gw.shape == (64, cfg.moe.top_k)
    np.testing.assert_allclose(np.asarray(gw.sum(-1)), 1.0, atol=1e-5)
    assert int(ids.max()) < cfg.moe.n_experts
    # top-1 id has the max prob
    np.testing.assert_array_equal(np.asarray(ids[:, 0]), np.asarray(probs.argmax(-1)))
    assert float(aux) > 0


def test_moe_capacity_dropping_bounded():
    """With capacity_factor=1.0 and adversarially skewed routing, output
    stays finite and the un-dropped fraction dominates."""
    cfg = configs.get("mixtral-8x22b", reduced=True)
    p = moe.init(jax.random.PRNGKey(0), cfg)
    x = jnp.broadcast_to(jax.random.normal(jax.random.PRNGKey(2), (1, 1, cfg.d_model)),
                         (2, 32, cfg.d_model))    # identical tokens -> same expert
    out, aux = moe.apply(p, cfg, x)
    assert bool(jnp.isfinite(out).all())


def test_sliding_window_masks_long_range():
    """With window W, a token W+1 away must not influence attention."""
    from repro.models import attention
    cfg = dataclasses.replace(configs.get("mixtral-8x22b", reduced=True), window=8)
    p = attention.init(jax.random.PRNGKey(0), cfg)
    B, S = 1, 32
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    out1, _ = attention.apply(p, cfg, x, positions=pos, window=8)
    x2 = x.at[:, 0].add(100.0)                    # perturb far-away token
    out2, _ = attention.apply(p, cfg, x2, positions=pos, window=8)
    np.testing.assert_allclose(np.asarray(out1[:, 16:]), np.asarray(out2[:, 16:]),
                               atol=1e-4)
    assert float(jnp.abs(out1[:, 0] - out2[:, 0]).max()) > 1e-3


def test_serving_engine_end_to_end():
    from repro.serve.engine import ServeEngine
    cfg = _ample_capacity(configs.get("tinyllama-1.1b", reduced=True))
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, slots=2, max_len=64)
    uids = [eng.submit(np.array([1, 2, 3]), max_new=4) for _ in range(3)]
    done = eng.run()
    assert set(done) == set(uids)
    for out in done.values():
        assert len(out) == 4 and all(0 <= t < cfg.vocab for t in out)
