"""Observability layer (DESIGN.md §19): device telemetry ring, metrics,
spans, the online drain, the report generator, and the bench-record guard.

The §19 acceptance criteria asserted here:

  * telemetry OFF is bit-identical — same committed trajectories on the
    Table II scenarios, single-device, batched and (when 4 host devices
    are forced) sharded;
  * telemetry ON is trajectory-identical WITHIN each path and the ring
    records exactly the committed per-iteration values (cost column ==
    cost_history), truncating — not wrapping — past capacity;
  * the online service drains per-event segments whose iteration counts
    reproduce the ``HealthReport.iterations`` it serves;
  * telemetry-on overhead <= 5% s_per_iter on the sw-queue scenario
    (skipped on a contended box — same loadavg guard ``bench_record``
    uses).
"""

import json
import os
import time

import jax
import numpy as np
import pytest

from benchmarks import common
from repro import obs
from repro.core import distributed, engine, events, gp, network
from repro.obs import device as obs_device
from repro.obs import report as obs_report
from repro.serve.online import OnlineSolver

# Fixed-length budget (same rationale as tests/test_distributed.py): pin
# the iteration count so parity compares whole trajectories bit-for-bit.
KW = dict(alpha=0.1, max_iters=30, patience=10**6, tol=0.0)

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >=2 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=4)",
)


def _inst(seed=0, scale=2.0):
    return network.table_ii_instance("abilene", seed=seed, rate_scale=scale)


# ---------------------------------------------------------------------------
# device layer
# ---------------------------------------------------------------------------

def test_resolve_telemetry():
    assert engine.resolve_telemetry(None) is None
    assert engine.resolve_telemetry(False) is None
    assert engine.resolve_telemetry(True) == obs.DEFAULT_TELEMETRY
    assert engine.resolve_telemetry("default") == obs.DEFAULT_TELEMETRY
    cfg = obs.TelemetryConfig(ring=8, bs_rounds=False)
    assert engine.resolve_telemetry(cfg) is cfg
    with pytest.raises(TypeError):
        engine.resolve_telemetry(7)


def test_empty_ring_shapes():
    assert obs_device.empty_ring(None).shape == (0, obs.TEL_WIDTH)
    assert obs_device.empty_ring(obs.TelemetryConfig(ring=5)).shape == (
        5, obs.TEL_WIDTH)


def test_ring_record_truncates_not_wraps():
    tb = obs_device.empty_ring(obs.TelemetryConfig(ring=3))
    for i in range(5):
        row = jax.numpy.full((obs.TEL_WIDTH,), float(i + 1))
        tb = obs_device.ring_record(tb, jax.numpy.int32(i), row,
                                    jax.numpy.bool_(True))
    got = np.asarray(tb)[:, 0]
    np.testing.assert_array_equal(got, [1.0, 2.0, 3.0])   # 4, 5 dropped
    assert obs_device.ring_overflow(tb, 5) == 2
    assert obs_device.ring_valid(tb, 5).shape == (3, obs.TEL_WIDTH)
    assert obs_device.ring_valid(tb, 2).shape == (2, obs.TEL_WIDTH)


def test_ring_record_respects_write_mask():
    tb = obs_device.empty_ring(obs.TelemetryConfig(ring=3))
    row = jax.numpy.full((obs.TEL_WIDTH,), 9.0)
    tb = obs_device.ring_record(tb, jax.numpy.int32(0), row,
                                jax.numpy.bool_(False))
    assert float(np.asarray(tb).sum()) == 0.0


def test_records_to_dicts_columns():
    rows = np.arange(2 * obs.TEL_WIDTH, dtype=np.float32).reshape(2, -1)
    recs = obs.records_to_dicts(rows)
    assert [r["iter"] for r in recs] == [0, 8]
    assert set(recs[0]) == set(obs_device.COLUMNS)
    assert isinstance(recs[0]["rung"], int)
    assert isinstance(recs[0]["cost"], float)


# ---------------------------------------------------------------------------
# solver parity: telemetry off/on bit-identical trajectories
# ---------------------------------------------------------------------------

def test_single_device_parity_and_ring_content():
    inst = _inst()
    phi0 = gp.init_phi(inst)
    off = gp.solve(inst, phi0, **KW)
    on = gp.solve(inst, phi0, telemetry=True, **KW)

    assert off.telemetry is None
    assert int(on.iterations) == int(off.iterations) == KW["max_iters"]
    np.testing.assert_array_equal(np.asarray(on.phi.e), np.asarray(off.phi.e))
    np.testing.assert_array_equal(np.asarray(on.phi.c), np.asarray(off.phi.c))
    np.testing.assert_array_equal(np.asarray(on.cost_history),
                                  np.asarray(off.cost_history))

    rows = obs.ring_valid(on.telemetry, on.iterations)
    assert rows.shape == (KW["max_iters"], obs.TEL_WIDTH)
    # iter column is the committed-iteration index, in order
    np.testing.assert_array_equal(rows[:, obs_device.COL_ITER],
                                  np.arange(KW["max_iters"]))
    # cost column IS the committed cost trajectory (cost_history[0] is the
    # initial cost; record i holds the cost after iteration i)
    np.testing.assert_array_equal(
        rows[:, obs_device.COL_COST],
        np.asarray(on.cost_history)[1:KW["max_iters"] + 1])
    assert obs.ring_overflow(on.telemetry, on.iterations) == 0
    # blocked-set sweep rounds plumb out as small positive counts
    assert (rows[:, obs_device.COL_BS_ROUNDS] >= 1).all()


def test_ring_overflow_truncates_on_real_solve():
    inst = _inst()
    phi0 = gp.init_phi(inst)
    cfg = obs.TelemetryConfig(ring=8)
    res = gp.solve(inst, phi0, telemetry=cfg, **KW)
    ref = gp.solve(inst, phi0, **KW)
    # truncation must not perturb the trajectory either
    np.testing.assert_array_equal(np.asarray(res.cost_history),
                                  np.asarray(ref.cost_history))
    rows = obs.ring_valid(res.telemetry, res.iterations)
    assert rows.shape == (8, obs.TEL_WIDTH)
    np.testing.assert_array_equal(rows[:, obs_device.COL_ITER], np.arange(8))
    assert obs.ring_overflow(res.telemetry, res.iterations) == (
        KW["max_iters"] - 8)


def test_batched_parity_and_per_member_rings():
    from repro.core import batch

    insts = [_inst(seed=s, scale=1.0 + 0.5 * s) for s in range(3)]
    binst = batch.pad_instances(insts)
    off = gp.solve_batched(binst, alpha=0.1, max_iters=25, tol=1e-4)
    on = gp.solve_batched(binst, alpha=0.1, max_iters=25, tol=1e-4,
                          telemetry=True)
    np.testing.assert_array_equal(np.asarray(on.iterations),
                                  np.asarray(off.iterations))
    np.testing.assert_array_equal(np.asarray(on.phi.e), np.asarray(off.phi.e))
    np.testing.assert_array_equal(np.asarray(on.cost_history),
                                  np.asarray(off.cost_history))
    assert off.telemetry is None
    tel = np.asarray(on.telemetry)
    assert tel.shape == (3, obs.DEFAULT_TELEMETRY.ring, obs.TEL_WIDTH)
    for b in range(3):
        n = int(np.asarray(on.iterations)[b])
        rows = obs.ring_valid(tel[b], n)
        np.testing.assert_array_equal(rows[:, obs_device.COL_ITER],
                                      np.arange(min(n, tel.shape[1])))


@multi_device
def test_sharded_parity():
    from repro.core import compat

    inst = _inst()
    phi0 = gp.init_phi(inst)
    mesh = compat.make_mesh((2,), ("stage",))
    off = distributed.solve_sharded(inst, mesh, phi0=phi0, **KW)
    on = distributed.solve_sharded(inst, mesh, phi0=phi0, telemetry=True,
                                   **KW)
    assert int(on.iterations) == int(off.iterations)
    np.testing.assert_array_equal(np.asarray(on.phi.e), np.asarray(off.phi.e))
    np.testing.assert_array_equal(np.asarray(on.cost_history),
                                  np.asarray(off.cost_history))
    rows = obs.ring_valid(on.telemetry, on.iterations)
    assert rows.shape[0] == int(on.iterations)
    np.testing.assert_array_equal(rows[:, obs_device.COL_ITER],
                                  np.arange(rows.shape[0]))
    # mesh cost column matches the mesh's own committed history
    np.testing.assert_array_equal(
        rows[:, obs_device.COL_COST],
        np.asarray(on.cost_history)[1:rows.shape[0] + 1])


# ---------------------------------------------------------------------------
# spans + metrics
# ---------------------------------------------------------------------------

def _fake_clock(times):
    it = iter(times)
    last = [0.0]

    def clock():
        try:
            last[0] = next(it)
        except StopIteration:
            pass
        return last[0]
    return clock


def test_span_nesting_and_chrome_roundtrip(tmp_path):
    tr = obs.Tracer(clock=_fake_clock([0.0, 1.0, 2.0, 3.0, 4.0]))
    with tr.span("event", tid=1, member=1):
        with tr.span("converge", tid=1):
            pass
    tr.instant("rollback", tid=1)
    tr.counter("online.iters", 42.0)
    depths = {e["name"]: e["depth"] for e in tr.events if e["ph"] == "X"}
    assert depths == {"event": 0, "converge": 1}

    path = str(tmp_path / "trace.json")
    tr.export_chrome(path, tid_names={1: "member-1"})
    evs = obs.load_chrome(path)
    phs = sorted(e["ph"] for e in evs)
    assert phs == ["C", "M", "M", "X", "X", "i"]
    x = [e for e in evs if e["ph"] == "X"]
    # child closes before parent but both carry ts/dur, child inside parent
    ev = next(e for e in x if e["name"] == "event")
    cv = next(e for e in x if e["name"] == "converge")
    assert ev["ts"] <= cv["ts"]
    assert cv["ts"] + cv["dur"] <= ev["ts"] + ev["dur"] + 1e-6
    assert all("depth" not in e for e in evs)      # internal field stripped
    # valid strict JSON end to end
    with open(path) as f:
        assert json.load(f)["traceEvents"]


def test_metrics_registry(tmp_path):
    m = obs.Metrics()
    m.counter("a.b")
    m.counter("a.b", 2)
    m.gauge("g", 7.5)
    for v in range(10):
        m.observe("h", float(v))
    snap = m.snapshot()
    assert snap["counters"]["a.b"] == 3
    assert snap["gauges"]["g"] == 7.5
    h = snap["histograms"]["h"]
    assert h["count"] == 10 and h["min"] == 0.0 and h["max"] == 9.0
    assert h["p50"] == 4.0
    path = str(tmp_path / "m.jsonl")
    m.export_jsonl(path)
    kinds = [json.loads(line)["kind"] for line in open(path)]
    assert kinds == ["counter", "gauge", "histogram"]


def test_collect_compile_caches():
    out = obs.collect_compile_caches(None)
    assert "compile.mesh_chunk.entries" in out


# ---------------------------------------------------------------------------
# online service drain
# ---------------------------------------------------------------------------

def _fleet(n=2):
    return [_inst(seed=s, scale=1.0 + 0.5 * s) for s in range(n)]


def test_online_parity_and_segment_drain():
    insts = _fleet()
    members = events.pad_fleet(insts, spare_apps=1)
    trace = events.random_trace(members, n_events=6, seed=0)

    kw = dict(spare_apps=1, alpha=0.1, tol=1e-4, accel=True)
    off = OnlineSolver(insts, **kw)
    reps_off = off.step(trace)

    m, tr = obs.Metrics(), obs.Tracer()
    on = OnlineSolver(insts, telemetry=True, metrics=m, tracer=tr, **kw)
    reps_on = on.step(trace)

    # parity: telemetry must not change what the service serves
    assert off.event_iters == on.event_iters
    for a, b in zip(reps_off, reps_on):
        assert a.iterations == b.iterations
        assert a.status == b.status
        np.testing.assert_array_equal(a.cost, b.cost)
    assert off.iter_trace == []

    # the drained segments reproduce the served iteration counts exactly
    per_event: dict[int, int] = {}
    for rec in on.iter_trace:
        per_event[rec["event"]] = per_event.get(rec["event"], 0) + 1
    for t, rep in enumerate(reps_on):
        assert per_event.get(t, 0) == rep.iterations, (
            f"event {t}: drained {per_event.get(t, 0)} records, "
            f"served {rep.iterations} iterations")
    assert per_event.get(-1, 0) > 0          # cold start recorded
    assert all(r.wall_s > 0 for r in reps_on)

    # metrics + spans populated
    snap = m.snapshot()
    assert snap["histograms"]["online.event.iters"]["sum"] == on.event_iters
    assert sum(v for k, v in snap["counters"].items()
               if k.startswith("online.event.")) == len(trace)
    assert any(e["name"].startswith("event:") for e in tr.events)
    assert tr.to_chrome()["traceEvents"]


# ---------------------------------------------------------------------------
# report generator
# ---------------------------------------------------------------------------

def _write_trace(tmp_path, events_rows, iters_rows, metrics=None):
    prefix = str(tmp_path / "t")
    with open(prefix + ".events.jsonl", "w") as f:
        for r in events_rows:
            f.write(json.dumps(r) + "\n")
    with open(prefix + ".iters.jsonl", "w") as f:
        for r in iters_rows:
            f.write(json.dumps(r) + "\n")
    if metrics is not None:
        with open(prefix + ".metrics.json", "w") as f:
            json.dump(metrics, f)
    return prefix


def _ev(t, member, iters, **kw):
    row = {"t": t, "event": "RateScale", "member": member,
           "iterations": iters, "cost": 1.0, "residual": 0.0,
           "status": "converged", "rungs": [], "rung_iters": [],
           "wall_s": 0.1, "solved_apps": 1, "skipped_apps": 0,
           "cold_restart": False, "rolled_back": False, "shed": []}
    row.update(kw)
    return row


def _it(member, event, segment, n):
    return [{"iter": i, "cost": 1.0, "residual": 0.1, "alpha": 0.1,
             "rung": 0, "anderson": -1.0, "bs_rounds": 1, "phi_delta": 0.0,
             "member": member, "event": event, "phase": "warm",
             "segment": segment} for i in range(n)]


def test_report_build_and_check(tmp_path):
    events_rows = [_ev(0, 0, 3), _ev(1, 1, 2,
                                     rungs=["half-alpha"], rung_iters=[2])]
    iters_rows = (_it(0, -1, 0, 4) + _it(0, 0, 1, 3) + _it(1, 1, 2, 2))
    metrics = {"counters": {"online.gate.skip": 1.0}, "gauges": {},
               "histograms": {}}
    prefix = _write_trace(tmp_path, events_rows, iters_rows, metrics)

    report = obs_report.build_report(obs_report.load_trace(prefix))
    s = report["summary"]
    assert s["n_events"] == 2 and s["event_iters"] == 5
    assert s["cold_start_iters_recorded"] == 4
    assert s["rung_iters"] == {"half-alpha": 2}
    assert s["gate_skips"] == 1.0
    m0 = next(m for m in report["members"] if m["member"] == 0)
    assert m0["total_iters"] == 3
    assert [seg["recorded"] for seg in m0["segments"]] == [4, 3]

    rows = [{"bench": "online", "scenario": "fig6-trace2", "V": 11,
             "solver": "online", "iters": 5}]
    assert obs_report.check_bench(report, rows, "fig6-trace2") == []
    rows[0]["iters"] = 6
    assert len(obs_report.check_bench(report, rows, "fig6-trace2")) == 1
    assert obs_report.check_bench(report, rows, "no-such") != []


def test_report_main_end_to_end(tmp_path):
    prefix = _write_trace(tmp_path, [_ev(0, 0, 4)], _it(0, 0, 0, 4))
    out = str(tmp_path / "report.json")
    bench = str(tmp_path / "bench.json")
    with open(bench, "w") as f:
        json.dump({"rows": [{"bench": "online", "scenario": "fig6-trace1",
                             "V": 11, "solver": "online", "iters": 4}]}, f)
    rc = obs_report.main(["--trace", prefix, "--out", out,
                          "--check-bench", bench,
                          "--scenario", "fig6-trace1"])
    assert rc == 0
    assert json.load(open(out))["summary"]["event_iters"] == 4
    # mismatch -> nonzero exit
    with open(bench, "w") as f:
        json.dump({"rows": [{"bench": "online", "scenario": "fig6-trace1",
                             "V": 11, "solver": "online", "iters": 5}]}, f)
    assert obs_report.main(["--trace", prefix, "--out", out,
                            "--check-bench", bench,
                            "--scenario", "fig6-trace1"]) == 1


# ---------------------------------------------------------------------------
# bench_record contention guard
# ---------------------------------------------------------------------------

def test_bench_record_skips_on_contended_box(tmp_path, monkeypatch):
    monkeypatch.setattr(common, "BENCH_PATH", str(tmp_path / "bench.json"))
    monkeypatch.setattr(os, "getloadavg",
                        lambda: (1e6, 0.0, 0.0), raising=False)
    monkeypatch.delenv("BENCH_FORCE_RECORD", raising=False)
    row = common.bench_record("b", scenario="s", V=1, solver="x", seconds=1.0)
    assert row["seconds"] == 1.0                    # row still returned
    assert not os.path.exists(common.BENCH_PATH)    # but nothing written


def test_bench_record_force_override(tmp_path, monkeypatch):
    monkeypatch.setattr(common, "BENCH_PATH", str(tmp_path / "bench.json"))
    monkeypatch.setattr(os, "getloadavg",
                        lambda: (1e6, 0.0, 0.0), raising=False)
    monkeypatch.setenv("BENCH_FORCE_RECORD", "1")
    common.bench_record("b", scenario="s", V=1, solver="x", seconds=1.0)
    assert len(common.load_rows(common.BENCH_PATH)) == 1


def test_bench_record_writes_on_idle_box(tmp_path, monkeypatch):
    monkeypatch.setattr(common, "BENCH_PATH", str(tmp_path / "bench.json"))
    monkeypatch.setattr(os, "getloadavg",
                        lambda: (0.0, 0.0, 0.0), raising=False)
    common.bench_record("b", scenario="s", V=1, solver="x", seconds=1.0)
    assert len(common.load_rows(common.BENCH_PATH)) == 1


# ---------------------------------------------------------------------------
# overhead gate: telemetry-on <= 5% per iteration on sw-queue
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_telemetry_overhead_sw_queue():
    if common._box_is_contended() is not None:
        pytest.skip("box is contended; timing comparison would be noise")
    inst = network.table_ii_instance("sw-queue", seed=0)
    phi0 = gp.init_phi(inst)
    kw = dict(alpha=0.1, max_iters=40, patience=10**6, tol=0.0)

    def timed(**extra):
        gp.solve(inst, phi0, **kw, **extra)          # compile warm-up
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            res = gp.solve(inst, phi0, **kw, **extra)
            jax.block_until_ready(res.phi.e)
            best = min(best, time.perf_counter() - t0)
        return best / int(res.iterations)

    off = timed()
    on = timed(telemetry=True)
    # 5% relative budget plus an absolute floor for dispatch jitter on
    # sub-millisecond iterations
    assert on <= off * 1.05 + 1e-4, (
        f"telemetry overhead {on / off - 1:.1%} per iteration "
        f"(on={on:.6f}s off={off:.6f}s)")
