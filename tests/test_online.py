"""Deterministic regression of the online GP service (DESIGN.md §16).

Drives the example's event sequence (``examples/online_adaptation.py``)
through :class:`repro.serve.OnlineSolver` and pins the service semantics:

  * every event re-converges (finite cost, residual below threshold) and
    tracks the cold optimum on the identical post-event instance;
  * the per-app skip gate freezes provably-stationary applications;
  * warm starts beat the cold restart strictly on the surge event;
  * topology events repair phi (zero mass on the failed link) and clear
    the Anderson window; small rate deltas keep it;
  * events touch only their fleet member — the others' live strategies
    are bit-identical before and after;
  * the event layer validates inputs and its random traces replay
    deterministically.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import events, gp, network, traffic
from repro.serve import OnlineSolver

ALPHA, TOL = 0.1, 1e-4


def _cold(inst):
    return gp.solve(inst, alpha=ALPHA, tol=TOL, accel=True)


def test_online_service_tracks_cold_through_event_sequence():
    inst = network.table_ii_instance("abilene", seed=0, rate_scale=0.5)
    solver = OnlineSolver([inst], alpha=ALPHA, tol=TOL, accel=True)

    # event 1: one app's rate jumps — the gate freezes the untouched apps
    rep = solver.process(events.RateScale(member=0, factor=1.8, app=0))
    assert rep.solved_apps == 1 and rep.skipped_apps == 2
    assert rep.kept_window and not rep.repaired
    cold = _cold(solver.member(0))
    assert rep.cost <= cold.final_cost * (1 + 10 * TOL)

    # event 2: global surge — warm start strictly beats the cold restart
    rep = solver.process(events.RateScale(member=0, factor=2.0))
    assert rep.kept_window            # 2.0 is inside SMALL_RATE_WINDOW
    cold = _cold(solver.member(0))
    assert rep.iterations < int(cold.iterations), (
        rep.iterations, int(cold.iterations))
    assert rep.cost <= cold.final_cost * 1.01

    # event 3: busiest-link failure — phi repaired, window cleared
    F = np.asarray(traffic.flows(solver.member(0), solver.phi(0)).F)
    i, j = (int(x) for x in np.unravel_index(F.argmax(), F.shape))
    rep = solver.process(events.LinkDown(member=0, i=i, j=j))
    assert rep.repaired and not rep.kept_window
    assert float(np.asarray(solver.phi(0).e)[:, :, i, j].max()) == 0.0
    cold = _cold(solver.member(0))
    assert rep.cost <= cold.final_cost * 1.01

    # event 4: load falls back — the service lands back near the start
    rep = solver.process(events.RateScale(member=0, factor=0.5))
    cold = _cold(solver.member(0))
    assert rep.cost <= cold.final_cost * 1.01

    # service-level invariants after the whole sequence
    assert np.isfinite(solver.costs()).all()
    assert float(solver.residuals()[0]) <= 1e-3
    assert solver.event_iters == sum(r.iterations for r in solver.reports)


def test_events_touch_only_their_member():
    insts = [network.table_ii_instance("abilene", seed=0, rate_scale=s)
             for s in (0.5, 1.0)]
    solver = OnlineSolver(insts, alpha=ALPHA, tol=TOL, accel=True)
    e0 = np.asarray(solver.phi(0).e).copy()
    c0 = np.asarray(solver.phi(0).c).copy()

    rep = solver.process(events.RateScale(member=1, factor=1.5))
    assert rep.member == 1
    np.testing.assert_array_equal(np.asarray(solver.phi(0).e), e0)
    np.testing.assert_array_equal(np.asarray(solver.phi(0).c), c0)


def test_app_churn_stays_inside_padded_envelope():
    inst = network.table_ii_instance("abilene", seed=0, rate_scale=1.0)
    (m,) = events.pad_fleet([inst], spare_apps=1)
    live0 = np.asarray(m.stage_mask).any(axis=1)
    assert live0.sum() == inst.A and not live0[-1]
    spare = int(np.flatnonzero(~live0)[0])

    arr = events.AppArrival(member=0, app=spare, dst=8,
                            rates=((1, 0.4), (6, 0.3)), n_tasks=2)
    m2, eff = events.apply_event(m, arr)
    live2 = np.asarray(m2.stage_mask).any(axis=1)
    assert eff.topology and live2[spare] and live2.sum() == inst.A + 1
    assert m2.r.shape == m.r.shape        # no shape change: same programs

    m3, eff3 = events.apply_event(m2, events.AppDeparture(member=0, app=spare))
    assert eff3.topology
    assert np.asarray(m3.stage_mask).any(axis=1).sum() == inst.A
    assert float(np.asarray(m3.r)[spare].max()) == 0.0


def test_event_validation_and_trace_determinism():
    inst = network.table_ii_instance("abilene", seed=0, rate_scale=1.0)
    (m,) = events.pad_fleet([inst], spare_apps=1)

    with pytest.raises(ValueError):        # link does not exist
        events.apply_event(m, events.LinkDown(member=0, i=0, j=0))
    with pytest.raises(ValueError):        # arrival into a live slot
        events.apply_event(m, events.AppArrival(member=0, app=0, dst=8,
                                                rates=((1, 0.4),)))
    with pytest.raises(ValueError):        # departure of a dead slot
        events.apply_event(m, events.AppDeparture(member=0, app=inst.A))

    members = events.pad_fleet(
        [network.table_ii_instance("abilene", seed=0, rate_scale=s)
         for s in (0.5, 1.0)], spare_apps=1)
    t1 = events.random_trace(members, n_events=20, seed=3)
    t2 = events.random_trace(members, n_events=20, seed=3)
    assert t1 == t2
    assert len(t1) == 20
    # every event in the trace must apply cleanly in sequence
    snaps = events.replay(members, t1)
    assert len(snaps) == 20


def test_health_report_and_lkg_semantics():
    inst = network.table_ii_instance("abilene", seed=0, rate_scale=0.5)
    solver = OnlineSolver([inst], alpha=ALPHA, tol=TOL, accel=True)

    rep = solver.process(events.RateScale(member=0, factor=1.5, app=0))
    # healthy path: converged status, empty ladder, LKG bound honoured
    assert rep.status == "converged" and rep.converged
    assert rep.rungs == () and not rep.rolled_back and not rep.quarantined
    assert np.isfinite(rep.incumbent_cost)
    assert rep.cost <= rep.incumbent_cost * (1 + 2 * 1e-4)
    # the serve advanced the last-known-good checkpoint
    _phi_lkg, cost_lkg = solver.incumbent(0)
    assert cost_lkg == pytest.approx(rep.cost)

    # the runtime invariant checker is clean on a healthy fleet
    for h in solver.verify_fleet():
        assert not h.corrupt and np.isfinite(h.cost)
        assert h.simplex <= 1e-5 and h.dead_link_mass <= 1e-6

    # a no-op event must not demote the verdict (fixed-point latches and
    # skip gates both count as converged)
    rep = solver.process(events.RateScale(member=0, factor=1.0, app=0))
    assert rep.status == "converged" and rep.converged
