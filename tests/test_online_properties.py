"""Property-based invariants of the online layer's primitives (§16).

The online service leans on three mechanical guarantees:

  * ``traffic.repair_phi`` / ``traffic.renormalize`` always return a
    strategy on the simplex constraints (1) with zero mass on dead links
    and disallowed CPU rows — for ANY live strategy and ANY surviving
    topology, not just the ones the benches happen to hit;
  * ``gp_step`` with the §15 accel safeguards commits only feasible
    strategies and never increases the objective (the stepsize ladder
    always holds the alpha=0 rung);
  * the bitset blocked-set kernel is bit-equal to the dense reference
    scan on randomized congested strategies (the fused hot path cannot
    silently diverge from Section IV's definition).

Randomization goes through ``tests/_hypothesis_compat`` — real
``hypothesis`` when installed, the deterministic fallback otherwise — so
tier-1 runs the same examples everywhere.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gp, marginals, network, traffic
from tests._hypothesis_compat import given, settings, strategies as st


# One compile each (shapes are fixed across examples): the eager accel
# ladder runs op-by-op and would dominate tier-1 wall clock otherwise.
_accel_step = jax.jit(lambda inst, phi, alpha: gp.gp_step(
    inst, phi, alpha, accel=True))
_blocked_both = jax.jit(lambda inst, phi: (
    lambda pdt: (gp.blocked_sets(inst, phi, pdt, method="bitset"),
                 gp.blocked_sets(inst, phi, pdt, method="scan"))
)(marginals.marginals(inst, phi).pdt))


def _random_strategy(inst, seed: int) -> traffic.Phi:
    """A feasible but arbitrary live strategy (cycles, improper links)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    e = jax.random.uniform(k1, (inst.A, inst.K1, inst.V, inst.V))
    e = e * inst.adj[None, None]
    c = jax.random.uniform(k2, (inst.A, inst.K1, inst.V))
    return traffic.renormalize(inst, traffic.Phi(e=e, c=c))


def _fail_link(inst, rank: int):
    """Drop the ``rank``-th live link (mod link count) from the instance."""
    import dataclasses

    links = np.argwhere(np.asarray(inst.adj))
    i, j = links[rank % len(links)]
    adj = np.asarray(inst.adj).copy()
    lp = np.asarray(inst.link_param).copy()
    adj[i, j] = False
    lp[i, j] = 0.0
    return dataclasses.replace(
        inst, adj=jnp.asarray(adj), link_param=jnp.asarray(lp)), (int(i), int(j))


# ---------------------------------------------------------------------------
# repair_phi / renormalize
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(scale=st.floats(min_value=0.4, max_value=2.5),
       seed=st.integers(min_value=0, max_value=10_000),
       rank=st.integers(min_value=0, max_value=27))
def test_repair_phi_simplex_and_zero_dead_mass(scale, seed, rank):
    inst = network.table_ii_instance("abilene", seed=0, rate_scale=scale)
    phi = _random_strategy(inst, seed)
    new_inst, (i, j) = _fail_link(inst, rank)

    repaired = traffic.repair_phi(new_inst, phi, gp.init_phi(new_inst))
    # constraint (1) holds exactly on the new instance
    assert float(traffic.feasibility_violation(new_inst, repaired)) <= 1e-5
    # zero mass on every dead direction, not just the newly failed link
    dead = ~np.asarray(new_inst.adj)[None, None]
    assert float(np.abs(np.asarray(repaired.e) * dead).max()) == 0.0
    assert float(np.asarray(repaired.e)[:, :, i, j].max()) == 0.0
    # zero CPU mass where offloading is disallowed
    cpu_dead = ~np.asarray(new_inst.cpu_allowed())[:, :, None]   # (A,K1,1)
    assert float(np.abs(np.asarray(repaired.c) * cpu_dead).max()) == 0.0


@settings(max_examples=6, deadline=None)
@given(scale=st.floats(min_value=0.4, max_value=2.5),
       seed=st.integers(min_value=0, max_value=10_000))
def test_renormalize_projects_onto_simplex(scale, seed):
    inst = network.table_ii_instance("abilene", seed=0, rate_scale=scale)
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    # drifted strategy: negative entries, off-graph mass, unnormalized rows
    e = jax.random.uniform(k1, (inst.A, inst.K1, inst.V, inst.V),
                           minval=-0.5, maxval=2.0)
    c = jax.random.uniform(k2, (inst.A, inst.K1, inst.V),
                           minval=-0.5, maxval=2.0)
    out = traffic.renormalize(inst, traffic.Phi(e=e, c=c))
    # contract: every row is either exactly on the simplex or exactly zero
    # (a row whose mass clipped away entirely is repair_phi's job, not
    # renormalize's), and degenerate rows are forced to zero
    tot = np.asarray(out.e.sum(-1) + out.c)
    degen = np.asarray(inst.degenerate_mask())
    assert (np.isclose(tot, 1.0, atol=1e-5) | (tot == 0.0)).all()
    assert (tot[degen] == 0.0).all()
    assert float(np.abs(np.asarray(out.e) *
                        ~np.asarray(inst.adj)[None, None]).max()) == 0.0
    assert float(np.asarray(out.e).min()) >= 0.0
    assert float(np.asarray(out.c).min()) >= 0.0


# ---------------------------------------------------------------------------
# gp_step: feasibility + monotone descent under the accel safeguards
# ---------------------------------------------------------------------------

@settings(max_examples=4, deadline=None)
@given(scale=st.floats(min_value=0.5, max_value=3.0),
       alpha=st.floats(min_value=0.02, max_value=0.4))
def test_gp_step_commits_feasible_never_worse_strategies(scale, alpha):
    inst = network.table_ii_instance("abilene", seed=0, rate_scale=scale)
    phi = gp.init_phi(inst)
    prev = float(traffic.total_cost(inst, phi))
    assert np.isfinite(prev)
    for _ in range(3):
        state = _accel_step(inst, phi, jnp.float32(alpha))
        phi = state.phi
        cost = float(state.cost)
        # committed strategy is feasible and its cost is the reported cost
        assert float(traffic.feasibility_violation(inst, phi)) <= 1e-5
        assert cost == pytest.approx(float(traffic.total_cost(inst, phi)),
                                     rel=1e-5)
        # the ladder holds an alpha=0 rung: the step can never lose ground
        assert cost <= prev * (1 + 1e-6) + 1e-6, (scale, alpha, cost, prev)
        prev = cost


# ---------------------------------------------------------------------------
# bitset blocked sets == dense reference scan (randomized)
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(scale=st.floats(min_value=0.5, max_value=3.0),
       seed=st.integers(min_value=0, max_value=10_000))
def test_blocked_bitset_bit_equal_to_dense_scan(scale, seed):
    inst = network.table_ii_instance("abilene", seed=0, rate_scale=scale)
    phi = _random_strategy(inst, seed)
    b_bit, b_scan = _blocked_both(inst, phi)
    np.testing.assert_array_equal(np.asarray(b_bit), np.asarray(b_scan))
