"""Ground-truth validation: packet-level simulation vs the analytic cost.

The paper's objective is 'delay-optimal' because sum of M/M/1 queue lengths
= expected packets in system = (Little) mean delay x input rate.  These
tests close the loop the flow-level evaluation leaves open.
"""

import numpy as np
import pytest

from repro.core import gp, network
from repro.core.simulate import simulate


@pytest.mark.slow
def test_littles_law_on_abilene():
    inst = network.table_ii_instance("abilene", seed=0, rate_scale=1.5)
    res = gp.solve(inst, alpha=0.1, max_iters=250)
    sim = simulate(inst, res.phi, horizon=3_000.0, warmup=300.0, seed=1)
    assert sim.n_delivered > 3_000
    # queueing simulations are noisy and service here is per-class
    # exponential (M/M/1 approximation); 30% agreement validates the model
    assert sim.mean_delay == pytest.approx(sim.predicted_delay, rel=0.30)
    # occupancy should also match D(phi) directly
    from repro.core.traffic import total_cost
    D = float(total_cost(inst, res.phi))
    assert sim.mean_queue_occupancy == pytest.approx(D, rel=0.30)


@pytest.mark.slow
def test_optimized_strategy_has_lower_simulated_delay():
    """GP's optimum must beat the congestion-oblivious baseline in REAL
    (simulated) delay, not just analytic cost."""
    from repro.core import baselines

    inst = network.table_ii_instance("abilene", seed=0, rate_scale=2.0)
    opt = gp.solve(inst, alpha=0.1, max_iters=200)
    lpr = baselines.lpr_sc(inst)
    sim_opt = simulate(inst, opt.phi, horizon=1_200.0, warmup=150.0, seed=2)
    sim_lpr = simulate(inst, lpr.phi, horizon=1_200.0, warmup=150.0, seed=2)
    assert sim_opt.n_delivered > 1_000
    # LPR overloads queues at 2x rates: simulated delay should be far worse
    assert sim_opt.mean_delay < sim_lpr.mean_delay * 0.8


@pytest.mark.slow
@pytest.mark.parametrize("name", ["abilene", "geant"])
def test_analytic_cost_tracks_simulation_before_and_after_surge(name):
    """The online story's ground truth: the analytic objective the service
    re-optimizes after a rate event must track REAL (packet-level) queue
    occupancy on both sides of the event, on two Table II networks.

    The surge doubles every input rate — the same event class the online
    service ingests as ``events.RateScale(factor=2.0)``."""
    import dataclasses

    from repro.core.traffic import total_cost

    # base load chosen so the doubled rates stay inside the regime where
    # the exponential-service approximation holds (heavier geant surges
    # drift past the 30% band as queues saturate)
    inst = network.table_ii_instance(name, seed=0, rate_scale=0.6)
    surged = dataclasses.replace(inst, r=inst.r * 2.0)
    for tag, cur in (("before", inst), ("after", surged)):
        res = gp.solve(cur, alpha=0.1, max_iters=250)
        sim = simulate(cur, res.phi, horizon=3_000.0, warmup=300.0, seed=4)
        assert sim.n_delivered > 2_000, (name, tag)
        D = float(total_cost(cur, res.phi))
        # same tolerance band as Little's-law test: per-class exponential
        # service is an M/M/1 approximation of the simulator's queues
        assert sim.mean_queue_occupancy == pytest.approx(D, rel=0.30), (
            name, tag, sim.mean_queue_occupancy, D)
    # sanity on the event itself: the surge must visibly raise occupancy
    assert float(total_cost(surged, gp.solve(surged, alpha=0.1,
                                             max_iters=250).phi)) > \
        float(total_cost(inst, gp.solve(inst, alpha=0.1, max_iters=250).phi))
