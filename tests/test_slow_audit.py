"""Marker hygiene: expensive generators never run in the quick tier.

``simulate()`` burns seconds to minutes per call and ``chaos_trace()``
replays every event it samples through ``apply_event``; CI's quick tier
deselects ``-m "not slow"`` and must stay fast.  This audit walks every
test module's AST and fails if a test function calls one of the audited
functions (directly or as ``module.fn``) without carrying
``@pytest.mark.slow`` — a regression that would otherwise surface only as
a mysteriously slow CI quick tier.
"""

import ast
import pathlib

TESTS = pathlib.Path(__file__).parent
AUDITED = {"simulate", "chaos_trace"}


def _calls_audited(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            fn = sub.func
            if isinstance(fn, ast.Name) and fn.id in AUDITED:
                return True
            if isinstance(fn, ast.Attribute) and fn.attr in AUDITED:
                return True
    return False


def _is_slow_marked(fn: ast.FunctionDef) -> bool:
    for dec in fn.decorator_list:
        # pytest.mark.slow, possibly called: pytest.mark.slow(...)
        node = dec.func if isinstance(dec, ast.Call) else dec
        if isinstance(node, ast.Attribute) and node.attr == "slow":
            return True
    return False


def test_every_expensive_caller_is_slow_marked():
    offenders = []
    for path in sorted(TESTS.glob("test_*.py")):
        tree = ast.parse(path.read_text(), filename=str(path))
        for node in ast.walk(tree):
            if (isinstance(node, ast.FunctionDef)
                    and node.name.startswith("test_")
                    and _calls_audited(node)
                    and not _is_slow_marked(node)):
                offenders.append(f"{path.name}::{node.name}")
    assert not offenders, (
        f"test functions call one of {sorted(AUDITED)} without "
        f"@pytest.mark.slow: {offenders}")
