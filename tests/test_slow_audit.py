"""Marker hygiene: packet-level simulation never runs in the quick tier.

``simulate()`` burns seconds to minutes per call; CI's quick tier
deselects ``-m "not slow"`` and must stay fast.  This audit walks every
test module's AST and fails if a test function calls ``simulate`` (directly
or as ``module.simulate``) without carrying ``@pytest.mark.slow`` — a
regression that would otherwise surface only as a mysteriously slow CI
quick tier.
"""

import ast
import pathlib

TESTS = pathlib.Path(__file__).parent


def _calls_simulate(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            fn = sub.func
            if isinstance(fn, ast.Name) and fn.id == "simulate":
                return True
            if isinstance(fn, ast.Attribute) and fn.attr == "simulate":
                return True
    return False


def _is_slow_marked(fn: ast.FunctionDef) -> bool:
    for dec in fn.decorator_list:
        # pytest.mark.slow, possibly called: pytest.mark.slow(...)
        node = dec.func if isinstance(dec, ast.Call) else dec
        if isinstance(node, ast.Attribute) and node.attr == "slow":
            return True
    return False


def test_every_simulate_caller_is_slow_marked():
    offenders = []
    for path in sorted(TESTS.glob("test_*.py")):
        tree = ast.parse(path.read_text(), filename=str(path))
        for node in ast.walk(tree):
            if (isinstance(node, ast.FunctionDef)
                    and node.name.startswith("test_")
                    and _calls_simulate(node)
                    and not _is_slow_marked(node)):
                offenders.append(f"{path.name}::{node.name}")
    assert not offenders, (
        "test functions call simulate() without @pytest.mark.slow: "
        f"{offenders}")
