"""Per-architecture smoke tests (assignment deliverable f).

For each of the 10 assigned architectures: instantiate the REDUCED variant
(2 layers, d_model <= 512, <= 4 experts) and run one forward pass and one
train step on CPU, asserting output shapes and the absence of NaNs.
"""

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.data.pipeline import batch_for
from repro.models import flops
from repro.models.transformer import make_model
from repro.train import trainer

ARCHS = configs.ARCH_NAMES


@pytest.mark.parametrize("name", ARCHS)
def test_reduced_constraints(name):
    cfg = configs.get(name, reduced=True)
    assert cfg.n_layers == 2
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_and_finiteness(name):
    cfg = configs.get(name, reduced=True)
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 64
    batch = batch_for(cfg, batch=B, seq_len=S, seed=0)
    logits, cache, aux = model.apply(params, batch)
    S_total = S if cfg.frontend != "vision" else batch["patches"].shape[1] + batch["tokens"].shape[1]
    assert logits.shape == (B, S_total, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ARCHS)
def test_one_train_step(name):
    cfg = configs.get(name, reduced=True)
    model = make_model(cfg)
    state = trainer.init_state(model, jax.random.PRNGKey(0))
    step = jax.jit(trainer.make_train_step(model))
    batch = batch_for(cfg, batch=2, seq_len=64, seed=0)
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"])) and float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        if a.size else 0.0,
        state.params, new_state.params)
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize("name", ARCHS)
def test_full_config_matches_assignment(name):
    """The FULL configs carry the exact assigned hyperparameters."""
    spec = {
        "deepseek-v3-671b": (61, 7168, 128, 128, 129280),
        "mixtral-8x22b": (56, 6144, 48, 8, 32768),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 200064),
        "internlm2-1.8b": (24, 2048, 16, 8, 92544),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 65536),
        "hubert-xlarge": (48, 1280, 16, 16, 504),
        "llava-next-34b": (60, 7168, 56, 8, 64000),
        "tinyllama-1.1b": (22, 2048, 32, 4, 32000),
        "mamba2-780m": (48, 1536, 0, 0, 50280),
        "gemma2-9b": (42, 3584, 16, 8, 256000),
    }[name]
    cfg = configs.get(name)
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.vocab) == spec
    moe_spec = {
        "deepseek-v3-671b": (256, 8), "mixtral-8x22b": (8, 2), "jamba-v0.1-52b": (16, 2),
    }
    if name in moe_spec:
        assert (cfg.moe.n_experts, cfg.moe.top_k) == moe_spec[name]
    if name == "mamba2-780m":
        assert cfg.ssm.d_state == 128 and cfg.attn_kind == "none"


def test_param_counts_match_advertised_sizes():
    """Analytic parameter counts land near the models' advertised sizes."""
    expect = {
        "deepseek-v3-671b": (671e9, 0.10),
        "mixtral-8x22b": (141e9, 0.12),
        "tinyllama-1.1b": (1.1e9, 0.12),
        "mamba2-780m": (0.78e9, 0.15),
        "gemma2-9b": (9.2e9, 0.15),
        "jamba-v0.1-52b": (52e9, 0.20),
    }
    for name, (target, tol) in expect.items():
        total, _ = flops.param_count(configs.get(name))
        assert abs(total - target) / target < tol, (name, total / 1e9)


def test_moe_active_params_much_smaller():
    total, active = flops.param_count(configs.get("deepseek-v3-671b"))
    assert active < 0.1 * total      # ~37B active of 671B
