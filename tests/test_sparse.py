"""Sparse solve path (DESIGN.md §18): neighbor-list / BSR chain solves,
neighbor blocked-set sweeps, hetero-degree batch padding, and the 2-D
(app x node-space) mesh.

Parity targets come from the nilpotency argument: loop-free strategies make
every stage matrix strictly triangular under a topological order, so the
fixed-point sweep terminates EXACTLY — the sparse paths are the same
arithmetic as the dense solves up to summation order (<= 1e-5 on cost-scale
quantities), and the tagged sweep is bit-equal (pure boolean lattice).

The 2-D mesh cases skip below 4 devices; CI runs this module a second time
under ``XLA_FLAGS=--xla_force_host_platform_device_count=4``.
"""

import jax
import numpy as np
import pytest

from repro.core import batch, compat, distributed, engine, gp, network
from repro.core import marginals as marginals_mod
from repro.core import traffic

KW = dict(alpha=0.1, max_iters=40, patience=10**6, tol=0.0)

need4 = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs 4 devices (XLA_FLAGS=--xla_force_host_platform_device_count=4)",
)

SCENARIOS = ["abilene", "geant", "sw-queue"]


def _sparse_inst(name, rate_scale=2.0):
    return network.with_sparse(
        network.table_ii_instance(name, seed=0, rate_scale=rate_scale))


def _rel(a, b):
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    return float(np.max(np.abs(a - b) / np.maximum(np.abs(a), 1e-9)))


def _mid_solve_phi(inst, iters=10):
    """A congested mid-solve iterate (nontrivial routing splits, traffic
    well away from the init point) — the regime the parity claim must hold
    in, not just at phi0."""
    res = gp.solve(inst, gp.init_phi(inst), alpha=0.1, max_iters=iters,
                   patience=10**6, tol=0.0, solver="batched_lu")
    return res.phi


@pytest.mark.parametrize("name", SCENARIOS)
def test_stage_traffic_sparse_matches_dense(name):
    inst = _sparse_inst(name)
    for phi in (gp.init_phi(inst), _mid_solve_phi(inst)):
        t_s, g_s = traffic.stage_traffic(inst, phi, solver="sparse")
        t_d, g_d = traffic.stage_traffic(inst, phi, solver="batched_lu")
        assert _rel(t_d, t_s) <= 1e-5
        assert _rel(g_d, g_s) <= 1e-5


@pytest.mark.parametrize("name", SCENARIOS)
def test_pdt_recursion_sparse_matches_dense(name):
    inst = _sparse_inst(name)
    phi = _mid_solve_phi(inst)
    fl = traffic.flows(inst, phi)
    Dp = marginals_mod.link_marginals(inst, fl.F)
    Cp = marginals_mod.comp_marginals(inst, fl.G)
    pdt_s = marginals_mod.pdt_recursion(inst, phi, Dp, Cp, solver="sparse")
    pdt_d = marginals_mod.pdt_recursion(inst, phi, Dp, Cp,
                                        solver="batched_lu")
    assert _rel(pdt_d, pdt_s) <= 1e-5


@pytest.mark.parametrize("name", SCENARIOS)
def test_blocked_nbr_bit_equal(name):
    """The neighbor-list tagged sweep is a monotone boolean fixed point —
    bit-equal to both the bit-packed kernel and the dense scan."""
    inst = _sparse_inst(name)
    phi = _mid_solve_phi(inst)
    m = marginals_mod.marginals(inst, phi)
    ref = engine.blocked_sets(inst, phi, m.pdt, method="scan")
    bit = engine.blocked_sets(inst, phi, m.pdt, method="bitset")
    nbr = engine.blocked_sets(inst, phi, m.pdt, method="nbr")
    assert np.array_equal(np.asarray(ref), np.asarray(bit))
    assert np.array_equal(np.asarray(ref), np.asarray(nbr))


@pytest.mark.parametrize("name", ["abilene", "geant"])
def test_full_solve_sparse_matches_dense(name):
    """Whole-trajectory parity: identical committed iterations, cost
    histories <= 1e-5 (the acceptance bound on the Table II scenarios)."""
    inst = _sparse_inst(name)
    phi0 = gp.init_phi(inst)
    ref = gp.solve(inst, phi0, solver="batched_lu", **KW)
    res = gp.solve(inst, phi0, solver="sparse", **KW)
    assert int(res.iterations) == int(ref.iterations)
    assert _rel(ref.cost_history, res.cost_history) <= 1e-5


def test_auto_dispatch():
    """"auto" resolves to sparse only with the topology attached AND at
    metro scale (SPARSE_MIN_V); stripping the fields restores dense."""
    inst = _sparse_inst("abilene")
    assert traffic.resolve_solver("auto", traffic.SPARSE_MIN_V, inst) == "sparse"
    assert traffic.resolve_solver("auto", traffic.SPARSE_MIN_V - 1,
                                  inst) != "sparse"
    bare = network.without_sparse(inst)
    assert traffic.resolve_solver("auto", traffic.SPARSE_MIN_V,
                                  bare) != "sparse"
    # explicit solver choices pass through untouched
    assert traffic.resolve_solver("dense", 10**4, inst) == "dense"


def _star(n_leaves):
    V = n_leaves + 1
    adj = np.zeros((V, V), dtype=bool)
    adj[0, 1:] = adj[1:, 0] = True
    return adj


def _ring(V):
    adj = np.zeros((V, V), dtype=bool)
    for i in range(V):
        adj[i, (i + 1) % V] = adj[(i + 1) % V, i] = True
    return adj


def test_pad_instances_hetero_degree():
    """Batching a degree-12 star with degree-2 rings must not silently
    densify the padded neighbor lists: default raises, "pad" opts into the
    family-max degree, "strip" falls back to the dense-only batch."""
    star = network.with_sparse(
        network.build_instance(_star(12), n_apps=2, seed=0))
    ring = network.with_sparse(
        network.build_instance(_ring(13), n_apps=2, seed=1))
    assert int(star.max_degree) > 4 * int(ring.max_degree)

    with pytest.raises(ValueError, match="degree"):
        batch.pad_instances([star, ring])

    padded = batch.pad_instances([star, ring], hetero_degree="pad")
    assert padded.has_sparse
    assert padded.out_nbr.shape[0] == 2
    assert padded.out_nbr.shape[-1] >= int(star.max_degree)

    stripped = batch.pad_instances([star, ring], hetero_degree="strip")
    assert not stripped.has_sparse

    with pytest.raises(ValueError):
        batch.pad_instances([star, network.without_sparse(ring)])

    # near-equal degrees stay sparse under the default policy
    ok = batch.pad_instances([ring, network.with_sparse(
        network.build_instance(_ring(13), n_apps=2, seed=2))])
    assert ok.has_sparse


def test_pad_instance_rederives_sparse():
    """Single-instance V-padding re-derives the topology on the padded
    adjacency: dead nodes are isolated, live neighbors unchanged."""
    inst = _sparse_inst("abilene")
    out = batch.pad_instance(inst, inst.V + 5, inst.A, inst.K1)
    assert out.has_sparse
    assert out.out_nbr.shape[0] == inst.V + 5
    assert not bool(np.asarray(out.out_mask[inst.V:]).any())
    np.testing.assert_array_equal(
        np.asarray(out.out_mask[:inst.V]), np.asarray(inst.out_mask))


# ---------------------------------------------------------------------------
# 2-D app x node-space mesh
# ---------------------------------------------------------------------------

def _metro60():
    return network.metro_instance("sw", 60)


@need4
def test_2d_mesh_matches_single_device():
    """2x2 stage x node mesh == single-device sparse solve (<= 1e-4; the
    node axis storage-shards phi rows and runs the tagged sweep
    node-parallel, so trajectories agree to summation order)."""
    inst = _metro60()
    phi0 = gp.init_phi(inst)
    ref = gp.solve(inst, phi0, solver="sparse", **KW)
    mesh = compat.make_mesh((2, 2), ("stage", "node"))
    res = distributed.solve_sharded(inst, mesh, node_axis="node",
                                    phi0=phi0, solver="sparse", **KW)
    assert int(res.iterations) == int(ref.iterations)
    assert _rel(ref.cost_history, res.cost_history) <= 1e-4


@need4
def test_node_only_mesh_matches_single_device():
    """1x4 mesh: all parallelism on the node axis (V=60 % 4 == 0 takes the
    genuinely sharded tagged-sweep path)."""
    inst = _metro60()
    phi0 = gp.init_phi(inst)
    ref = gp.solve(inst, phi0, solver="sparse", alpha=0.1, max_iters=15,
                   patience=10**6, tol=0.0)
    mesh = compat.make_mesh((1, 4), ("stage", "node"))
    res = distributed.solve_sharded(inst, mesh, node_axis="node",
                                    phi0=phi0, solver="sparse", alpha=0.1,
                                    max_iters=15, patience=10**6, tol=0.0)
    assert _rel(ref.cost_history, res.cost_history) <= 1e-4
