"""Substrate tests: optimizer, schedule, data pipeline, checkpointing."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, strategies as st

from repro.data.pipeline import SyntheticTokens, batch_for
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule
from repro import configs


def test_adamw_reduces_quadratic():
    """AdamW minimizes a simple quadratic."""
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    target = jnp.asarray([1.0, 2.0])
    for _ in range(400):
        grads = {"w": 2 * (params["w"] - target)}
        params, state, _ = adamw_update(params, grads, state, lr=0.05,
                                        weight_decay=0.0)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_adamw_bf16_moments_close_to_f32():
    params = {"w": jnp.ones((64,))}
    g = {"w": 0.1 * jnp.arange(64, dtype=jnp.float32)}
    s32 = adamw_init(params, jnp.float32)
    s16 = adamw_init(params, jnp.bfloat16)
    p32, s32, _ = adamw_update(params, g, s32, lr=1e-2)
    p16, s16, _ = adamw_update(params, g, s16, lr=1e-2)
    np.testing.assert_allclose(np.asarray(p32["w"]), np.asarray(p16["w"]),
                               atol=1e-3)
    assert s16.mu["w"].dtype == jnp.bfloat16


def test_grad_clipping():
    params = {"w": jnp.zeros((4,))}
    state = adamw_init(params)
    huge = {"w": 1e8 * jnp.ones((4,))}
    new_params, _, gnorm = adamw_update(params, huge, state, lr=1.0,
                                        grad_clip=1.0, weight_decay=0.0)
    assert float(gnorm) == pytest.approx(2e8, rel=1e-3)
    assert float(jnp.abs(new_params["w"]).max()) < 10.0


@given(step=st.integers(1, 10_000))
@settings(max_examples=30, deadline=None)
def test_schedule_bounds(step):
    lr = float(cosine_schedule(jnp.int32(step), peak_lr=3e-4, warmup=100,
                               total=10_000))
    assert 0.0 < lr <= 3e-4 + 1e-9


def test_schedule_warmup_then_decay():
    lrs = [float(cosine_schedule(jnp.int32(s), peak_lr=1.0, warmup=10, total=100))
           for s in [1, 5, 10, 50, 100]]
    assert lrs[0] < lrs[1] < lrs[2]           # warmup
    assert lrs[2] >= lrs[3] >= lrs[4]         # decay
    assert lrs[4] >= 0.1 - 1e-6               # min ratio floor


def test_synthetic_tokens_deterministic_and_structured():
    a = next(iter(SyntheticTokens(vocab=64, seq_len=32, batch=4, seed=7)))
    b = next(iter(SyntheticTokens(vocab=64, seq_len=32, batch=4, seed=7)))
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    np.testing.assert_array_equal(
        np.asarray(a["tokens"][:, 1:]), np.asarray(a["targets"][:, :-1]))


def test_batch_for_modalities():
    au = batch_for(configs.get("hubert-xlarge", reduced=True), 2, 64)
    assert au["embeds"].shape == (2, 64, 256) and au["mask"].dtype == bool
    vl = batch_for(configs.get("llava-next-34b", reduced=True), 2, 64)
    assert "patches" in vl and "tokens" in vl


def test_checkpoint_roundtrip():
    from repro.checkpoint import load_checkpoint, restore_latest, save_checkpoint

    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": [jnp.ones((2,), jnp.int32), {"c": jnp.asarray(2.5)}]}
    with tempfile.TemporaryDirectory() as td:
        save_checkpoint(td, tree, step=3)
        save_checkpoint(td, jax.tree_util.tree_map(lambda x: x * 2, tree), step=7)
        like = jax.tree_util.tree_map(lambda x: jnp.zeros_like(x), tree)
        restored, step = restore_latest(td, like)
        assert step == 7
        np.testing.assert_allclose(np.asarray(restored["a"]),
                                   2 * np.arange(12, dtype=np.float32).reshape(3, 4))


def test_checkpoint_shape_mismatch_rejected():
    from repro.checkpoint import load_checkpoint, save_checkpoint

    with tempfile.TemporaryDirectory() as td:
        fn = save_checkpoint(td, {"a": jnp.zeros((3,))}, step=0)
        with pytest.raises(AssertionError):
            load_checkpoint(fn, {"a": jnp.zeros((4,))})


def test_training_reduces_loss_tiny_model():
    """Integration: a tiny LM learns the Markov stream (fast version of
    examples/train_100m.py)."""
    import dataclasses

    from repro.models.transformer import Model
    from repro.train import trainer

    cfg = dataclasses.replace(
        configs.get("tinyllama-1.1b", reduced=True),
        vocab=128, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256, n_layers=2)
    model = Model(cfg)
    data = iter(SyntheticTokens(vocab=cfg.vocab, seq_len=64, batch=8, seed=0))
    state, hist = trainer.train_loop(model, data, steps=60, peak_lr=3e-3,
                                     warmup=10, total=60, log_every=20)
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.98
