"""Traffic fixed-point invariants (Section II flow model)."""

import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, strategies as st

from repro.core import network, traffic
from tests.helpers import random_loopfree_phi, small_instances


@pytest.mark.parametrize("inst", small_instances(), ids=["abilene", "tree"])
@given(seed=st.integers(0, 1000))
@settings(max_examples=15, deadline=None)
def test_flow_conservation(inst, seed):
    """t_i(a,k) = sum_j t_j phi_ji + injection  (definition of traffic)."""
    phi = random_loopfree_phi(inst, seed)
    fl = traffic.flows(inst, phi)
    t, g = np.asarray(fl.t), np.asarray(fl.g)
    r = np.asarray(inst.r)
    for a in range(inst.A):
        for k in range(inst.K1):
            inject = r[a] if k == 0 else g[a, k - 1]
            incoming = np.asarray(phi.e)[a, k].T @ t[a, k]
            np.testing.assert_allclose(t[a, k], incoming + inject, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("inst", small_instances(), ids=["abilene", "tree"])
@given(seed=st.integers(0, 1000))
@settings(max_examples=15, deadline=None)
def test_traffic_bounded_and_valid(inst, seed):
    """Loop-free traffic never exceeds the injected totals (no amplification)."""
    phi = random_loopfree_phi(inst, seed)
    fl = traffic.flows(inst, phi)
    assert bool(traffic.traffic_is_valid(inst, fl.t))
    total_in = float(jnp.sum(inst.r, axis=1).max())
    assert float(fl.t.max()) <= total_in + 1e-3
    assert float(fl.t.min()) >= -1e-4


@pytest.mark.parametrize("inst", small_instances(), ids=["abilene", "tree"])
def test_all_input_reaches_destination(inst):
    """Constraint (1): everything injected exits as final results at d_a."""
    phi = random_loopfree_phi(inst, seed=123)
    fl = traffic.flows(inst, phi)
    t = np.asarray(fl.t)
    for a in range(inst.A):
        k_last = int(inst.n_tasks[a])
        d = int(inst.dst[a])
        injected = float(np.asarray(inst.r)[a].sum())
        # traffic absorbed at (d_a, K) = arriving final results + local conv
        phi_row = np.asarray(phi.e)[a, k_last][d]
        assert phi_row.sum() == pytest.approx(0.0, abs=1e-6)
        # total final-stage production equals total input (packet conversion
        # is one-in-one-out): sum of stage-K injections == r_total
        produced = float(np.asarray(fl.g)[a, k_last - 1].sum())
        assert produced == pytest.approx(injected, rel=1e-4)


def test_renormalize_fixes_violations():
    inst = small_instances()[0]
    phi = random_loopfree_phi(inst, 7)
    broken = traffic.Phi(e=phi.e * 1.7 + 0.01 * inst.adj[None, None], c=phi.c * 0.3)
    fixed = traffic.renormalize(inst, broken)
    assert float(traffic.feasibility_violation(inst, fixed)) < 1e-5


def test_total_cost_positive_and_finite():
    for inst in small_instances():
        phi = random_loopfree_phi(inst, 3)
        c = float(traffic.total_cost(inst, phi))
        assert np.isfinite(c) and c > 0
